"""Paper Sec. IV-B: filtering-stage accuracy (HR) under the three configs —
(1) FP32 + cosine, (2) int8 + cosine, (3) int8 + LSH-Hamming (iMARS).

Synthetic MovieLens (real dataset unavailable offline): reproduces the
ORDERING + drop structure (int8 ~ fp32; LSH costs several points), not the
absolute 26.8/26.2/20.8 values. Paper deltas quoted in the output.
"""
import jax
import jax.numpy as jnp

from repro.data import synthetic
from repro.models import recsys as rs
from repro.optim import adamw
from repro.serving.recsys_engine import RecSysEngine, hit_rate


def train_and_eval(n_users=1500, n_items=800, steps=300, radius=112,
                   seed=0, scan_block=None, history_len=20):
    """Train a YoutubeDNN on the synthetic catalog and HR@10-eval the three
    accuracy configs. `scan_block` forces the filtering-stage NNS plan
    (None=auto, 0=dense, >0=streaming chunk) so accuracy can be re-anchored
    through the streaming path at any catalog size."""
    data = synthetic.make_movielens(n_users=n_users, n_items=n_items,
                                    history_len=history_len)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=data.histories.shape[1])
    params = rs.init_youtubednn(jax.random.key(seed), cfg)
    state = adamw.init_adamw_state(params)
    lg = jax.jit(jax.value_and_grad(
        lambda p, b: rs.filtering_loss(p, cfg, b)))
    for batch in synthetic.movielens_batches(data, 256, steps):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        _, g = lg(params, b)
        params, state = adamw.adamw_update(g, state, params, 3e-3,
                                           weight_decay=0.0)
    engine = RecSysEngine.build(params, cfg, radius=radius, n_candidates=64,
                                scan_block=scan_block)
    hrs = {mode: hit_rate(engine, data, k=10, mode=mode)
           for mode in ("fp32", "int8", "lsh")}
    return hrs


def rows(quick: bool = True):
    kw = dict(n_users=400, n_items=300, steps=250) if quick else {}
    hrs = train_and_eval(**kw)
    paper = {"fp32": 0.268, "int8": 0.262, "lsh": 0.208}
    out = []
    for mode in ("fp32", "int8", "lsh"):
        out.append((
            f"accuracy/hr10_{mode}", 0.0,
            f"hr={hrs[mode]:.3f};paper={paper[mode]:.3f}(real MovieLens)",
        ))
    out.append((
        "accuracy/ordering", 0.0,
        f"int8_drop={hrs['fp32']-hrs['int8']:+.3f}(paper +0.006);"
        f"lsh_drop={hrs['int8']-hrs['lsh']:+.3f}(paper +0.054);"
        f"ok={hrs['lsh'] <= hrs['int8'] + 0.02 and abs(hrs['fp32']-hrs['int8']) < 0.05}",
    ))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.6f},{derived}")


if __name__ == "__main__":
    main()
