"""Pipelined vs synchronous serving: qps and latency through the same engine.

The synchronous `MicroBatcher` serializes host work (stacking/padding the
next bucket, converting and fanning out the previous bucket's results)
against device compute, and serializes consecutive buckets' NNS scans
behind each other — exactly the lookup/scan overlap iMARS builds into
hardware. The pipelined `AsyncServer` recovers both:

  * a ring of in-flight buckets dispatched through the staged serve
    pipeline (lookup -> scan -> rank) overlaps host prep and result
    fan-out with device compute (JAX async dispatch, no threads);
  * on an engine sharded with a query mesh axis, consecutive full buckets
    coalesce into one routed super-batch whose buckets scan **disjoint
    query blocks in parallel** (2 fake CPU devices here, same mechanism
    as the `streaming_qp2` cells in benchmarks/nns_scale.py).

This benchmark serves the *same* query stream through the synchronous
path, the pipelined-only path, and the pipelined+routed path on this host
and reports qps, per-wave p50/p99 wall latency, and the
pipelined-over-synchronous speedup at batch 256 with the >= 1.2x target
(acceptance gate; bit-for-bit equality with the synchronous path is
asserted here and in tests/test_async_serving.py — the pipeline may only
move time, never results).

The engine runs the *streaming* filtering plan by default (scan_block=4096
at a 16k catalog — the million-item operating point scaled to bench
runtime; `--scan-block 0` switches to the dense plan), so the scan
dominates exactly as it does at production scale.

  PYTHONPATH=src python -m benchmarks.async_serving
      [--sizes 16384] [--batch 256] [--queries 2048] [--scan-block 4096]
      [--depth 2] [--devices 2] [--wave 1024] [--repeats 2] [--out DIR]

``--sizes`` (comma-separated catalog sizes), ``--repeats``, and ``--out``
are the flags every serving benchmark shares, so tools/bench_compare.py
can diff any pair of artifacts without per-benchmark special cases.

Variance control (this host is a noisy 2-core container): unless the
caller already set it, ``--xla_cpu_multi_thread_eigen=false`` is appended
to XLA_FLAGS before jax loads (Eigen's intra-op thread pool thrashing the
2 cores was the dominant run-to-run jitter), and every server is measured
``--repeats`` times with the best run reported — the first measured pass
doubles as a thermal/allocator warmup on top of the compile-off-the-clock
wave. Emits BENCH_async_serving.json (see benchmarks/bench_io.py).
"""
from __future__ import annotations

import argparse
import os
import time


def _default_xla_cpu_flags() -> None:
    """Append the Eigen single-thread flag unless the caller chose one.

    Must run before the first jax import; both this benchmark's main() and
    benchmarks/catalog_churn.py call it first thing.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_cpu_multi_thread_eigen" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_cpu_multi_thread_eigen=false").strip()


def _setup(n_items: int, scan_block: int | None, history_len: int = 12,
           hot_rows: int = 256):
    import jax
    import numpy as np

    from repro.data import synthetic
    from repro.models import recsys as rs
    from repro.serving import RecSysEngine

    data = synthetic.make_movielens(n_users=2000, n_items=n_items,
                                    history_len=history_len)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=history_len)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=data.n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=50,
                                top_k=10, hot_rows=hot_rows, item_freqs=freqs,
                                scan_block=scan_block)
    return engine, data


def _measure(server, queries, wave: int):
    """Serve `queries` in `wave`-sized waves; (qps, p50_ms, p99_ms, items).

    A wave holds several buckets so the pipelined server's ring actually
    fills; the synchronous server drains the same waves bucket by bucket.
    """
    import numpy as np

    served, wave_ms = [], []
    t0 = time.perf_counter()
    for lo in range(0, len(queries), wave):
        w0 = time.perf_counter()
        served.extend(server.serve_many(queries[lo: lo + wave]))
        wave_ms.append((time.perf_counter() - w0) * 1e3)
    dt = time.perf_counter() - t0
    lat = np.percentile(wave_ms, [50, 99])
    return len(queries) / dt, lat[0], lat[1], np.stack(
        [s.items for s in served])


def rows(batch: int, n_queries: int, n_items: int, depth: int,
         n_devices: int, wave: int, scan_block: int | None,
         repeats: int = 2):
    import jax
    import numpy as np

    from repro.data.synthetic import serving_queries
    from repro.serving import make_server

    engine, data = _setup(n_items, scan_block)
    rng = np.random.default_rng(0)
    queries = serving_queries(data, rng.integers(0, data.n_users, n_queries))
    warm = serving_queries(data, rng.integers(0, data.n_users, wave))

    servers = [
        ("sync", make_server(engine, "sync", max_batch=batch,
                             buckets=(batch,))),
        ("pipelined", make_server(engine, "pipelined", max_batch=batch,
                                  buckets=(batch,), depth=depth)),
    ]
    if n_devices > 1 and jax.device_count() >= n_devices:
        mesh = jax.make_mesh((n_devices,), ("qp",))
        routed = engine.shard(mesh, query_axis="qp")
        servers.append((
            f"pipelined_routed_qp{n_devices}",
            make_server(routed, "pipelined", max_batch=batch,
                        buckets=(batch,), depth=depth)))

    out, qps, base_items = [], {}, None
    for name, server in servers:
        server.serve_many(warm)  # compile every wave shape off the clock
        # best of `repeats` measured passes: run 1 doubles as warmup, the
        # best run is the least-preempted one on this noisy 2-core host
        q, p50, p99, items = max(
            (_measure(server, queries, wave) for _ in range(max(repeats, 1))),
            key=lambda r: r[0])
        qps[name] = q
        if base_items is None:
            base_items = items
        bitmatch = bool((items == base_items).all())
        out.append((
            f"serving/async/{name}_batch{batch}_n{n_items}", 1e6 / q,
            f"qps={q:.0f};p50_ms={p50:.2f};p99_ms={p99:.2f};"
            f"bitmatch_sync={bitmatch};host=CPU(container)",
        ))
        assert bitmatch, f"{name} diverged from the synchronous path"
    best = max(q for name, q in qps.items() if name != "sync")
    speedup = best / qps["sync"]
    out.append((
        f"serving/async/pipelined_speedup_n{n_items}", 0.0,
        f"pipelined_over_sync={speedup:.2f}x(target >=1.2x);"
        f"ok={speedup >= 1.2};batch={batch};items={n_items};depth={depth}",
    ))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated catalog sizes (unified flag; "
                         "default: --items)")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--queries", type=int, default=2048)
    ap.add_argument("--items", type=int, default=16384,
                    help="catalog size (alias kept for back-compat; "
                         "--sizes wins when both are given)")
    ap.add_argument("--scan-block", type=int, default=4096,
                    help="engine scan_block: the streaming filtering plan "
                         "(the million-item operating point, scaled to "
                         "bench runtime); 0 forces the dense plan")
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--devices", type=int, default=2,
                    help="fake CPU devices for the routed query mesh "
                         "(set before jax import; 1 disables routing)")
    ap.add_argument("--wave", type=int, default=1024,
                    help="queries submitted per serve_many call")
    ap.add_argument("--repeats", type=int, default=2,
                    help="measured passes per server (first doubles as "
                         "warmup; best pass reported)")
    ap.add_argument("--out", type=str, default=None,
                    help="artifact directory (default $BENCH_OUT_DIR or .)")
    args = ap.parse_args()
    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else (args.items,))

    _default_xla_cpu_flags()  # must precede the first jax import
    if args.devices > 1:  # must precede the first jax import
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    from benchmarks.bench_io import csv_rows_to_json, write_bench_json

    out = []
    for n_items in sizes:
        out.extend(rows(args.batch, args.queries, n_items, args.depth,
                        args.devices, args.wave, args.scan_block,
                        args.repeats))
    for name, us, derived in out:
        print(f"{name},{us:.6f},{derived}")
    path = write_bench_json(
        "async_serving", csv_rows_to_json(out), out_dir=args.out,
        config={"batch": args.batch, "queries": args.queries,
                "sizes": sizes, "scan_block": args.scan_block,
                "depth": args.depth, "devices": args.devices,
                "wave": args.wave, "repeats": args.repeats})
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
