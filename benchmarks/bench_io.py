"""Machine-readable benchmark artifacts.

Every benchmark module keeps its human CSV on stdout and additionally writes
``BENCH_<name>.json`` (to $BENCH_OUT_DIR, default CWD) so the perf trajectory
across PRs can be diffed by tooling instead of parsed out of logs.
"""
from __future__ import annotations

import datetime
import json
import os
import platform
import subprocess
import time


def git_sha() -> str | None:
    """Commit sha of the benchmarked tree ($GITHUB_SHA in CI, else git)."""
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None  # provenance is best-effort; never lose the artifact


def write_bench_json(name: str, rows: list[dict], *, out_dir: str | None = None,
                     **extra) -> str:
    """Write BENCH_<name>.json with `rows` + host metadata; returns the path.

    Every artifact carries provenance (`git_sha`, `iso_time`) so perf
    trajectories across PRs are attributable — `tools/bench_compare.py`
    prints both sides' provenance when diffing. `rows` must be the
    csv-shaped dicts of `csv_rows_to_json` (name/us_per_call/derived) —
    the one shape `tools/bench_compare.py` diffs without special cases;
    benchmark-specific raw measurements ride in `**extra` keys instead.
    `out_dir` (the unified ``--out`` flag) overrides $BENCH_OUT_DIR.
    """
    out_dir = out_dir or os.environ.get("BENCH_OUT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {
        "bench": name,
        "unix_time": int(time.time()),
        "iso_time": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "git_sha": git_sha(),
        "host": {"platform": platform.platform(),
                 "python": platform.python_version()},
        "rows": rows,
        **extra,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def csv_rows_to_json(rows: list[tuple]) -> list[dict]:
    """Adapt the (name, us_per_call, derived) CSV tuples to JSON dicts."""
    return [{"name": n, "us_per_call": us, "derived": d} for n, us, d in rows]
