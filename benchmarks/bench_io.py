"""Machine-readable benchmark artifacts.

Every benchmark module keeps its human CSV on stdout and additionally writes
``BENCH_<name>.json`` (to $BENCH_OUT_DIR, default CWD) so the perf trajectory
across PRs can be diffed by tooling instead of parsed out of logs.
"""
from __future__ import annotations

import datetime
import json
import os
import platform
import subprocess
import time


def git_sha() -> str | None:
    """Commit sha of the benchmarked tree ($GITHUB_SHA in CI, else git)."""
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None  # provenance is best-effort; never lose the artifact


def write_bench_json(name: str, rows: list[dict], *, out_dir: str | None = None,
                     **extra) -> str:
    """Write BENCH_<name>.json with `rows` + host metadata; returns the path.

    Every artifact carries provenance (`git_sha`, `iso_time`) so perf
    trajectories across PRs are attributable — `tools/bench_compare.py`
    prints both sides' provenance when diffing. `rows` must be the
    csv-shaped dicts of `csv_rows_to_json` (name/us_per_call/derived) —
    the one shape `tools/bench_compare.py` diffs without special cases;
    benchmark-specific raw measurements ride in `**extra` keys instead.
    `out_dir` (the unified ``--out`` flag) overrides $BENCH_OUT_DIR.
    """
    out_dir = out_dir or os.environ.get("BENCH_OUT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {
        "bench": name,
        "unix_time": int(time.time()),
        "iso_time": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "git_sha": git_sha(),
        "host": {"platform": platform.platform(),
                 "python": platform.python_version()},
        "rows": rows,
        **extra,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def csv_rows_to_json(rows: list[tuple]) -> list[dict]:
    """Adapt the (name, us_per_call, derived) CSV tuples to JSON dicts."""
    return [{"name": n, "us_per_call": us, "derived": d} for n, us, d in rows]


def parse_derived(derived: str) -> dict[str, str]:
    """The ``derived`` string's ``key=value;key=value`` pairs as a dict."""
    out = {}
    for seg in (derived or "").split(";"):
        if not seg:
            continue
        key, eq, value = seg.partition("=")
        if not eq or not key:
            raise ValueError(f"derived segment {seg!r} is not key=value "
                             f"(in {derived!r})")
        out[key] = value
    return out


def check_telemetry_schema(telemetry: dict,
                           required: tuple[str, ...] = ()) -> None:
    """Validate an embedded telemetry snapshot; raises ValueError on drift.

    Benchmarks embed `MetricsRegistry.snapshot()` as the top-level
    ``telemetry`` key of BENCH_*.json (it rides ``**extra`` of
    `write_bench_json` — a sibling of ``rows``, so `check_row_schema`
    never sees it). The snapshot must be a flat dict of dotted lowercase
    ``subsystem.metric`` keys whose values are JSON scalars or plain
    dict/list structures, carrying at least the `required` keys.
    """
    problems = []
    if not isinstance(telemetry, dict):
        raise ValueError(f"telemetry must be a dict, got "
                         f"{type(telemetry).__name__}")
    for key, value in telemetry.items():
        if not isinstance(key, str) or not key or key != key.lower() \
                or "." not in key:
            problems.append(f"key {key!r} is not dotted lowercase "
                            f"subsystem.metric")
        if not isinstance(value, (int, float, str, bool, dict, list,
                                  type(None))):
            problems.append(f"key {key!r}: value {value!r} is not "
                            f"JSON-serializable")
    missing = [k for k in required if k not in telemetry]
    if missing:
        problems.append(f"missing required keys {missing}")
    if problems:
        raise ValueError("telemetry-schema violations:\n  "
                         + "\n  ".join(problems))


def check_row_schema(rows: list[dict], required: tuple[str, ...] = (),
                     *, within: tuple[str, ...] = ()) -> None:
    """Validate the shared csv-row shape; raises ValueError on drift.

    Every row must be exactly ``{name, us_per_call, derived}`` with a
    numeric ``us_per_call`` and a ``;``-joined ``key=value`` derived
    string carrying at least the `required` keys. For each name prefix in
    `within`, all matching rows must expose the SAME derived-key set —
    the guard against one cell of a sweep silently dropping a metric the
    others emit (a row whose sweep mate carries a metric it lacks reads
    as "metric fine here" when it was never measured). Rows that report a
    ``status`` key (failed / skipped cells) are schema-exempt within
    their group: they legitimately carry no measurements.
    """
    problems = []
    for i, row in enumerate(rows):
        if set(row) != {"name", "us_per_call", "derived"}:
            problems.append(f"row {i}: keys {sorted(row)} != "
                            f"['derived', 'name', 'us_per_call']")
            continue
        if not isinstance(row["name"], str) or not row["name"]:
            problems.append(f"row {i}: empty or non-string name")
        if not isinstance(row["us_per_call"], (int, float)):
            problems.append(f"row {i} ({row['name']}): non-numeric "
                            f"us_per_call {row['us_per_call']!r}")
        try:
            keys = parse_derived(row["derived"])
        except ValueError as e:
            problems.append(f"row {i} ({row['name']}): {e}")
            continue
        missing = [k for k in required
                   if k not in keys and "status" not in keys]
        if missing:
            problems.append(f"row {i} ({row['name']}): derived missing "
                            f"required keys {missing}")
    for prefix in within:
        schemas = {}
        for row in rows:
            if not isinstance(row.get("name"), str) \
                    or not row["name"].startswith(prefix):
                continue
            try:
                keys = parse_derived(row.get("derived", ""))
            except ValueError:
                continue  # already reported above
            if "status" in keys:
                continue
            schemas.setdefault(frozenset(keys), []).append(row["name"])
        if len(schemas) > 1:
            variants = " vs ".join(
                f"{sorted(k)} ({names[0]}...)"
                for k, names in sorted(schemas.items(), key=str))
            problems.append(f"group {prefix!r}: inconsistent derived "
                            f"schemas: {variants}")
    if problems:
        raise ValueError("benchmark row-schema violations:\n  "
                         + "\n  ".join(problems))
