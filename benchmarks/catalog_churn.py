"""Catalog churn: serving throughput while the item catalog mutates live.

The frozen-catalog benchmarks measure the engine at rest; production
catalogs are re-embedded, extended, and pruned *while traffic is live*.
This benchmark serves one fixed query stream three ways over a 256k–1M item
catalog (the streaming-NNS operating point):

  * ``frozen``      — the baseline `RecSysEngine`, no delta machinery;
  * ``live_clean``  — the same engine wrapped in `LiveCatalog` with an
                      empty delta shard (the steady post-compaction state:
                      measures the pure overlay overhead);
  * ``live_churn``  — `dirty_frac` of the rows resident in the delta shard
                      and a continuous upsert stream applied between query
                      waves (re-embeds recycling the dirty set, so the
                      shard stays at its operating size).

and then exercises the epoch machinery:

  * compaction pause (the host-side fold; serving swaps epochs atomically
    between buckets, so this is *amortized* — not a serving stall);
  * post-compaction bit-match vs a **cold rebuild** from the final table
    (`rebuild_reference`), asserted over the whole probe stream;
  * block-summary soundness across churn: the incrementally-maintained
    `BlockSummary` must equal a cold `build_block_summary` over the final
    (sigs, mask) bitwise — before AND after compaction — and the
    post-compaction pruned scan must serve the exact bits of a
    prune-disabled engine (asserted, not sampled);
  * an epoch swap under the `AsyncServer` ring at depth `--depth`:
    every query of the stream is asserted to equal exactly the epoch it
    was dispatched against — old epoch before the swap, new epoch after,
    never stale, never mixed (asserted, not sampled).

Acceptance gate: ``live_churn`` sustains >= 0.8x frozen qps at 256k items
with 1% dirty rows. The nightly lane runs the 1M cell.

  PYTHONPATH=src python -m benchmarks.catalog_churn
      [--sizes 262144] [--queries 1024] [--batch 256] [--dirty-frac 0.01]
      [--updates-per-wave 256] [--scan-block 4096] [--wave 256] [--depth 3]
      [--repeats 2] [--out DIR]

``--sizes``/``--repeats``/``--out`` are the flags every serving benchmark
shares (see tools/bench_compare.py); front-ends come from `make_server`.

Variance control mirrors benchmarks/async_serving.py: the Eigen
single-thread XLA flag is defaulted in before jax loads and every qps cell
reports the best of ``--repeats`` measured passes.

Emits BENCH_catalog_churn.json (see benchmarks/bench_io.py).
"""
from __future__ import annotations

import argparse
import time


def _setup(n_items: int, scan_block: int | None, history_len: int = 12,
           hot_rows: int = 256):
    import jax
    import numpy as np

    from repro.data import synthetic
    from repro.models import recsys as rs
    from repro.serving import RecSysEngine

    # user behavior over a small id prefix (synthetic histories are O(U*I));
    # the engine's item table/signature bank is the full `n_items` catalog
    data = synthetic.make_movielens(n_users=2000,
                                    n_items=min(n_items, 4096),
                                    history_len=history_len)
    cfg = rs.YoutubeDNNConfig(
        n_items=n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=history_len)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=50,
                                top_k=10, hot_rows=hot_rows, item_freqs=freqs,
                                scan_block=scan_block)
    return engine, data


def _serve_waves(server, queries, wave, updates=None):
    """Serve `queries` in waves, applying `updates` (a callable) between
    waves; returns (qps, items, n_updates, update_rate)."""
    import numpy as np

    served, n_updates = [], 0
    t0 = time.perf_counter()
    for lo in range(0, len(queries), wave):
        served.extend(server.serve_many(queries[lo: lo + wave]))
        if updates is not None and lo + wave < len(queries):
            n_updates += updates()
    dt = time.perf_counter() - t0
    return (len(queries) / dt, np.stack([s.items for s in served]),
            n_updates, n_updates / dt)


def _assert_stream_equal(got, want, label):
    import numpy as np

    if not (np.asarray(got) == np.asarray(want)).all():
        raise AssertionError(f"{label}: served stream diverged")


def _assert_summary_sound(engine, label):
    """The engine's incrementally-maintained BlockSummary must be bitwise
    identical to a cold rebuild over the same (sigs, tombstone mask) — the
    update_block_summary maintenance contract (docs/KERNELS.md)."""
    import numpy as np

    from repro.core.nns import build_block_summary

    cold = build_block_summary(np.asarray(engine.item_sigs),
                               engine.block_summary.block_rows,
                               db_mask=np.asarray(engine.item_mask))
    for f in ("or_sigs", "and_sigs", "min_pc", "max_pc", "n_alive"):
        if not (np.asarray(getattr(engine.block_summary, f))
                == np.asarray(getattr(cold, f))).all():
            raise AssertionError(
                f"{label}: summary field {f} diverged from cold rebuild")


def rows(items: int, n_queries: int, batch: int, wave: int,
         dirty_frac: float, updates_per_wave: int, scan_block: int | None,
         depth: int, repeats: int = 2):
    import dataclasses

    import numpy as np

    from repro.data.synthetic import serving_queries
    from repro.serving import LiveCatalog, make_server

    def sync_server(eng):
        return make_server(eng, "sync", max_batch=batch, buckets=(batch,))

    engine, data = _setup(items, scan_block)
    rng = np.random.default_rng(0)
    d = engine.cfg.embed_dim
    queries = serving_queries(data, rng.integers(0, data.n_users, n_queries))
    warm = serving_queries(data, rng.integers(0, data.n_users, wave))

    n_dirty = max(1, int(items * dirty_frac))
    dirty_ids = np.sort(rng.choice(items, n_dirty, replace=False))

    out = []

    def best(server, updates=None):
        # best of `repeats` passes (run 1 doubles as warmup on this noisy
        # 2-core host, same policy as benchmarks/async_serving.py)
        return max((_serve_waves(server, queries, wave, updates)
                    for _ in range(max(repeats, 1))), key=lambda r: r[0])

    # -- frozen baseline ------------------------------------------------
    frozen = sync_server(engine)
    frozen.serve_many(warm)  # compile off the clock
    qps_frozen, items_frozen, _, _ = best(frozen)
    out.append((f"serving/churn/frozen_{items}", 1e6 / qps_frozen,
                f"qps={qps_frozen:.0f};items={items}"))

    # -- live, empty delta (steady post-compaction state) ---------------
    cat = LiveCatalog(engine, delta_capacity=n_dirty)
    clean = sync_server(cat.engine)
    cat.attach(clean)
    clean.serve_many(warm)
    qps_clean, items_clean, _, _ = best(clean)
    _assert_stream_equal(items_clean, items_frozen, "live_clean vs frozen")
    out.append((f"serving/churn/live_clean_{items}", 1e6 / qps_clean,
                f"qps={qps_clean:.0f};overhead_vs_frozen="
                f"{qps_clean / qps_frozen:.2f}x"))

    # -- live churn: dirty delta + upserts between waves ----------------
    cat.upsert(dirty_ids, rng.normal(size=(n_dirty, d)).astype(np.float32))
    assert cat.n_pending == n_dirty

    def apply_updates():
        pick = rng.choice(dirty_ids, updates_per_wave)  # recycle dirty set
        cat.upsert(pick, rng.normal(
            size=(updates_per_wave, d)).astype(np.float32))
        return updates_per_wave

    churn = sync_server(cat.engine)
    cat.attach(churn)
    churn.serve_many(warm)
    qps_churn, _, n_up, up_rate = best(churn, apply_updates)
    sustain = qps_churn / qps_frozen
    ok = sustain >= 0.8
    out.append((
        f"serving/churn/live_churn_{items}", 1e6 / qps_churn,
        f"qps={qps_churn:.0f};sustain_vs_frozen={sustain:.2f}x"
        f"(target >=0.8x);ok={ok};dirty_rows={n_dirty};"
        f"upserts={n_up};upserts_per_s={up_rate:.0f}"))
    assert ok, (f"delta path sustained only {sustain:.2f}x of frozen qps "
                f"(target >= 0.8x)")

    # -- the delta path is exact (pre-compaction) -----------------------
    probe = queries[: min(len(queries), 2 * batch)]
    live_out = sync_server(cat.engine).serve_many(probe)
    ref_pre = sync_server(cat.rebuild_reference()).serve_many(probe)
    _assert_stream_equal(np.stack([s.items for s in live_out]),
                         np.stack([s.items for s in ref_pre]),
                         "delta path vs cold rebuild")
    _assert_summary_sound(cat.engine, "pre-compaction, churned")

    # -- compaction: pause + post-fold bit-match vs cold rebuild --------
    pause_s = cat.compact()
    post = sync_server(cat.engine).serve_many(probe)
    ref_post = sync_server(cat.rebuild_reference()).serve_many(probe)
    _assert_stream_equal(np.stack([s.items for s in post]),
                         np.stack([s.items for s in ref_post]),
                         "post-compaction vs cold rebuild")
    _assert_stream_equal(np.stack([s.items for s in post]),
                         np.stack([s.items for s in live_out]),
                         "compaction changed served bits")
    _assert_summary_sound(cat.engine, "post-compaction")
    # the post-compact pruned scan serves the exact unpruned bits
    unpruned = sync_server(dataclasses.replace(
        cat.engine, prune=False)).serve_many(probe)
    _assert_stream_equal(np.stack([s.items for s in post]),
                         np.stack([s.items for s in unpruned]),
                         "post-compaction pruned vs prune-disabled")
    out.append((
        f"serving/churn/compact_{items}", pause_s * 1e6,
        f"pause_ms={pause_s * 1e3:.1f};epoch={cat.epoch};"
        f"bitmatch_cold_rebuild=True;summary_bitmatch_cold=True;"
        f"pruned_eq_unpruned=True"))

    # -- epoch swap under the pipelined ring: never stale, never mixed --
    k = min(updates_per_wave, n_dirty)
    cat.upsert(dirty_ids[:k], rng.normal(size=(k, d)).astype(np.float32))
    old_ref = cat.rebuild_reference()
    pipe = make_server(cat.engine, "pipelined", max_batch=batch,
                       buckets=(batch,), depth=depth)
    cat.attach(pipe)
    pipe.serve_many(warm)
    tickets = [pipe.submit(q) for q in queries]
    n_pre = 0
    while pipe.in_flight < min(depth - 1, 1) or n_pre == 0:
        pipe._ring.append(pipe._dispatch(pipe._take_parts()))
        n_pre += batch
        if n_pre >= len(queries):
            break
    cat.compact()  # swaps the epoch under the loaded ring
    new_ref = cat.rebuild_reference()
    pipe.flush()
    got = np.stack([pipe.result(t).items for t in tickets])
    want_old = np.stack([s.items for s in
                         sync_server(old_ref).serve_many(queries)])
    want_new = np.stack([s.items for s in
                         sync_server(new_ref).serve_many(queries)])
    _assert_stream_equal(got[:n_pre], want_old[:n_pre],
                         "pre-swap buckets must serve the old epoch")
    _assert_stream_equal(got[n_pre:], want_new[n_pre:],
                         "post-swap buckets must serve the new epoch")
    out.append((
        f"serving/churn/epoch_swap_{items}", 0.0,
        f"depth={depth};buckets_old_epoch={n_pre // batch};"
        f"buckets_new_epoch={(len(queries) - n_pre) // batch};"
        f"stale_or_mixed=False(asserted over all {len(queries)} queries)"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated catalog sizes (unified flag; "
                         "default: --items)")
    ap.add_argument("--items", type=int, default=262_144,
                    help="catalog rows (256k default; nightly runs 1M; "
                         "--sizes wins when both are given)")
    ap.add_argument("--queries", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--wave", type=int, default=256,
                    help="queries per serve_many call (updates land "
                         "between waves)")
    ap.add_argument("--dirty-frac", type=float, default=0.01,
                    help="fraction of rows resident in the delta shard")
    ap.add_argument("--updates-per-wave", type=int, default=256)
    ap.add_argument("--scan-block", type=int, default=4096,
                    help="engine scan_block (streaming plan); 0 = dense")
    ap.add_argument("--depth", type=int, default=3,
                    help="AsyncServer ring depth for the epoch-swap phase")
    ap.add_argument("--repeats", type=int, default=2,
                    help="measured passes per qps cell (first doubles as "
                         "warmup; best pass reported)")
    ap.add_argument("--out", type=str, default=None,
                    help="artifact directory (default $BENCH_OUT_DIR or .)")
    args = ap.parse_args()
    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else (args.items,))

    from benchmarks.async_serving import _default_xla_cpu_flags

    _default_xla_cpu_flags()  # must precede the first jax import

    from benchmarks.bench_io import csv_rows_to_json, write_bench_json

    out = []
    for n_items in sizes:
        out.extend(rows(n_items, args.queries, args.batch, args.wave,
                        args.dirty_frac, args.updates_per_wave,
                        args.scan_block, args.depth, args.repeats))
    for name, us, derived in out:
        print(f"{name},{us:.6f},{derived}")
    path = write_bench_json(
        "catalog_churn", csv_rows_to_json(out), out_dir=args.out,
        config={"sizes": sizes, "queries": args.queries,
                "batch": args.batch, "wave": args.wave,
                "dirty_frac": args.dirty_frac,
                "updates_per_wave": args.updates_per_wave,
                "scan_block": args.scan_block, "depth": args.depth,
                "repeats": args.repeats})
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
