"""Paper Sec. IV-C3: end-to-end latency/energy — 16.8x / 713x on MovieLens,
13.2x / 57.8x on Criteo — composed from the calibrated cost model, plus a
measured software-path throughput of the actual JAX pipeline on this host
(labeled as such; this container is CPU, not the paper's RTX 1080)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm


def rows(measure_software: bool = True):
    out = []
    ml = cm.end_to_end_movielens()
    out.append((
        "end_to_end/movielens/imars", ml["imars_latency_us"],
        f"qps={ml['imars_qps']:.0f}(paper 22025);"
        f"latency_x={ml['latency_speedup']:.2f}(paper 16.8);"
        f"energy_x={ml['energy_reduction']:.1f}(paper 713)",
    ))
    out.append((
        "end_to_end/movielens/gpu_paper", ml["gpu_latency_us"],
        f"qps={ml['gpu_qps']:.0f}(paper 1311)",
    ))
    cr = cm.end_to_end_criteo()
    out.append((
        "end_to_end/criteo/imars", cr["imars_latency_us"],
        f"latency_x={cr['latency_speedup']:.2f}(paper 13.2);"
        f"energy_x={cr['energy_reduction']:.1f}(paper 57.8)",
    ))

    if measure_software:
        from repro.data import synthetic
        from repro.models import recsys as rs
        from repro.serving import CacheStats, RecSysEngine, serve_step

        data = synthetic.make_movielens(n_users=500, n_items=300,
                                        history_len=8)
        cfg = rs.YoutubeDNNConfig(
            n_items=data.n_items,
            user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                           "occupation": 21, "zip_bucket": 250},
            history_len=8)
        params = rs.init_youtubednn(jax.random.key(0), cfg)
        freqs = np.bincount(data.histories[data.histories >= 0],
                            minlength=data.n_items)
        engine = RecSysEngine.build(params, cfg, radius=112,
                                    n_candidates=50, top_k=10,
                                    hot_rows=64, item_freqs=freqs)
        rng = np.random.default_rng(0)
        idx = rng.integers(0, data.n_users, 64)
        batch = {
            **{k: jnp.asarray(v[idx]) for k, v in data.user_feats.items()},
            "history": jnp.asarray(data.histories[idx]),
            "genre": jnp.asarray(data.genres[idx]),
        }
        stats = CacheStats.zero()
        r = serve_step(engine, batch, stats)  # compile
        stats = jax.block_until_ready(r)[3]
        t0 = time.perf_counter()
        n = 10
        for _ in range(n):
            r = serve_step(engine, batch, stats)
            stats = r[3]
        jax.block_until_ready(r)
        dt = time.perf_counter() - t0
        per_query_us = dt / (n * 64) * 1e6
        out.append((
            "end_to_end/movielens/software_cpu", per_query_us,
            f"qps={1e6/per_query_us:.0f};hot_hit_rate={stats.hit_rate():.3f};"
            f"host=CPU(container, not GPU)",
        ))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.6f},{derived}")


if __name__ == "__main__":
    main()
