"""Kernel microbenchmarks: wall time of the reference paths on this host
(CPU) + interpret-mode parity checks. On TPU the same harness times the
Pallas kernels (kernels/ops.py dispatch)."""
import time

import jax
import jax.numpy as jnp

from repro.core.quantization import quantize_rowwise
from repro.kernels import ops


def _time(fn, *args, n=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def rows():
    key = jax.random.key(0)
    out = []

    table = quantize_rowwise(jax.random.normal(key, (30000, 128)))
    ids = jax.random.randint(jax.random.key(1), (256, 20), 0, 30000)
    f = jax.jit(lambda tv, ts, i: ops.embedding_pool(tv, ts, i))
    us = _time(f, table.values, table.scales, ids)
    out.append(("kernel/embedding_pool_30kx128_b256", us,
                "fused int8 dequant-gather-pool (ref path on CPU)"))

    db = jax.random.randint(jax.random.key(2), (30000, 8), 0, 2**31 - 1
                            ).astype(jnp.uint32)
    q = db[:64]
    f2 = jax.jit(ops.hamming_distances)
    us = _time(f2, q, db)
    out.append(("kernel/hamming_64x30000x256b", us,
                "XOR+popcount sweep (TCAM analogue)"))

    x = jax.random.randint(jax.random.key(3), (256, 512), -127, 128
                           ).astype(jnp.int8)
    w = jax.random.randint(jax.random.key(4), (512, 512), -127, 128
                           ).astype(jnp.int8)
    sx = jnp.ones((256, 1)); sw = jnp.ones((1, 512))
    f3 = jax.jit(ops.int8_matmul)
    us = _time(f3, x, w, sx, sw)
    out.append(("kernel/int8_matmul_256x512x512", us,
                "crossbar MVM analogue (int32 accumulate)"))

    qq = jax.random.normal(key, (4, 8, 512, 64), jnp.bfloat16)
    f4 = jax.jit(lambda a: ops.flash_attention(a, a, a, causal=True))
    us = _time(f4, qq)
    out.append(("kernel/attention_4x8x512x64", us,
                "blocked online-softmax attention"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
