"""Offered-load sweep: p50/p99 latency per tenant + qps at a fixed p99 SLO.

Peak qps (benchmarks/serving_throughput.py, async_serving.py) is a
closed-loop number: the client stops offering load while the server
works. The number a datacenter actually provisions against is open-loop —
"at what offered rate does the p99 still meet the SLO?" — because beyond
saturation an open-loop queue grows without bound and p99 collapses
first. This benchmark drives the concurrent multi-tenant front-end
(`make_server(engine, mode="concurrent")`: bounded per-tenant queues +
admission control + load shedding over the `AsyncServer` ring) with the
open-loop generator (`serving/load_gen.py`: Poisson arrivals, Zipf query
popularity, optional bursty phases) and reports, per catalog size:

  * measured closed-loop capacity (the load scale's 1.0x anchor);
  * per-load-fraction, per-tenant p50/p99 latency, achieved goodput, and
    shed fraction — the latency-vs-offered-load curve;
  * ``qps_at_slo`` — the largest achieved goodput among loads whose
    admitted p99 meets the SLO: the provisioning number;
  * the overload contract at the top load (>= 2x capacity): the
    front-end **sheds** (rejects are accounted per tenant, errors are
    zero, every submit is accounted), admitted p99 stays **bounded** (by
    queue depth / capacity — not by the offered rate), and admitted
    results **bit-match** synchronous serving of the same stream
    (asserted here; shedding moves admission, never the bits).

  PYTHONPATH=src python -m benchmarks.load_sweep
      [--sizes 16384] [--batch 64] [--tenants 4] [--queue-depth N]
      [--duration 4.0] [--loads 0.25,0.5,0.75,1.0,1.5,2.0]
      [--slo-ms MS] [--zipf-a 1.1] [--burst PERIOD,DUTY,MULT]
      [--pool 512] [--depth 2] [--repeats 2] [--out DIR] [--smoke]

``--smoke`` is the CI fast-lane preset: 2 tenants, a ~2-second 2-point
sweep (0.6x and 2.5x) on a small dense-plan catalog, fixed seed. The
nightly lane runs the full sweep and uploads the artifact. Variance
control mirrors benchmarks/async_serving.py (Eigen single-thread XLA
flag, best-of-``--repeats`` by lowest p99). Emits BENCH_load_sweep.json.
"""
from __future__ import annotations

import argparse
import sys
import time

DEFAULT_LOADS = (0.25, 0.5, 0.75, 1.0, 1.5, 2.0)
SMOKE = dict(sizes=(4096,), loads=(0.6, 2.5), duration=1.2, tenants=2,
             batch=32, pool=256, repeats=1, scan_block=0)


def _measure_capacity(engine, pool, batch: int, repeats: int) -> float:
    """Closed-loop qps through the synchronous front-end (the 1.0x anchor)."""
    from repro.serving import make_server

    n = max(4 * batch, 256)
    queries = [pool[i % len(pool)] for i in range(n)]
    server = make_server(engine, "sync", max_batch=batch, buckets=(batch,))
    server.serve_many(queries[:batch])  # compile off the clock
    best = 0.0
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        server.serve_many(queries)
        best = max(best, n / (time.perf_counter() - t0))
    return best


def _run_load(engine, pool, *, rate, duration, tenants, queue_depth, batch,
              depth, zipf_a, burst, seed, repeats):
    """One open-loop cell; returns (summary, replay, results, telemetry
    snapshot) of the best (lowest admitted p99) of `repeats` passes."""
    from repro.serving import LoadGen, make_server, summarize_trace

    best = None
    for rep in range(max(repeats, 1)):
        server = make_server(engine, "concurrent", tenants=tenants,
                             queue_depth=queue_depth, max_batch=batch,
                             buckets=(batch,), depth=depth)
        # compile / warm every tenant path off the clock, then clear trace
        for t in range(tenants):
            server.serve_many(pool[:batch], tenant=t)
        server.take_trace()
        gen = LoadGen(rate_qps=rate, duration_s=duration, tenants=tenants,
                      pool_size=len(pool), zipf_a=zipf_a, burst=burst,
                      seed=seed)  # same seed every pass: identical offers
        replay = gen.replay(server, pool)
        server.flush()
        trace = server.take_trace()
        results = {t: server.result(t) for (t, _, _) in replay}
        snap = server.snapshot()
        server.close()
        summary = summarize_trace(trace, duration)
        key = summary.p99_ms if summary.p99_ms == summary.p99_ms else 1e12
        if best is None or key < best[0]:
            best = (key, summary, replay, results, snap)
    return best[1], best[2], best[3], best[4]


def _assert_bitmatch(engine, pool, replay, results, batch: int) -> int:
    """Admitted results == synchronous serving of the admitted stream."""
    import numpy as np

    from repro.serving import make_server

    admitted = [(qi, results[t]) for (t, _, qi) in replay
                if results[t].status == "ok"]
    if not admitted:
        return 0
    ref = make_server(engine, "sync", max_batch=batch,
                      buckets=(batch,)).serve_many(
                          [pool[qi] for qi, _ in admitted])
    for (qi, got), want in zip(admitted, ref):
        if not (np.array_equal(got.items, want.items)
                and np.array_equal(got.scores, want.scores)):
            raise AssertionError(
                f"admitted query (pool index {qi}) diverged from "
                f"synchronous serving")
    return len(admitted)


def rows(args):
    import numpy as np  # noqa: F401  (summaries carry numpy scalars)

    from benchmarks.async_serving import _setup
    from repro.data.synthetic import serving_queries

    out = []
    telemetry = None
    for n_items in args.sizes:
        engine, data = _setup(n_items, args.scan_block or None)
        rng_pool = min(args.pool, data.n_users)
        pool = serving_queries(data, range(rng_pool))
        cap = _measure_capacity(engine, pool, args.batch, args.repeats)
        slo_ms = args.slo_ms or max(25.0, 8e3 * args.batch / cap)
        # queue sized so a full queue drains in ~one SLO: the structural
        # bound on admitted latency under any overload
        queue_depth = args.queue_depth or max(
            args.batch, int(cap * slo_ms / 1e3 / args.tenants))
        bound_ms = 3e3 * queue_depth * args.tenants / cap + 3 * slo_ms
        out.append((f"load_sweep/capacity_n{n_items}", 1e6 / cap,
                    f"qps={cap:.0f};batch={args.batch};closed_loop=True"))

        qps_at_slo, sweep = 0.0, []
        for i, frac in enumerate(args.loads):
            summary, replay, results, telemetry = _run_load(
                engine, pool, rate=frac * cap, duration=args.duration,
                tenants=args.tenants, queue_depth=queue_depth,
                batch=args.batch, depth=args.depth, zipf_a=args.zipf_a,
                burst=args.burst, seed=args.seed + i, repeats=args.repeats)
            sweep.append((frac, summary, replay, results))
            meets = summary.p99_ms <= slo_ms
            if meets:
                qps_at_slo = max(qps_at_slo, summary.achieved_qps)
            out.append((
                f"load_sweep/load{frac:g}x_n{n_items}",
                summary.p99_ms * 1e3,
                f"p50_ms={summary.p50_ms:.1f};p99_ms={summary.p99_ms:.1f};"
                f"offered_qps={summary.offered_qps:.0f};"
                f"achieved_qps={summary.achieved_qps:.0f};"
                f"shed_frac={summary.shed_frac:.3f};meets_slo={meets}"))
            for t, s in summary.per_tenant.items():
                out.append((
                    f"load_sweep/load{frac:g}x_n{n_items}/tenant{t}", 0.0,
                    f"p50_ms={s['p50_ms']:.1f};p99_ms={s['p99_ms']:.1f};"
                    f"offered_qps={s['offered_qps']:.0f};"
                    f"achieved_qps={s['achieved_qps']:.0f};"
                    f"shed_frac={s['shed_frac']:.3f}"))

        out.append((
            f"load_sweep/qps_at_slo_n{n_items}", 0.0,
            f"qps_at_slo={qps_at_slo:.0f};slo_ms={slo_ms:.1f};"
            f"capacity_qps={cap:.0f};ok={qps_at_slo > 0}"))

        # ---- overload contract at the top load ------------------------
        frac, summary, replay, results = sweep[-1]
        n_matched = _assert_bitmatch(engine, pool, replay, results,
                                     args.batch)
        per_t = summary.per_tenant.values()
        accounted = all(s["n_ok"] + s["n_shed"] + s["n_errors"] ==
                        round(s["offered_qps"] * summary.duration_s)
                        for s in per_t)
        shed_ok = (summary.shed_frac > 0) if frac >= 1.5 else True
        bounded = summary.p99_ms <= bound_ms
        ok = accounted and shed_ok and bounded and summary.error_frac == 0
        out.append((
            f"load_sweep/overload{frac:g}x_n{n_items}", 0.0,
            f"shed_frac={summary.shed_frac:.3f};p99_ms={summary.p99_ms:.1f};"
            f"bound_ms={bound_ms:.1f};errors={summary.error_frac:.3f};"
            f"accounted={accounted};bitmatch_sync=True(n={n_matched});"
            f"ok={ok}"))
        if not ok:
            raise AssertionError(
                f"overload contract violated at {frac}x (n={n_items}): "
                f"shed_frac={summary.shed_frac:.3f}, "
                f"p99={summary.p99_ms:.1f}ms (bound {bound_ms:.1f}ms), "
                f"errors={summary.error_frac:.3f}, accounted={accounted}")
        # the low-load end must bit-match too (shed-free path)
        _assert_bitmatch(engine, pool, sweep[0][2], sweep[0][3], args.batch)
    return out, telemetry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default="16384",
                    help="comma-separated catalog sizes (unified flag)")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--queue-depth", type=int, default=None,
                    help="per-tenant queue bound (default: sized so a "
                         "full queue drains in ~one SLO)")
    ap.add_argument("--duration", type=float, default=4.0,
                    help="open-loop seconds per load point")
    ap.add_argument("--loads", type=str,
                    default=",".join(str(f) for f in DEFAULT_LOADS),
                    help="offered load as fractions of measured capacity")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="p99 SLO (default: 8 batch-times, min 25ms)")
    ap.add_argument("--zipf-a", type=float, default=1.1)
    ap.add_argument("--burst", type=str, default=None,
                    help="PERIOD_S,DUTY_FRAC,MULT bursty-phase spec")
    ap.add_argument("--pool", type=int, default=512,
                    help="distinct queries in the Zipf pool")
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--scan-block", type=int, default=4096,
                    help="engine scan_block (streaming plan); 0 = dense")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=2,
                    help="passes per load point (best = lowest p99)")
    ap.add_argument("--out", type=str, default=None,
                    help="artifact directory (default $BENCH_OUT_DIR or .)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast-lane preset: 2 tenants, ~2s, 2 loads")
    args = ap.parse_args()

    from benchmarks.async_serving import _default_xla_cpu_flags

    _default_xla_cpu_flags()  # must precede the first jax import

    if args.smoke:
        args.sizes, args.loads = SMOKE["sizes"], SMOKE["loads"]
        args.duration, args.tenants = SMOKE["duration"], SMOKE["tenants"]
        args.batch, args.pool = SMOKE["batch"], SMOKE["pool"]
        args.repeats, args.scan_block = SMOKE["repeats"], SMOKE["scan_block"]
    else:
        args.sizes = tuple(int(s) for s in args.sizes.split(","))
        args.loads = tuple(float(f) for f in args.loads.split(","))
    if isinstance(args.burst, str):
        p, d, m = args.burst.split(",")
        args.burst = (float(p), float(d), float(m))

    from benchmarks.bench_io import (check_telemetry_schema,
                                     csv_rows_to_json, write_bench_json)

    out, telemetry = rows(args)
    for name, us, derived in out:
        print(f"{name},{us:.3f},{derived}")
    check_telemetry_schema(telemetry, required=("serving.submitted",
                                                "serving.per_tenant"))
    path = write_bench_json(
        "load_sweep", csv_rows_to_json(out), out_dir=args.out,
        config={"sizes": args.sizes, "batch": args.batch,
                "tenants": args.tenants, "queue_depth": args.queue_depth,
                "duration": args.duration, "loads": args.loads,
                "slo_ms": args.slo_ms, "zipf_a": args.zipf_a,
                "burst": args.burst, "pool": args.pool,
                "depth": args.depth, "scan_block": args.scan_block,
                "seed": args.seed, "repeats": args.repeats,
                "smoke": args.smoke},
        telemetry=telemetry)
    print(f"# wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
