"""Filtering-stage scaling: dense vs streaming fixed-radius NNS.

The iMARS filtering stage scans the *entire* item signature bank per query.
The dense software path materializes a (q, n) int32 distance matrix — at the
million-item north star that is gigabytes per batch and the capacity wall of
the pipeline. The streaming path (`scan_block`) holds O(q * max_candidates)
instead. This benchmark sweeps catalog size and records, per path:

  * queries/sec through the jitted `fixed_radius_nns`
  * peak incremental RSS during the scan (compile + steady state)
  * a bit-match check of streaming vs dense where both run

Each (size, path) cell runs in a *fresh subprocess* so `ru_maxrss` deltas
are real per-cell peaks, not shadows of an earlier phase's high-water mark
(the dense top-k at 65k items already pushes ~0.5 GiB of sort workspace).
Dense is skipped (OOM guard) once its distance matrix alone would exceed
DENSE_MAX_BYTES; the streaming path must hold >= 1M items on CPU with peak
incremental memory under 10% of the dense matrix it replaces.

  PYTHONPATH=src python -m benchmarks.nns_scale [--full]

Emits BENCH_nns_scale.json (see benchmarks/bench_io.py).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

SIZES = (65_536, 262_144, 1_048_576)
FULL_SIZES = SIZES + (4_194_304,)
Q = 128  # concurrent queries per scan (one serving micro-batch)
WORDS = 8  # 256-bit signatures
RADIUS = 96
MAX_CANDIDATES = 128
SCAN_BLOCK = 4096
DENSE_MAX_BYTES = 1 << 28  # skip dense when (q, n) int32 alone exceeds 256 MiB
REPS = 2


def _cell(n: int, path: str) -> dict:
    """One measurement in this process: build arrays, scan, report JSON."""
    import gc
    import resource
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.nns import fixed_radius_nns

    rng = np.random.default_rng(0)
    queries = jnp.asarray(
        rng.integers(0, 2**32, size=(Q, WORDS), dtype=np.uint32))
    db = jnp.asarray(
        rng.integers(0, 2**32, size=(n, WORDS), dtype=np.uint32))
    jax.block_until_ready(db)
    scan_block = SCAN_BLOCK if path == "streaming" else 0

    def fn(q):
        return fixed_radius_nns(q, db, RADIUS, MAX_CANDIDATES,
                                scan_block=scan_block)

    gc.collect()
    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
    t0 = time.perf_counter()
    res = fn(queries)
    jax.block_until_ready(res)  # compile + first scan
    t1 = time.perf_counter()
    for _ in range(REPS):
        res = fn(queries)
    jax.block_until_ready(res)
    steady = (time.perf_counter() - t1) / REPS
    rss_delta = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024 - rss0

    row = {"n": n, "q": Q, "path": path, "status": "ok",
           "qps": Q / steady, "us_per_query": 1e6 * steady / Q,
           "compile_and_first_s": t1 - t0,
           "rss_peak_delta_bytes": int(rss_delta),
           "dense_matrix_bytes": Q * n * 4,
           "scan_block": scan_block}
    if path == "streaming":
        row["mem_lt_10pct_dense"] = bool(rss_delta < 0.1 * Q * n * 4)
    else:
        # bit-match check on a query slice while the db is resident
        d = fixed_radius_nns(queries[:8], db, RADIUS, MAX_CANDIDATES,
                             scan_block=0)
        s = fixed_radius_nns(queries[:8], db, RADIUS, MAX_CANDIDATES,
                             scan_block=SCAN_BLOCK)
        row["bitmatch_streaming"] = all(
            bool(jnp.array_equal(a, b)) for a, b in zip(d, s))
    return row


def _spawn_cell(n: int, path: str) -> dict:
    """Run one cell in a fresh interpreter; returns its JSON row.

    A crashed cell (e.g. the dense path OOM-killed on a small host — the
    failure mode this benchmark probes) is reported as a status=failed row
    with its stderr tail, so the sweep continues and still emits the
    artifact."""
    env = dict(os.environ)
    # the bare container env hangs on TPU plugin init; pin the parent backend
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.nns_scale",
         "--cell", str(n), path],
        env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        tail = proc.stderr.strip().splitlines()[-3:]
        print(f"# cell n={n} path={path} failed "
              f"(rc={proc.returncode}): {' | '.join(tail)}", file=sys.stderr)
        return {"n": n, "q": Q, "path": path, "status": "failed",
                "returncode": proc.returncode,
                "stderr_tail": tail, "dense_matrix_bytes": Q * n * 4}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def rows(sizes=SIZES):
    out, json_rows = [], []
    for n in sizes:
        row = _spawn_cell(n, "streaming")
        json_rows.append(row)
        if row["status"] != "ok":
            out.append((f"nns_scale/streaming/n{n}", 0.0, "status=failed"))
        else:
            out.append((
                f"nns_scale/streaming/n{n}", row["us_per_query"],
                f"qps={row['qps']:.1f};"
                f"rss_delta={row['rss_peak_delta_bytes']};"
                f"dense_bytes={row['dense_matrix_bytes']};"
                f"mem_lt_10pct_dense={row['mem_lt_10pct_dense']}",
            ))
        if Q * n * 4 <= DENSE_MAX_BYTES:
            row = _spawn_cell(n, "dense")
            json_rows.append(row)
            if row["status"] != "ok":
                out.append((f"nns_scale/dense/n{n}", 0.0, "status=failed"))
            else:
                out.append((
                    f"nns_scale/dense/n{n}", row["us_per_query"],
                    f"qps={row['qps']:.1f};"
                    f"rss_delta={row['rss_peak_delta_bytes']};"
                    f"bitmatch={row['bitmatch_streaming']}",
                ))
        else:
            json_rows.append({"n": n, "q": Q, "path": "dense",
                              "status": "skipped_oom_guard",
                              "dense_matrix_bytes": Q * n * 4})
            out.append((
                f"nns_scale/dense/n{n}", 0.0,
                f"status=skipped_oom_guard;dense_bytes={Q * n * 4}"))
    return out, json_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="extend the sweep to 4M items")
    ap.add_argument("--cell", nargs=2, metavar=("N", "PATH"),
                    help="internal: run one measurement and print JSON")
    args = ap.parse_args()
    if args.cell:
        print(json.dumps(_cell(int(args.cell[0]), args.cell[1])))
        return

    from benchmarks.bench_io import write_bench_json

    out, json_rows = rows(FULL_SIZES if args.full else SIZES)
    for name, us, derived in out:
        print(f"{name},{us:.3f},{derived}")
    path = write_bench_json(
        "nns_scale", json_rows,
        config={"radius": RADIUS, "max_candidates": MAX_CANDIDATES,
                "words": WORDS, "scan_block": SCAN_BLOCK, "q": Q,
                "dense_max_bytes": DENSE_MAX_BYTES, "reps": REPS})
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
