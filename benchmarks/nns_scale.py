"""Filtering-stage scaling: dense vs streaming fixed-radius NNS.

The iMARS filtering stage scans the *entire* item signature bank per query.
The dense software path materializes a (q, n) int32 distance matrix — at the
million-item north star that is gigabytes per batch and the capacity wall of
the pipeline. The streaming path (`scan_block`) holds O(q * max_candidates)
instead, and past the 4.19M-row packed-key capacity it scans as offset
superblocks (wide keys) — the 8M/16M cells in the --full sweep exercise that
wide path end to end. This benchmark sweeps catalog size and records, per
path:

  * queries/sec through the jitted `fixed_radius_nns`
  * peak incremental RSS during the scan (compile + steady state)
  * a bit-match check against the dense oracle on a query slice, on every
    cell where the dense matrix for that slice fits in RAM (including the
    8M/16M wide-key cells)

Paths: `streaming` (single device), `dense` (until the OOM guard),
`streaming_qp2` at >= 1M items — the streaming scan shard_mapped over a
2-way query mesh axis (2 fake CPU devices), the query-block-parallel knob —
and the Zipf-skewed pair `zipf_stream` / `zipf_pruned`: the same streaming
scan over a clustered catalog with Zipf cluster sizes and Zipf query
popularity (the workload shape block-summary pruning targets), without and
with the `BlockSummary` prune mask. The pruned cell records
`blocks_touched` / `scan_frac` (per-query mean fraction of summary blocks
admitted), bit-matches the unpruned scan on the full batch in-cell, and
its row carries `speedup_vs_unpruned` against the zipf_stream cell at the
same size — `--assert-scan-frac` turns the scan_frac < ceiling, bit-match,
and >= 1.2x speedup (at >= 1M rows) checks into hard exit codes for the
nightly lane.

Each (size, path) cell runs in a *fresh subprocess* so `ru_maxrss` deltas
are real per-cell peaks, not shadows of an earlier phase's high-water mark
(the dense top-k at 65k items already pushes ~0.5 GiB of sort workspace).
Dense is skipped (OOM guard) once its distance matrix alone would exceed
DENSE_MAX_BYTES; the streaming path must hold >= 1M items on CPU with peak
incremental memory under 10% of the dense matrix it replaces.

  PYTHONPATH=src python -m benchmarks.nns_scale [--full] [--sizes N,N,...]
      [--repeats 2] [--out DIR] [--assert-stream-mem BYTES]

``--sizes``/``--repeats``/``--out`` are the flags every benchmark shares;
the artifact's ``rows`` are the same csv-shaped dicts every benchmark
emits (so tools/bench_compare.py diffs any pair without special cases)
and the raw per-cell measurements ride in the ``cells`` key.

`--assert-stream-mem` exits non-zero if any streaming cell fails its memory
contract (the nightly CI lane runs the 8M cell under a hard RSS budget).
Emits BENCH_nns_scale.json (see benchmarks/bench_io.py).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

SIZES = (65_536, 262_144, 1_048_576)
FULL_SIZES = SIZES + (4_194_304, 8_388_608, 16_777_216)
Q = 128  # concurrent queries per scan (one serving micro-batch)
Q_ORACLE = 2  # query slice for the dense bit-match check on big cells
WORDS = 8  # 256-bit signatures
RADIUS = 96
MAX_CANDIDATES = 128
SCAN_BLOCK = 4096
DENSE_MAX_BYTES = 1 << 28  # skip dense when (q, n) int32 alone exceeds 256 MiB
REPS = 2  # default --repeats (steady-state scans averaged per cell)

# Zipf-skewed cells: clustered catalog (Zipf cluster sizes, Zipf query
# popularity) with intra-cluster noise confined to a few designated bit
# positions, so block OR/AND summaries stay tight — the layout pruning is
# built for. Radius admits a whole cluster (queries sit <= 5 flips from
# their center) while cross-cluster distances concentrate near 128.
# Query popularity runs ANTI-aligned with cluster size — the recsys hot-set
# shape: a compact set of hot clusters takes most of the traffic while the
# bulky legacy clusters go cold. That anti-alignment is what makes the
# batch-level prune union sublinear; popularity aligned with mass would
# re-touch most of the catalog every batch no matter how sound the bound.
ZIPF_CLUSTERS = 128
ZIPF_EXPONENT = 1.2
ZIPF_FLIP_POSITIONS = 24
ZIPF_RADIUS = 40
PRUNE_MIN_SPEEDUP = 1.2  # zipf_pruned vs zipf_stream qps, >= 1M rows


def scan_block_for(n: int) -> int:
    """Scan chunk: 4096 up to 1M rows (the PR-2 operating point), ramping to
    32k at 16M so per-chunk dispatch overhead stays off the critical path."""
    return min(32_768, max(SCAN_BLOCK, n // 512))


def _zipf_catalog(n: int, rng):
    """Clustered catalog + query batch with Zipf skew (see module docstring).

    Rows are grouped by cluster (contiguous runs of similar signatures)
    and every row/query differs from its cluster center only at the
    cluster's `ZIPF_FLIP_POSITIONS` designated bit positions — random
    flips over all 256 positions would saturate the block OR and the
    summary could never prune. Cluster boundaries align to this size's
    scan chunk (a multiple of the 4096-row summary block) so summary
    blocks stay single-cluster and the ref backend's chunk-granular skip
    maps 1:1 onto clusters. Query popularity is Zipf over clusters in
    REVERSE size order (see the constant block comment)."""
    import numpy as np

    c = ZIPF_CLUSTERS
    unit = scan_block_for(n)  # cluster-run granularity, multiple of 4096
    n_units = max((n + unit - 1) // unit, c)
    w = np.arange(1, c + 1, dtype=np.float64) ** -ZIPF_EXPONENT
    w /= w.sum()
    units = 1 + np.floor(w * (n_units - c)).astype(np.int64)
    units[0] += n_units - units.sum()
    centers = rng.integers(0, 2**32, size=(c, WORDS), dtype=np.uint32)
    pos = rng.integers(0, 32 * WORDS, size=(c, ZIPF_FLIP_POSITIONS))
    cluster = np.repeat(np.arange(c), units * unit)[:n]

    def perturb(owner, n_flips):
        out = centers[owner].copy()
        m = np.empty((owner.shape[0], WORDS), np.uint32)
        for _ in range(n_flips):
            p = pos[owner, rng.integers(0, ZIPF_FLIP_POSITIONS,
                                        size=owner.shape[0])]
            m[:] = 0
            m[np.arange(owner.shape[0]), p // 32] = (
                np.uint32(1) << (p % 32).astype(np.uint32))
            out ^= m
        return out

    db = perturb(cluster, 3)
    # hot queries hit the compact clusters: popularity w reversed over size
    q_cluster = rng.choice(c, size=Q, p=w[::-1])
    queries = perturb(q_cluster, 2)
    return queries, db


def _cell(n: int, path: str) -> dict:
    """One measurement in this process: build arrays, scan, report JSON."""
    if path == "streaming_qp2":  # before jax import: 2 fake CPU devices
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=2")

    import gc
    import resource
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.nns import (
        build_block_summary,
        fixed_radius_nns,
        query_parallel_nns,
    )

    rng = np.random.default_rng(0)
    zipf = path.startswith("zipf")
    radius = ZIPF_RADIUS if zipf else RADIUS
    if zipf:
        queries_np, db_np = _zipf_catalog(n, rng)
    else:
        queries_np = rng.integers(0, 2**32, size=(Q, WORDS), dtype=np.uint32)
        db_np = rng.integers(0, 2**32, size=(n, WORDS), dtype=np.uint32)
    summary = build_block_summary(db_np) if path == "zipf_pruned" else None
    queries = jnp.asarray(queries_np)
    db = jnp.asarray(db_np)
    del db_np
    jax.block_until_ready(db)
    scan_block = scan_block_for(n) if path != "dense" else 0

    if path == "streaming_qp2":
        mesh = jax.make_mesh((2,), ("qp",))

        def fn(q):
            return query_parallel_nns(mesh, "qp", q, db, RADIUS,
                                      MAX_CANDIDATES, scan_block=scan_block)
    else:
        # summary=None on the unpruned paths: the prune-mask computation is
        # part of the pruned scan, so it sits inside the timed fn
        def fn(q):
            return fixed_radius_nns(q, db, radius, MAX_CANDIDATES,
                                    scan_block=scan_block, summary=summary)

    gc.collect()
    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
    t0 = time.perf_counter()
    res = fn(queries)
    jax.block_until_ready(res)  # compile + first scan
    reps = int(os.environ.get("NNS_SCALE_REPS", REPS))
    t1 = time.perf_counter()
    for _ in range(reps):
        res = fn(queries)
    jax.block_until_ready(res)
    steady = (time.perf_counter() - t1) / reps
    rss_delta = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024 - rss0

    row = {"n": n, "q": Q, "path": path, "status": "ok",
           "qps": Q / steady, "us_per_query": 1e6 * steady / Q,
           "compile_and_first_s": t1 - t0,
           "rss_peak_delta_bytes": int(rss_delta),
           "dense_matrix_bytes": Q * n * 4,
           "scan_block": scan_block}
    if path in ("streaming", "zipf_stream", "zipf_pruned"):
        # every single-device streaming-family cell carries the memory
        # metric (this used to be the plain `streaming` row only, so the
        # zipf cells' rows pattern-matched as "memory fine" when it was
        # never measured — check_row_schema now pins the per-group schema).
        # The qp2 cells stay excluded: they replicate the catalog once per
        # fake device in-process, so 10%-of-dense would be meaningless
        # noise for them
        row["mem_lt_10pct_dense"] = bool(rss_delta < 0.1 * Q * n * 4)
    if path == "zipf_pruned":
        # scan_frac: per-query mean fraction of summary blocks the bound
        # admitted — the sublinearity headline. Pruned results must carry
        # exactly the unpruned scan's bits on the FULL batch (in-benchmark
        # assertion; `check_prune_contract` turns False into exit 1)
        touched = np.asarray(res.blocks_touched)
        row["blocks_touched_mean"] = float(touched.mean())
        row["n_summary_blocks"] = int(summary.n_blocks)
        row["scan_frac"] = float(touched.mean() / summary.n_blocks)
        plain = fixed_radius_nns(queries, db, radius, MAX_CANDIDATES,
                                 scan_block=scan_block)
        row["bitmatch_unpruned"] = all(
            bool(jnp.array_equal(a, b))
            for a, b in zip(res[:3], plain[:3]))
    # bit-match check while the db is resident: dense cells check streaming
    # against themselves on a query slice; streaming cells check against the
    # dense oracle wherever the slice's distance matrix fits in RAM — this
    # is what certifies the 8M/16M wide-key cells (streaming == oracle).
    # Only the first three NNSResult fields compare: `blocks_touched` is
    # None on exactly one side by design
    if path == "dense":
        # `res` already holds the dense full-batch output; only the
        # streaming side needs computing
        s = fixed_radius_nns(queries[:Q_ORACLE], db, radius, MAX_CANDIDATES,
                             scan_block=scan_block_for(n))
        row["bitmatch_oracle"] = all(
            bool(jnp.array_equal(a[:Q_ORACLE], b))
            for a, b in zip(res[:3], s[:3]))
    elif Q_ORACLE * n * 4 <= DENSE_MAX_BYTES:
        # jit the dense slice so the (Q_ORACLE, n, WORDS) xor/popcount
        # intermediates fuse into the reduction — eager, they would be
        # 2*WORDS x larger than the (Q_ORACLE, n) matrix the guard budgets
        d = jax.jit(lambda qs: fixed_radius_nns(
            qs, db, radius, MAX_CANDIDATES, scan_block=0))(
                queries[:Q_ORACLE])
        # `res` is this path's own full-catalog result from the timing loop
        row["bitmatch_oracle"] = all(
            bool(jnp.array_equal(a, b[:Q_ORACLE]))
            for a, b in zip(d[:3], res[:3]))
    return row


def _spawn_cell(n: int, path: str, repeats: int = REPS) -> dict:
    """Run one cell in a fresh interpreter; returns its JSON row.

    A crashed cell (e.g. the dense path OOM-killed on a small host — the
    failure mode this benchmark probes) is reported as a status=failed row
    with its stderr tail, so the sweep continues and still emits the
    artifact."""
    env = dict(os.environ)
    # the bare container env hangs on TPU plugin init; pin the parent backend
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["NNS_SCALE_REPS"] = str(max(repeats, 1))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.nns_scale",
         "--cell", str(n), path],
        env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        tail = proc.stderr.strip().splitlines()[-3:]
        print(f"# cell n={n} path={path} failed "
              f"(rc={proc.returncode}): {' | '.join(tail)}", file=sys.stderr)
        return {"n": n, "q": Q, "path": path, "status": "failed",
                "returncode": proc.returncode,
                "stderr_tail": tail, "dense_matrix_bytes": Q * n * 4}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _derived(row: dict) -> str:
    bits = [f"qps={row['qps']:.1f}",
            f"rss_delta={row['rss_peak_delta_bytes']}",
            f"dense_bytes={row['dense_matrix_bytes']}"]
    if "mem_lt_10pct_dense" in row:
        bits.append(f"mem_lt_10pct_dense={row['mem_lt_10pct_dense']}")
    if "blocks_touched_mean" in row:
        bits.append(f"blocks_touched={row['blocks_touched_mean']:.1f}")
        bits.append(f"scan_frac={row['scan_frac']:.4f}")
    if "speedup_vs_unpruned" in row:
        bits.append(f"speedup_vs_unpruned={row['speedup_vs_unpruned']:.2f}")
    if "bitmatch_unpruned" in row:
        bits.append(f"bitmatch_unpruned={row['bitmatch_unpruned']}")
    if "bitmatch_oracle" in row:
        bits.append(f"bitmatch={row['bitmatch_oracle']}")
    return ";".join(bits)


def rows(sizes=SIZES, repeats: int = REPS):
    out, json_rows = [], []
    for n in sizes:
        paths = ["streaming"]
        if n >= 1_048_576:
            paths.append("streaming_qp2")  # query-parallel knob
        # the Zipf-skewed pair: same clustered catalog, scan without / with
        # block-summary pruning (zipf_stream must run first — the pruned
        # row's speedup_vs_unpruned reads it)
        paths += ["zipf_stream", "zipf_pruned"]
        if Q * n * 4 <= DENSE_MAX_BYTES:
            paths.append("dense")
        for path in paths:
            row = _spawn_cell(n, path, repeats)
            if path == "zipf_pruned" and row["status"] == "ok":
                stream = next(
                    (r for r in json_rows
                     if r["n"] == n and r["path"] == "zipf_stream"
                     and r["status"] == "ok"), None)
                # NaN (not absent) when the stream cell failed: the row
                # schema stays uniform across the sweep and bench_compare
                # drops NaN metrics as not-comparable
                row["speedup_vs_unpruned"] = (
                    row["qps"] / stream["qps"] if stream is not None
                    else float("nan"))
            json_rows.append(row)
            if row["status"] != "ok":
                out.append((f"nns_scale/{path}/n{n}", 0.0, "status=failed"))
            else:
                out.append((f"nns_scale/{path}/n{n}", row["us_per_query"],
                            _derived(row)))
        if Q * n * 4 > DENSE_MAX_BYTES:
            json_rows.append({"n": n, "q": Q, "path": "dense",
                              "status": "skipped_oom_guard",
                              "dense_matrix_bytes": Q * n * 4})
            out.append((
                f"nns_scale/dense/n{n}", 0.0,
                f"status=skipped_oom_guard;dense_bytes={Q * n * 4}"))
    return out, json_rows


def check_stream_contract(json_rows, rss_budget: int) -> list[str]:
    """The streaming cells' memory/bit-match contract (nightly lane)."""
    problems = []
    for row in json_rows:
        if not row["path"].startswith("streaming"):
            continue
        if row["status"] != "ok":
            problems.append(f"n={row['n']} {row['path']}: status failed")
            continue
        if row["path"] == "streaming":
            # memory contract applies to the single-device scan only (the
            # qp2 cells replicate the catalog once per fake device inside
            # one process, so their RSS measures devices x db, not the
            # scan) and only once the dense matrix dwarfs constant
            # jit/runtime overheads
            if (row["n"] >= 1_048_576
                    and not row.get("mem_lt_10pct_dense", False)):
                problems.append(
                    f"n={row['n']} {row['path']}: rss_delta "
                    f"{row['rss_peak_delta_bytes']} >= 10% of dense matrix")
            if row["rss_peak_delta_bytes"] >= rss_budget:
                problems.append(
                    f"n={row['n']} {row['path']}: rss_delta "
                    f"{row['rss_peak_delta_bytes']} >= budget {rss_budget}")
        if "bitmatch_oracle" not in row:
            # a cell whose oracle slice never ran is uncertified, not ok
            problems.append(f"n={row['n']} {row['path']}: oracle check "
                            f"skipped (dense slice exceeds DENSE_MAX_BYTES)")
        elif not row["bitmatch_oracle"]:
            problems.append(f"n={row['n']} {row['path']}: oracle mismatch")
    return problems


def check_prune_contract(json_rows, max_scan_frac: float) -> list[str]:
    """The pruned Zipf cells' contract (nightly lane): bit-identical to the
    unpruned scan always; scan_frac under the ceiling and >=
    PRUNE_MIN_SPEEDUP over the unpruned streaming scan at >= 1M rows.
    The perf legs apply at >= 1M only — below that, the 4096-row summary
    blocks each span many clusters, so the OR saturates by construction
    and the pruned scan merely matches the unpruned one."""
    problems = []
    for row in json_rows:
        if row["path"] != "zipf_pruned":
            continue
        if row["status"] != "ok":
            problems.append(f"n={row['n']} zipf_pruned: status failed")
            continue
        if not row.get("bitmatch_unpruned", False):
            problems.append(
                f"n={row['n']} zipf_pruned: pruned != unpruned bits")
        if row["n"] < 1_048_576:
            continue
        if row["scan_frac"] >= max_scan_frac:
            problems.append(
                f"n={row['n']} zipf_pruned: scan_frac {row['scan_frac']:.4f}"
                f" >= ceiling {max_scan_frac}")
        speedup = row.get("speedup_vs_unpruned")
        if speedup is None:
            problems.append(
                f"n={row['n']} zipf_pruned: no zipf_stream cell to compare")
        elif speedup < PRUNE_MIN_SPEEDUP:
            problems.append(
                f"n={row['n']} zipf_pruned: speedup {speedup:.2f}x < "
                f"{PRUNE_MIN_SPEEDUP}x over the unpruned scan")
    return problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="extend the sweep to the 4M/8M/16M wide-key cells")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated catalog sizes (unified flag; "
                         "overrides --full)")
    ap.add_argument("--repeats", type=int, default=REPS,
                    help="steady-state scans averaged per cell")
    ap.add_argument("--out", type=str, default=None,
                    help="artifact directory (default $BENCH_OUT_DIR or .)")
    ap.add_argument("--assert-stream-mem", type=int, default=None,
                    metavar="BYTES",
                    help="exit 1 unless every streaming cell is ok, under "
                         "10%% of the dense matrix AND under this RSS budget")
    ap.add_argument("--assert-scan-frac", type=float, default=None,
                    metavar="FRAC",
                    help="exit 1 unless every zipf_pruned cell bit-matches "
                         "the unpruned scan, keeps scan_frac under FRAC, "
                         f"and (>= 1M rows) beats it {PRUNE_MIN_SPEEDUP}x")
    ap.add_argument("--cell", nargs=2, metavar=("N", "PATH"),
                    help="internal: run one measurement and print JSON")
    args = ap.parse_args()
    if args.cell:
        print(json.dumps(_cell(int(args.cell[0]), args.cell[1])))
        return

    from benchmarks.bench_io import (
        check_row_schema,
        csv_rows_to_json,
        write_bench_json,
    )

    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        sizes = FULL_SIZES if args.full else SIZES
    out, json_rows = rows(sizes, args.repeats)
    for name, us, derived in out:
        print(f"{name},{us:.3f},{derived}")
    # schema gate: every cell of a path carries the same metric set (a
    # sweep cell silently dropping a metric fails the run, it doesn't
    # ship a hole in the artifact)
    check_row_schema(
        csv_rows_to_json(out),
        within=tuple(f"nns_scale/{p}/" for p in
                     ("streaming", "streaming_qp2", "zipf_stream",
                      "zipf_pruned", "dense")))
    # `rows` carries the one csv shape bench_compare diffs; the raw
    # per-cell measurements (rss deltas, compile times, ...) ride in
    # `cells` — previously they *were* the rows, which broke any tool
    # expecting the shared name/us_per_call/derived shape
    path = write_bench_json(
        "nns_scale", csv_rows_to_json(out), out_dir=args.out,
        cells=json_rows,
        config={"radius": RADIUS, "max_candidates": MAX_CANDIDATES,
                "words": WORDS, "q": Q, "q_oracle": Q_ORACLE,
                # the chunk each cell ran with is in its row's scan_block
                # field (scan_block_for ramps it with catalog size)
                "dense_max_bytes": DENSE_MAX_BYTES,
                "zipf": {"clusters": ZIPF_CLUSTERS,
                         "exponent": ZIPF_EXPONENT,
                         "flip_positions": ZIPF_FLIP_POSITIONS,
                         "radius": ZIPF_RADIUS},
                "reps": args.repeats})
    print(f"# wrote {path}")
    failed = False
    if args.assert_stream_mem is not None:
        problems = check_stream_contract(json_rows, args.assert_stream_mem)
        for p in problems:
            print(f"# CONTRACT VIOLATION: {p}", file=sys.stderr)
        failed |= bool(problems)
        if not problems:
            print(f"# streaming contract ok (rss budget "
                  f"{args.assert_stream_mem} bytes)")
    if args.assert_scan_frac is not None:
        problems = check_prune_contract(json_rows, args.assert_scan_frac)
        for p in problems:
            print(f"# CONTRACT VIOLATION: {p}", file=sys.stderr)
        failed |= bool(problems)
        if not problems:
            print(f"# prune contract ok (scan_frac < "
                  f"{args.assert_scan_frac})")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
