"""Telemetry overhead gate: instrumented serving must keep >= 0.95x qps.

Observability that taxes the serving path gets turned off in production —
so the telemetry layer (src/repro/obs/: per-ticket stage spans, the
metrics registry, the exporters) carries an enforced overhead budget.
This benchmark drives the same query stream through each front-end twice
— ``trace=True`` (spans + registry histograms live) and ``trace=False``
(bare counters) — and **fails** (exit 1) unless, per mode:

  * instrumented qps >= 0.95x uninstrumented qps — judged on the best
    back-to-back traced/untraced pass pair out of ``--repeats``, so a
    noisy host phase (CI containers share cores) lands on both arms of
    a pair instead of reading as overhead — and
  * the per-ticket stage breakdown is *consistent*: the mean stage-span
    sum is within 10% of the mean measured submit->resolve latency on the
    traced pass (span chains are contiguous by construction, so this
    catches a front-end dropping or misordering a boundary).

Artifacts: ``BENCH_obs_overhead.json`` with ``overhead_frac=`` per row
(diffed lower-is-better by tools/bench_compare.py) and the traced pass's
full registry snapshot embedded as the top-level ``telemetry`` key
(schema-checked by `bench_io.check_telemetry_schema`); the traced
tickets as ``obs_trace_<mode>.jsonl`` next to it — the input of
``python tools/obs_report.py``.

  PYTHONPATH=src python -m benchmarks.obs_overhead
      [--smoke] [--sizes 64] [--repeats 5] [--out DIR]

``--smoke`` shrinks the cell for the CI fast lane; ``--sizes`` sweeps
batch sizes (the unified serving-benchmark flags).
"""
import argparse
import os
import sys
import time

import numpy as np

from repro.data.synthetic import serving_queries as _queries
from repro.obs import dump_trace
from repro.serving import make_server

from benchmarks.serving_throughput import _setup

MODES = ("sync", "pipelined")
GATE_MIN_RATIO = 0.95  # instrumented / uninstrumented qps floor
BREAKDOWN_TOL = 0.10  # |stage-sum mean - latency mean| / latency mean


def _pass_qps(server, queries, batch: int) -> float:
    """One timed pass of the full stream through `server`, as qps."""
    n = len(queries)
    t0 = time.perf_counter()
    for lo in range(0, n, batch):
        server.serve_many(queries[lo: lo + batch])
    return n / (time.perf_counter() - t0)


def _measure(engine, data, mode: str, batch: int, n_queries: int,
             repeats: int):
    """(best traced qps, best untraced qps, ratio, records, registry).

    The two arms run genuinely interleaved — traced pass, untraced pass,
    traced pass, ... with the order flipped every repeat — and the gate
    ratio is the best *per-repeat pair* (traced qps / untraced qps of
    two back-to-back passes). Intrinsic tracing cost taxes every pair,
    so a real regression drags the best pair down with it; a noisy
    neighbour on a shared CI core slows one pair but not all of them,
    and comparing across pairs (best-of-each-arm) would misread that
    noise as overhead.
    """
    rng = np.random.default_rng(0)
    servers = {
        arm: make_server(engine, mode, max_batch=batch, buckets=(batch,),
                         trace=arm)
        for arm in (True, False)
    }
    queries = _queries(data, rng.integers(0, data.n_users, n_queries))
    for server in servers.values():
        # warm off the clock: compile, fill the ring, settle allocators —
        # a full pass, not one chunk, or pass 1 still pays warmup and the
        # arm measured first reads as slower than it is
        _pass_qps(server, queries, batch)
        _pass_qps(server, queries, batch)
        server.take_trace()
    best = {True: 0.0, False: 0.0}
    best_ratio = 0.0
    records: list = []
    for r in range(max(repeats, 1)):
        pair = {}
        for arm in ((True, False) if r % 2 == 0 else (False, True)):
            servers[arm].take_trace()
            pair[arm] = qps = _pass_qps(servers[arm], queries, batch)
            if qps > best[arm]:
                best[arm] = qps
                if arm:
                    records = servers[arm].take_trace()
        if pair[False]:
            best_ratio = max(best_ratio, pair[True] / pair[False])
    return (best[True], best[False], best_ratio, records,
            servers[True].registry)


def _breakdown_gap(records) -> tuple[float, dict]:
    """Fractional gap between mean stage-sum and mean measured latency."""
    from tools.obs_report import stage_breakdown

    bd = stage_breakdown(records, status="ok")
    lat = bd["latency_s"]["mean"]
    if not lat:
        return float("inf"), bd
    return abs(bd["stage_sum_mean_s"] - lat) / lat, bd


def run(batch_sizes, repeats: int, smoke: bool, out_dir):
    engine, data, _, _, _ = _setup()
    n_queries = 256 if smoke else 1024
    rows, telemetry, failures = [], None, []
    for mode in MODES:
        for batch in batch_sizes:
            qps_on, qps_off, ratio, records, registry = _measure(
                engine, data, mode, batch, n_queries, repeats)
            overhead = max(0.0, 1.0 - ratio)
            gap, bd = _breakdown_gap(records)
            ok = ratio >= GATE_MIN_RATIO and gap <= BREAKDOWN_TOL
            if ratio < GATE_MIN_RATIO:
                failures.append(
                    f"{mode}/batch{batch}: best traced/untraced pair is "
                    f"{ratio:.3f}x (floor {GATE_MIN_RATIO}x; best qps "
                    f"{qps_on:.0f} traced / {qps_off:.0f} untraced)")
            if gap > BREAKDOWN_TOL:
                failures.append(
                    f"{mode}/batch{batch}: stage-sum vs latency gap "
                    f"{gap:.1%} exceeds {BREAKDOWN_TOL:.0%}")
            rows.append((
                f"obs/overhead/{mode}/batch{batch}", 1e6 / qps_on,
                f"qps={qps_on:.0f};qps_untraced={qps_off:.0f};"
                f"overhead_frac={overhead:.4f};breakdown_gap={gap:.4f};"
                f"ok={ok}",
            ))
            if telemetry is None:
                telemetry = registry.snapshot()  # first traced cell
            if out_dir is not None and batch == batch_sizes[0]:
                n = dump_trace(records, os.path.join(
                    out_dir, f"obs_trace_{mode}.jsonl"))
                print(f"# dumped {n} traced tickets for mode={mode}")
    return rows, telemetry, failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default="64",
                    help="comma-separated batch sizes (unified flag)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="interleaved passes per arm (best reported)")
    ap.add_argument("--smoke", action="store_true",
                    help="small cell for the CI fast lane")
    ap.add_argument("--out", type=str, default=None,
                    help="artifact directory (default $BENCH_OUT_DIR or .)")
    args = ap.parse_args()
    batch_sizes = tuple(int(s) for s in args.sizes.split(","))
    out_dir = args.out or os.environ.get("BENCH_OUT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)

    from benchmarks.bench_io import (check_row_schema,
                                     check_telemetry_schema,
                                     csv_rows_to_json, write_bench_json)

    rows, telemetry, failures = run(batch_sizes, args.repeats, args.smoke,
                                    out_dir)
    for name, us, derived in rows:
        print(f"{name},{us:.6f},{derived}")
    json_rows = csv_rows_to_json(rows)
    check_row_schema(json_rows, ("qps", "overhead_frac"),
                     within=("obs/overhead/",))
    check_telemetry_schema(telemetry, required=(
        "serving.served", "serving.ticket_latency_s.count",
        "cache.lookups"))
    path = write_bench_json(
        "obs_overhead", json_rows, out_dir=out_dir,
        config={"batch_sizes": batch_sizes, "repeats": args.repeats,
                "smoke": args.smoke, "gate_min_ratio": GATE_MIN_RATIO},
        telemetry=telemetry)
    print(f"# wrote {path}")
    if failures:
        print("OVERHEAD GATE FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
