"""Online freshness: train-while-serve quality, staleness, and throughput.

Every serving benchmark so far froze the model at build time; production
recommenders retrain continuously and fold the updated embeddings into the
live index (the churn iMARS' in-memory fabric exists to absorb). This
benchmark closes the loop end to end with `serving.OnlineTrainer` (gradient
steps -> `LiveCatalog.upsert` folds -> `engine_refresh_model` dense
refreshes, all publishing through `swap_engine` under the concurrent
front-end's serve lock) and locks it down with the `serving.shadow`
freshness oracle. Four phases over one seeded query stream:

  * ``frozen``       — concurrent front-end over the deployed live
                       catalog at rest (trainer idle): the qps baseline,
                       measured through the SAME delta-overlay serving
                       path the training phase uses so the sustain gate
                       isolates the cost of concurrent training (the
                       overlay-vs-plain-engine cost is catalog_churn's
                       gated axis);
  * ``train_serve``  — the same stream while a paced training thread
                       (``--steps-per-s``, modeling the interaction arrival
                       rate) lands gradient steps and folds embedding
                       updates into the live catalog between drain chunks;
  * ``freshness``    — `ShadowHarness.checkpoint()` every ``--eval-every``
                       steps: HR@10 of the continuously-updated live engine
                       vs a **cold rebuild of the current parameters**
                       (`rebuild_from_params` — re-quantized, re-signed,
                       re-summarized from scratch), asserted within
                       ``--tol`` at EVERY checkpoint;
  * ``cadence``      — fold-cadence sweep (`fold_every` in ``--cadences``):
                       measured staleness (update landed -> update visible)
                       against the update rate, the freshness/overhead axis.

Acceptance gates (asserted in-benchmark, reported as ``ok=`` fields):
  * live HR@10 within ``--tol`` (0.01 absolute) of the cold-retrained
    reference at every checkpoint;
  * serving qps under concurrent training >= 0.8x frozen;
  * zero ``status="error"`` tickets across every served stream.

  PYTHONPATH=src python -m benchmarks.online_freshness
      [--sizes 2000] [--queries 1024] [--batch 256] [--train-batch 256]
      [--pretrain 300] [--train-steps 300] [--eval-every 100]
      [--steps-per-s 8] [--fold-every 8] [--compact-every 1]
      [--cadences 1,8,32] [--tol 0.01] [--repeats 2] [--out DIR] [--smoke]

``--sizes``/``--repeats``/``--out`` are the flags every serving benchmark
shares (see tools/bench_compare.py). ``--smoke`` is the CI fast-lane cell:
a tiny model (~200 online steps) that still runs every phase and gate.

Variance control mirrors benchmarks/catalog_churn.py: the Eigen
single-thread XLA flag is defaulted in before jax loads and every qps cell
reports the best of ``--repeats`` measured passes.

Emits BENCH_online_freshness.json (see benchmarks/bench_io.py).
"""
from __future__ import annotations

import argparse
import itertools
import threading
import time


def _setup(n_items: int, n_users: int, pretrain_steps: int,
           train_batch: int, history_len: int = 12, hot_rows: int = 128,
           seed: int = 0):
    """Pretrain a YoutubeDNN (the exact `make_recsys_train_step`
    computation the online trainer continues) and build its engine."""
    import jax

    from repro.data import synthetic
    from repro.distributed import training
    from repro.models import recsys as rs
    from repro.serving import RecSysEngine
    import numpy as np

    data = synthetic.make_movielens(n_users=n_users, n_items=n_items,
                                    history_len=history_len)
    cfg = rs.YoutubeDNNConfig(
        n_items=n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=history_len)
    params = rs.init_youtubednn(jax.random.key(seed), cfg)
    state = training.init_recsys_train_state(params)
    step = training.make_recsys_train_step(cfg)
    for batch in synthetic.movielens_batches(data, train_batch,
                                             pretrain_steps):
        state, _ = step(state, batch)
    params = state.params
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=64,
                                top_k=10, hot_rows=hot_rows,
                                item_freqs=freqs)
    return engine, data, cfg, params


def _paced_steps(trainer, batches, steps_per_s: float,
                 stop: threading.Event | None = None):
    """Run `trainer.step` over `batches` paced at `steps_per_s` (the
    modeled interaction arrival rate; 0 = free-run). Returns steps taken."""
    period = 1.0 / steps_per_s if steps_per_s > 0 else 0.0
    next_t = time.perf_counter()
    n = 0
    for batch in batches:
        if stop is not None and stop.is_set():
            break
        trainer.step(batch)
        n += 1
        if period:
            next_t += period
            lag = next_t - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            else:
                next_t = time.perf_counter()  # don't burst after a stall
    return n


def _serve_stream(server, queries, repeats: int, min_s: float = 0.0):
    """Best-of-passes qps over the stream; counts error tickets (run 1
    doubles as warmup, same policy as benchmarks/catalog_churn.py).

    `min_s` keeps replaying the stream until that much wall time has
    elapsed (as well as at least `repeats` passes) — the train-while-serve
    phase needs the serving window to span several paced gradient steps
    and folds, not to outrun them."""
    best_qps, n_err, n_pass = 0.0, 0, 0
    t_start = time.perf_counter()
    while n_pass < max(repeats, 1) or time.perf_counter() - t_start < min_s:
        t0 = time.perf_counter()
        served = server.serve_many(queries)
        dt = time.perf_counter() - t0
        n_err += sum(1 for s in served if s.status == "error")
        best_qps = max(best_qps, len(queries) / dt)
        n_pass += 1
    return best_qps, n_err


def rows(n_items: int, n_users: int, n_queries: int, batch: int,
         train_batch: int, pretrain: int, train_steps: int, eval_every: int,
         steps_per_s: float, fold_every: int, compact_every: int,
         cadences, tol: float, max_users: int | None, repeats: int = 2):
    import numpy as np

    from repro.data.synthetic import movielens_batches, serving_queries
    from repro.serving import (
        LiveCatalog,
        OnlineTrainer,
        ShadowHarness,
        make_server,
    )

    def concurrent_server(eng):
        # queue_depth=None: this harness measures throughput, not
        # admission control — nothing sheds, errors still surface
        return make_server(eng, "concurrent", max_batch=batch,
                           buckets=(batch,), queue_depth=None)

    engine, data, cfg, params = _setup(n_items, n_users, pretrain,
                                       train_batch)
    rng = np.random.default_rng(0)
    queries = serving_queries(data, rng.integers(0, data.n_users, n_queries))
    warm = serving_queries(data, rng.integers(0, data.n_users, batch))
    out = []

    # -- deploy the online-learning stack -------------------------------
    # delta_capacity=n_items: the full-softmax gradient touches every item
    # row, so a fold may upsert the whole catalog — size for it and let
    # compaction be a cadence choice, not a forced stall
    cat = LiveCatalog(engine, delta_capacity=n_items)
    live = concurrent_server(cat.engine)
    cat.attach(live)
    trainer = OnlineTrainer(cat, cfg, params, fold_every=fold_every,
                            compact_every=compact_every)
    batches = list(movielens_batches(data, train_batch, train_steps,
                                     seed=1))
    trainer.step(batches[0])  # train-step compile off the clock
    trainer.fold()  # first fold + compact pay one-time compiles; eat them
    live.serve_many(warm)  # ...and re-warm serving on the swapped engine

    # -- frozen baseline: the same serving path, trainer idle -----------
    # Both sides of the sustain gate serve through the live catalog — the
    # delta-overlay-vs-plain-engine cost is benchmarks/catalog_churn.py's
    # gated axis; this gate isolates the marginal cost of CONCURRENT
    # TRAINING, which an engine-vs-catalog comparison would drown out.
    # Both sides also get the same min_s window so best-of-pass counts
    # are comparable; the window spans several folds (see below).
    min_s = max(3.0, 4 * fold_every / steps_per_s) if steps_per_s > 0 \
        else 3.0
    qps_frozen, err_frozen = _serve_stream(live, queries, repeats,
                                           min_s=min_s)
    out.append((f"serving/online/frozen_{n_items}", 1e6 / qps_frozen,
                f"qps={qps_frozen:.0f};items={n_items};path=live_catalog;"
                f"errors={err_frozen}"))

    # -- train-while-serve: paced trainer vs the same stream ------------
    stop = threading.Event()
    feed = itertools.cycle(batches)  # trainer runs as long as serving does
    tally = {}
    th = threading.Thread(
        target=lambda: tally.setdefault(
            "steps", _paced_steps(trainer, feed, steps_per_s, stop)),
        name="online-trainer", daemon=True)
    # the shared min_s window holds serving open long enough for the paced
    # trainer to land several folds inside it — otherwise a fast stream
    # outruns the pacing and "qps under training" measures an idle trainer
    t_train0 = time.perf_counter()
    th.start()
    qps_train, err_train = _serve_stream(live, queries, repeats,
                                         min_s=min_s)
    stop.set()
    th.join()
    train_dt = time.perf_counter() - t_train0
    sustain = qps_train / qps_frozen
    n_err = live.stats()["n_errors"]
    ok_sustain = sustain >= 0.8
    ok_err = n_err == 0 and err_train == 0 and err_frozen == 0
    out.append((
        f"serving/online/train_serve_{n_items}", 1e6 / qps_train,
        f"qps={qps_train:.0f};sustain_vs_frozen={sustain:.2f}x"
        f"(target >=0.8x);ok={ok_sustain};errors={n_err};"
        f"steps_during={tally.get('steps', 0)};"
        f"steps_per_s={tally.get('steps', 0) / train_dt:.1f};"
        f"folds={trainer.n_folds};rows_folded={trainer.rows_folded}"))
    assert ok_sustain, (
        f"serving under concurrent training sustained only {sustain:.2f}x "
        f"of frozen qps (target >= 0.8x)")
    assert ok_err, (
        f"error tickets under train-while-serve: {n_err} in stats, "
        f"{err_train} in stream (target: zero)")

    # -- freshness: shadow checkpoints against the cold rebuild ---------
    # (trainer thread has exited — the main thread is now the single
    # writer, so checkpoints may fold/refresh directly)
    shadow = ShadowHarness(trainer, data, k=10, mode="lsh", tol=tol,
                           max_users=max_users)
    feed = movielens_batches(data, train_batch, train_steps, seed=2)
    done = 0
    while done < train_steps:
        burst = min(eval_every, train_steps - done)
        done += _paced_steps(trainer, itertools.islice(feed, burst),
                             steps_per_s)
        shadow.checkpoint()  # raises the moment live leaves the tol band
    recs = shadow.records
    max_gap = max(r.gap for r in recs)
    ok_gap = max_gap <= tol  # every checkpoint already asserted
    out.append((
        f"serving/online/freshness_{n_items}", 0.0,
        f"hr_at_10={recs[-1].hr_live:.4f};hr_ref={recs[-1].hr_ref:.4f};"
        f"max_gap={max_gap:.4f}(tol {tol});checkpoints={len(recs)};"
        f"agree_frac={recs[-1].agree_frac:.3f};ok={ok_gap}"))

    # -- staleness under the measured update rate -----------------------
    st = trainer.stats()
    out.append((
        f"serving/online/staleness_{n_items}",
        st["staleness_ms_mean"] * 1e3,
        f"staleness_ms={st['staleness_ms_mean']:.1f};"
        f"staleness_p95_ms={st['staleness_ms_p95']:.1f};"
        f"update_rate={steps_per_s:.1f};"
        f"updates_landed={st['updates_landed']};"
        f"updates_visible={st['updates_visible']};"
        f"updates_pending={st['updates_pending']}"))
    live.close()

    # -- fold-cadence sweep: staleness vs update rate -------------------
    feed = movielens_batches(data, train_batch, 10_000, seed=3)
    for cadence in cadences:
        trainer.fold_every = cadence
        trainer.fold()  # drain pending from the previous cadence
        lo = len(trainer.staleness_ms)
        burst = max(16, 2 * cadence)
        _paced_steps(trainer, itertools.islice(feed, burst), steps_per_s)
        trainer.fold()
        lat = trainer.staleness_ms[lo:]
        out.append((
            f"serving/online/cadence{cadence}_{n_items}",
            float(np.mean(lat)) * 1e3,
            f"staleness_ms={np.mean(lat):.1f};fold_every={cadence};"
            f"update_rate={steps_per_s:.1f};steps={burst}"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated catalog sizes (unified flag; "
                         "default: --items)")
    ap.add_argument("--items", type=int, default=2000)
    ap.add_argument("--users", type=int, default=800)
    ap.add_argument("--queries", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--train-batch", type=int, default=256)
    ap.add_argument("--pretrain", type=int, default=300,
                    help="offline steps before the engine deploys")
    ap.add_argument("--train-steps", type=int, default=300,
                    help="online steps in the freshness phase")
    ap.add_argument("--eval-every", type=int, default=100,
                    help="shadow checkpoint cadence (steps)")
    ap.add_argument("--steps-per-s", type=float, default=8.0,
                    help="paced trainer rate (modeled interaction arrival "
                         "rate; 0 = free-run)")
    # the full-softmax gradient densifies every item row, so each fold
    # upserts ~the whole catalog into the delta shard; without compaction
    # serving pays a permanent full-size delta scan + overlay and the
    # 0.8x sustain gate fails.  Pairing folds with compaction (and folding
    # every few steps rather than every step) keeps the delta drained.
    # Fold+compact cost scales with catalog size (~14 ms at 400 items,
    # ~400 ms at 2000), so the default cadence is sized for the full run;
    # --smoke folds tighter (every 4) where folds are cheap.
    ap.add_argument("--fold-every", type=int, default=8)
    ap.add_argument("--compact-every", type=int, default=1,
                    help="compact the catalog every N folds (0 = never)")
    ap.add_argument("--cadences", type=str, default="1,8,32",
                    help="fold_every values for the staleness sweep")
    ap.add_argument("--tol", type=float, default=0.01,
                    help="max |HR@10 live - cold rebuild| per checkpoint")
    ap.add_argument("--max-users", type=int, default=None,
                    help="cap the HR eval stream (None = every user)")
    ap.add_argument("--repeats", type=int, default=2,
                    help="measured passes per qps cell (first doubles as "
                         "warmup; best pass reported)")
    ap.add_argument("--out", type=str, default=None,
                    help="artifact directory (default $BENCH_OUT_DIR or .)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast-lane cell: tiny model, ~200 online "
                         "steps, every phase and gate")
    args = ap.parse_args()
    if args.smoke:
        args.items, args.users = 400, 300
        args.queries, args.batch, args.train_batch = 256, 64, 64
        args.pretrain, args.train_steps, args.eval_every = 120, 200, 100
        args.cadences, args.max_users = "1,8", 200
        args.fold_every = 4  # folds are cheap at this scale; keep fresh
    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else (args.items,))
    cadences = tuple(int(c) for c in args.cadences.split(","))

    from benchmarks.async_serving import _default_xla_cpu_flags

    _default_xla_cpu_flags()  # must precede the first jax import

    from benchmarks.bench_io import csv_rows_to_json, write_bench_json

    out = []
    for n_items in sizes:
        out.extend(rows(n_items, args.users, args.queries, args.batch,
                        args.train_batch, args.pretrain, args.train_steps,
                        args.eval_every, args.steps_per_s, args.fold_every,
                        args.compact_every, cadences, args.tol,
                        args.max_users, args.repeats))
    for name, us, derived in out:
        print(f"{name},{us:.6f},{derived}")
    path = write_bench_json(
        "online_freshness", csv_rows_to_json(out), out_dir=args.out,
        config={"sizes": sizes, "users": args.users,
                "queries": args.queries, "batch": args.batch,
                "train_batch": args.train_batch, "pretrain": args.pretrain,
                "train_steps": args.train_steps,
                "eval_every": args.eval_every,
                "steps_per_s": args.steps_per_s,
                "fold_every": args.fold_every,
                "compact_every": args.compact_every, "cadences": cadences,
                "tol": args.tol, "repeats": args.repeats,
                "smoke": args.smoke})
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
