"""Roofline analysis from the compiled dry-run artifacts.

Per (arch x shape x mesh) cell, using the trip-count-corrected HLO stats in
experiments/dryrun/*.json (all per-device — the compiled module IS the
per-device SPMD program):

  compute term    = flops_per_device / 197 TFLOP/s          (bf16 v5e)
  memory term     = hbm_bytes_per_device / 819 GB/s
  collective term = collective_operand_bytes_per_device / 50 GB/s (ICI)

MODEL_FLOPS uses 6*N*D (train, dense), 6*N_active*D (MoE), 2*N*D (prefill),
2*N_active*B (decode: one token per sequence). The reported
`useful_fraction` = (MODEL_FLOPS time at peak) / (dominant term) — the
roofline fraction the hillclimb drives up.
"""
from __future__ import annotations

import json
import pathlib
import sys

from repro.configs.base import SHAPES, active_param_count, param_count_dense
from repro.configs.registry import ARCH_IDS, get_arch

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s/link / chip

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    bundle = get_arch(arch)
    cfg = bundle.model
    shape = SHAPES[shape_name]
    n = param_count_dense(cfg)
    n_act = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


def _cell_file(arch, shape, mesh, tag=""):
    safe = arch.replace(".", "_").replace("/", "_")
    suffix = f"__{tag}" if tag else ""
    return DRYRUN_DIR / f"{safe}__{shape}__{mesh}{suffix}.json"


def analyze_cell(arch: str, shape: str, mesh: str = "single", tag: str = ""
                 ) -> dict | None:
    path = _cell_file(arch, shape, mesh, tag)
    if not path.exists():
        return None
    d = json.loads(path.read_text())
    if d["status"] == "skipped":
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "status": "skipped", "reason": d["reason"]}
    if d["status"] != "ok":
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "status": d["status"], "error": d.get("error", "")[:200]}
    chips = d["n_devices"]
    flops_dev = d["hlo"]["flops"]
    hbm_dev = d["hlo"]["hbm_bytes"]
    coll_dev = d["hlo"]["collective_bytes"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = hbm_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    t_useful = mf / (chips * PEAK_FLOPS)
    bound = max(terms.values())
    frac = t_useful / bound if bound > 0 else 0.0
    hlo_global = flops_dev * chips
    suggestions = {
        "compute": "reduce recompute (remat policy) / shrink redundant "
                   "per-shard math so HLO_FLOPs approaches MODEL_FLOPS",
        "memory": "fuse or shrink HBM round-trips (bigger blocks, int8 "
                  "tables/caches, fewer saved activations)",
        "collective": "reshard to cut the dominant collective (kv-repeat "
                      "layout, SP boundaries, expert placement) or overlap "
                      "it under compute",
    }
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "status": "ok",
        "chips": chips,
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "useful_fraction": frac,
        "memory_per_device_gib": (
            d["memory"]["argument_bytes"] + d["memory"]["temp_bytes"]
        ) / 2**30,
        "what_would_help": suggestions[dominant],
        "per_collective": d["hlo"].get("per_collective", {}),
        "tag": tag,
    }


def full_table(mesh: str = "single", tag: str = "") -> list[dict]:
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = analyze_cell(arch, shape, mesh, tag)
            if r is not None:
                rows.append(r)
    return rows


def format_markdown(rows: list[dict]) -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO | roofline frac | per-dev GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['useful_fraction']:.2f} | "
            f"{r['memory_per_device_gib']:.1f} |")
    return "\n".join(out)


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    rows = full_table(mesh)
    print(format_markdown(rows))
    print()
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["useful_fraction"])
        coll = max(ok, key=lambda r: r["collective_s"]
                   / max(r["compute_s"], 1e-12))
        print(f"worst roofline fraction: {worst['arch']} x {worst['shape']}"
              f" ({worst['useful_fraction']:.3f}, {worst['dominant']}-bound)")
        print(f"most collective-bound:  {coll['arch']} x {coll['shape']} "
              f"(coll/compute = "
              f"{coll['collective_s']/max(coll['compute_s'],1e-12):.2f})")


if __name__ == "__main__":
    main()
