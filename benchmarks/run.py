"""Benchmark harness entry point — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV. The roofline table (dry-run
derived) is appended when experiments/dryrun/ artifacts exist.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size accuracy run (slower)")
    ap.add_argument("--skip-accuracy", action="store_true")
    args = ap.parse_args()

    from benchmarks import (
        accuracy_hr,
        end_to_end,
        kernel_bench,
        table2_array_fom,
        table3_et_ops,
    )
    from repro.core import mapping

    print("name,us_per_call,derived")

    ml, cr = mapping.movielens_mapping(), mapping.criteo_mapping()
    print(f"table1/movielens,0.0,banks={ml.banks};mats={ml.mats};"
          f"cmas={ml.cmas};paper=7/8/54")
    print(f"table1/criteo,0.0,banks={cr.banks};mats={cr.mats};"
          f"cmas={cr.cmas};paper=26/104/2860")

    for mod in (table2_array_fom, table3_et_ops):
        for name, us, derived in mod.rows():
            print(f"{name},{us:.6f},{derived}")

    for name, us, derived in end_to_end.rows():
        print(f"{name},{us:.6f},{derived}")

    if not args.skip_accuracy:
        for name, us, derived in accuracy_hr.rows(quick=not args.full):
            print(f"{name},{us:.6f},{derived}")

    for name, us, derived in kernel_bench.rows():
        print(f"{name},{us:.3f},{derived}")

    # roofline summary (if the dry-run has produced artifacts)
    try:
        from benchmarks import roofline

        rows = roofline.full_table("single")
        ok = [r for r in rows if r.get("status") == "ok"]
        for r in ok:
            print(
                f"roofline/{r['arch']}/{r['shape']},0.0,"
                f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
                f"collective={r['collective_s']:.4f}s;dom={r['dominant']};"
                f"frac={r['useful_fraction']:.3f}")
    except Exception as e:  # dry-run not yet produced
        print(f"roofline/unavailable,0.0,{type(e).__name__}")


if __name__ == "__main__":
    main()
