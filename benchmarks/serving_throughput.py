"""Serving throughput: queries/sec vs batch size and hot-cache size.

The iMARS claim is architectural (keep ET traffic inside the memory fabric);
the software image of the same win is (a) amortizing dispatch over micro-
batches and (b) serving hot ET rows from a dense f32 cache. This benchmark
measures both on the actual jitted pipeline of this host:

  * qps at batch sizes 1 / 8 / 64 / 256 through the synchronous front-end
    (compile excluded; the batch-256 row must be >= 5x the batch-1 row)
  * measured hot-cache hit rate at several cache capacities under the
    skewed synthetic MovieLens item popularity.

  PYTHONPATH=src python -m benchmarks.serving_throughput
      [--sizes 1,8,64,256] [--repeats 1] [--out DIR]

``--sizes`` here sweeps **batch** sizes (the quantity this benchmark
varies); ``--sizes``/``--repeats``/``--out`` are the flags every serving
benchmark shares, so tools/bench_compare.py can diff any pair of
artifacts without per-benchmark special cases. Front-ends come from
`make_server` (the unified Server API); cache counters come from
`stats()`. Emits BENCH_serving_throughput.json (see benchmarks/bench_io.py).
"""
import argparse
import time

import jax
import numpy as np

from repro.data import synthetic
from repro.data.synthetic import serving_queries as _queries
from repro.models import recsys as rs
from repro.serving import RecSysEngine, make_server

BATCH_SIZES = (1, 8, 64, 256)
CACHE_SIZES = (0, 64, 256)


def _setup(n_users=2000, n_items=1200, history_len=12, hot_rows=256):
    data = synthetic.make_movielens(n_users=n_users, n_items=n_items,
                                    history_len=history_len)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=history_len)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=data.n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=50,
                                top_k=10, hot_rows=hot_rows, item_freqs=freqs)
    return engine, data, params, cfg, freqs


def _measure_qps(engine, data, batch: int, n_queries: int,
                 repeats: int = 1) -> tuple[float, float, dict]:
    """(queries/sec, hit_rate, telemetry snapshot) through the sync
    front-end at one bucket size; best of `repeats` measured passes."""
    rng = np.random.default_rng(0)
    server = make_server(engine, "sync", max_batch=batch, buckets=(batch,))
    # warmup: compile this bucket shape
    server.serve_many(_queries(data, rng.integers(0, data.n_users, batch)))
    idx = rng.integers(0, data.n_users, n_queries)
    queries = _queries(data, idx)
    best = 0.0
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        for lo in range(0, n_queries, batch):
            server.serve_many(queries[lo: lo + batch])
        best = max(best, n_queries / (time.perf_counter() - t0))
    snap = server.snapshot()
    return best, snap["cache.hits"] / max(snap["cache.lookups"], 1), snap


def rows(batch_sizes=BATCH_SIZES, repeats: int = 1):
    engine, data, params, cfg, freqs = _setup()
    out = []
    qps = {}
    telemetry = None
    for batch in batch_sizes:
        n = max(64, min(1024, batch * 4))
        q, hit, telemetry = _measure_qps(engine, data, batch, n, repeats)
        qps[batch] = q
        out.append((
            f"serving/throughput/batch{batch}", 1e6 / q,
            f"qps={q:.0f};hot_hit_rate={hit:.3f};host=CPU(container)",
        ))
    if 1 in qps and 256 in qps:
        speedup = qps[256] / qps[1]
        out.append((
            "serving/throughput/batched_speedup", 0.0,
            f"qps256_over_qps1={speedup:.1f}x(target >=5x);ok={speedup >= 5}",
        ))
    # hit rate vs cache capacity (same skewed popularity, batch 64)
    for cap in CACHE_SIZES:
        eng = RecSysEngine.build(params, cfg, radius=112, n_candidates=50,
                                 top_k=10, hot_rows=cap, item_freqs=freqs)
        _, hit, _ = _measure_qps(eng, data, 64, 256)
        out.append((
            f"serving/hot_cache/capacity{cap}", 0.0,
            f"hot_hit_rate={hit:.3f};items={data.n_items}",
        ))
    return out, telemetry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str,
                    default=",".join(str(b) for b in BATCH_SIZES),
                    help="comma-separated batch sizes (unified flag)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="measured passes per cell (best pass reported)")
    ap.add_argument("--out", type=str, default=None,
                    help="artifact directory (default $BENCH_OUT_DIR or .)")
    args = ap.parse_args()
    batch_sizes = tuple(int(s) for s in args.sizes.split(","))

    from benchmarks.bench_io import (check_telemetry_schema,
                                     csv_rows_to_json, write_bench_json)

    out, telemetry = rows(batch_sizes, args.repeats)
    for name, us, derived in out:
        print(f"{name},{us:.6f},{derived}")
    check_telemetry_schema(telemetry, required=("serving.served",
                                                "cache.lookups"))
    path = write_bench_json(
        "serving_throughput", csv_rows_to_json(out), out_dir=args.out,
        config={"batch_sizes": batch_sizes, "cache_sizes": CACHE_SIZES,
                "repeats": args.repeats},
        telemetry=telemetry)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
