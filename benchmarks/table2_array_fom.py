"""Paper Table II: array-level figures of merit (the cost model's inputs).

These are the published HSPICE/Neurosim/45nm-synthesis numbers the paper
measured; we print them alongside the derived per-op quantities the model
composes from them.
"""
from repro.core.cost_model import ARRAY_FOM, CAL, e_shot


def rows():
    out = []
    for op, (e_pj, t_ns) in ARRAY_FOM.items():
        out.append((f"table2/{op}", t_ns / 1e3, f"{e_pj}pJ"))
    out.append(("table2/rsc_transfer(cal)", CAL.t_rsc_ns / 1e3,
                f"{e_shot(7):.0f}pJ@7banks"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.6f},{derived}")


if __name__ == "__main__":
    main()
