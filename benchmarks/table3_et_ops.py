"""Paper Table III: ET-operation latency/energy, iMARS (cost model) vs the
paper's measured GPU baselines, plus the NNS comparison of Sec. IV-C2."""
from repro.core import cost_model as cm


def rows():
    out = []
    t3 = cm.table3_model()
    for stage, r in t3.items():
        out.append((
            f"table3/{stage}/imars",
            r["model_latency_us"],
            f"energy={r['model_energy_uj']:.4f}uJ;"
            f"paper={r['paper_latency_us']}us/{r['paper_energy_uj']}uJ;"
            f"lat_err={r['latency_rel_err']*100:+.1f}%;"
            f"en_err={r['energy_rel_err']*100:+.1f}%",
        ))
        out.append((
            f"table3/{stage}/speedup",
            0.0,
            f"latency_x={r['speedup_vs_gpu']:.2f};"
            f"energy_x={r['energy_reduction_vs_gpu']:.1f}",
        ))
    nns = cm.ml_nns_model()
    out.append((
        "table3/nns/imars",
        nns["model_latency_us"],
        f"energy={nns['model_energy_uj']*1e3:.3f}nJ;"
        f"latency_x={nns['latency_speedup']:.0f}(paper {cm.PAPER_END_TO_END['nns_latency_speedup']:.0f});"
        f"energy_x={nns['energy_reduction']:.0f}(paper {cm.PAPER_END_TO_END['nns_energy_reduction']:.0f})",
    ))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.6f},{derived}")


if __name__ == "__main__":
    main()
