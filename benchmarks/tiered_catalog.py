"""Frequency-tiered out-of-core catalog vs the all-RAM engine.

The tiered-catalog headline claim: one host serves a catalog far larger
than RAM-resident serving allows, bit-identically, at comparable
throughput, because the working set under skewed (Zipf) traffic is tiny:

  * the **tiered** cell opens the memmapped base shard and serves through
    `TieredCatalog` — int8 pool + f32 hot cache over the measured-hot head,
    block-summary-pruned out-of-core NNS over the cold tail;
  * the **allram** cell loads the SAME shard bytes fully into RAM
    (`TieredCatalog.to_ram_engine()` — the int8 engine with identical hot
    cache, mask, and summary) and serves the same stream.

Both cells serve the identical query stream and report a sha256 digest
over every served item id, CTR score, and the accumulated cache counters
— the cells must agree bit for bit (asserted). The tiered cell must hold
peak RSS under `--rss-frac` (default 0.25) of the all-RAM cell's and
reach `--min-qps-frac` (default 0.7) of its throughput; both checks are
in-benchmark hard exit codes, and the nightly lane adds an absolute
`--rss-budget` on top.

Catalog construction (deterministic, chunked — the writer never holds
the table): the first `BOOT_ITEMS` rows are a bootstrap table; user
histories are Zipf over that hot head. Cold-tail rows are generated in
per-chunk rng streams, clustered (cluster-contiguous ids) around real
user embeddings computed through the bootstrap engine's filtering MLP —
so query signatures land near their home cluster's signatures, the
block summary admits a compact block set per batch, and the out-of-core
scan's residency tracks the admitted working set the way production
skew would make it. The block summary is prebuilt at write time: opening
the shard never touches a signature page.

  PYTHONPATH=src python -m benchmarks.tiered_catalog [--items N] [--full]
      [--repeats 2] [--out DIR] [--rss-budget BYTES]
      [--rss-frac 0.25] [--min-qps-frac 0.7] [--shard-dir DIR]

The digest gate always applies. The RSS/qps *fraction* gates are claims
about scale — below GATE_MIN_ITEMS the fixed jit workspaces dominate
both cells and the ratios are noise, so quick runs skip them (with a
note); ``--full`` (the nightly lane) runs the headline 8M-item catalog
with every gate hard.

Emits BENCH_tiered_catalog.json; the `resident_bytes=` metric is judged
lower-is-better by tools/bench_compare.py.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys
import tempfile

N_ITEMS = 1 << 20  # default quick cell; the nightly lane runs --full
FULL_ITEMS = 1 << 23  # the headline scale: 8M items, far beyond hot RAM
# the RSS/qps fractions are claims about SCALE — below this, fixed jit
# workspaces (~100MB) dwarf the catalog itself and the ratios are noise
GATE_MIN_ITEMS = 1 << 22
BOOT_ITEMS = 4096  # bootstrap head: history ids + cluster-center source
EMBED_DIM = 32
WORDS = 8  # 256-bit signatures
CLUSTERS = 96
NOISE = 0.08  # intra-cluster spread around the center embedding
RADIUS = 72
N_CANDIDATES = 64
HISTORY_LEN = 8
BATCH = 64
N_BATCHES = 8  # digest + timing stream length (per repeat)
POOL_ROWS = 1 << 15
HOT_ROWS = 4096
ZIPF_EXPONENT = 1.1
WRITE_CHUNK = 1 << 18
SEED = 11
REPS = 2


def _default_cfg():
    from repro.models import recsys as rs

    return rs.YoutubeDNNConfig(
        n_items=BOOT_ITEMS,
        user_features={"user_id": 512, "gender": 3, "age": 7},
        history_len=HISTORY_LEN, embed_dim=EMBED_DIM)


def _zipf_weights(np, k: int):
    w = np.arange(1, k + 1, dtype=np.float64) ** -ZIPF_EXPONENT
    return w / w.sum()


def _bootstrap_engine():
    """The user-side model + bootstrap item head (deterministic)."""
    import jax

    from repro.models import recsys as rs
    from repro.serving.recsys_engine import RecSysEngine

    cfg = _default_cfg()
    params = rs.init_youtubednn(jax.random.key(SEED), cfg)
    return RecSysEngine.build(params, cfg, radius=RADIUS,
                              n_candidates=N_CANDIDATES, top_k=10,
                              hot_rows=HOT_ROWS)


def _protos(np):
    """The CLUSTERS prototype users (deterministic) that anchor the item
    clusters; the query stream samples them with Zipf popularity, so
    cluster traffic is skewed like production."""
    rng = np.random.default_rng([SEED, 3])
    w = _zipf_weights(np, BOOT_ITEMS)
    return [{"user_id": int(rng.integers(0, 512)),
             "gender": int(rng.integers(0, 3)),
             "age": int(rng.integers(0, 7)),
             "genre": int(rng.integers(0, 18)),
             "history": rng.choice(BOOT_ITEMS, size=HISTORY_LEN, p=w)}
            for _ in range(CLUSTERS)]


def _queries(np, n_queries: int, seed_tag: int):
    """Deterministic Zipf-skewed query stream (regenerated in each cell)."""
    rng = np.random.default_rng([SEED, 7, seed_tag])
    protos = _protos(np)
    pick = rng.choice(CLUSTERS, size=n_queries, p=_zipf_weights(np, CLUSTERS))
    return [protos[int(i)] for i in pick]


def _proto_centers(engine):
    """Cluster centers = the prototype users' real filtering embeddings."""
    import numpy as np

    from repro.serving.batcher import MicroBatcher
    from repro.serving.hot_cache import CacheStats
    from repro.serving.recsys_engine import lookup_step

    mb = MicroBatcher(engine, max_batch=CLUSTERS)
    batch = mb._stack(_protos(np), CLUSTERS)
    u, _, _ = lookup_step(engine, batch, CacheStats.zero())
    return np.asarray(u)


def write_catalog(directory: str, n_items: int) -> None:
    """Stream the n_items catalog to a base shard (O(chunk) resident)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.lsh import lsh_signature
    from repro.core.nns import SUMMARY_BLOCK_ROWS, build_block_summary
    from repro.core.quantization import dequantize_rowwise, quantize_rowwise
    from repro.serving.tiered import BaseShardWriter

    engine = _bootstrap_engine()
    centers = _proto_centers(engine)  # (CLUSTERS, d)
    writer = BaseShardWriter(directory, n_items, EMBED_DIM, WORDS)
    writer.write(0, np.asarray(engine.item_table_q.values),
                 np.asarray(engine.item_table_q.scales),
                 np.asarray(engine.item_sigs))
    per_cluster = -(-(n_items - BOOT_ITEMS) // CLUSTERS)
    for ci, lo in enumerate(range(BOOT_ITEMS, n_items, WRITE_CHUNK)):
        hi = min(lo + WRITE_CHUNK, n_items)
        rng = np.random.default_rng([SEED, 5, ci])
        cluster = np.minimum((np.arange(lo, hi) - BOOT_ITEMS) // per_cluster,
                             CLUSTERS - 1)
        rows = (centers[cluster]
                + NOISE * rng.standard_normal((hi - lo, EMBED_DIM))
                ).astype(np.float32)
        q = quantize_rowwise(jnp.asarray(rows))
        sigs = lsh_signature(dequantize_rowwise(q), engine.lsh_proj)
        writer.write(lo, np.asarray(q.values), np.asarray(q.scales),
                     np.asarray(sigs))
    # prebuilt summary: the serving cells never fault in every sig page
    summary = build_block_summary(writer._maps["sigs"], SUMMARY_BLOCK_ROWS)
    writer.finish(summary=summary)


def _serve_stream(serve_fn, batches):
    """Serve every batch; returns (digest over items+scores+stats, results)."""
    import numpy as np

    h = hashlib.sha256()
    hits = lookups = 0
    for batch in batches:
        res = serve_fn(batch)
        h.update(np.ascontiguousarray(np.asarray(res.items)).tobytes())
        h.update(np.ascontiguousarray(
            np.asarray(res.topk.scores, np.float32)).tobytes())
        hits += int(res.stats.hits)
        lookups += int(res.stats.lookups)
    h.update(np.asarray([hits, lookups], np.int64).tobytes())
    return h.hexdigest(), hits, lookups


def _reset_peak_rss() -> bool:
    """Reset the kernel's peak-RSS watermark (VmHWM) to current usage so
    the serving phase's peak is measurable above the bootstrap spike.
    Linux-only; returns False (callers fall back to ru_maxrss) elsewhere."""
    try:
        with open("/proc/self/clear_refs", "w") as f:
            f.write("5")
        return True
    except OSError:
        return False


def _peak_rss_bytes() -> int:
    """Peak RSS in bytes — VmHWM (resettable) if available, else ru_maxrss."""
    import resource

    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024


def _cell(mode: str, n_items: int, shard_dir: str) -> dict:
    import gc
    import time

    import numpy as np

    from repro.serving.batcher import MicroBatcher
    from repro.serving.tiered import TieredCatalog

    reps = int(os.environ.get("TIERED_CATALOG_REPS", REPS))
    engine = _bootstrap_engine()
    mb = MicroBatcher(engine, max_batch=BATCH)
    queries = _queries(np, BATCH * N_BATCHES, seed_tag=1)
    batches = [mb._stack_np(queries[i: i + BATCH], BATCH)
               for i in range(0, len(queries), BATCH)]
    # measured traffic drives the tiers: both cells pin the same hot head
    freqs = np.zeros(n_items, np.int64)
    for b in batches:
        hist = np.asarray(b["history"])
        np.add.at(freqs, hist[hist >= 0], 1)

    gc.collect()
    _reset_peak_rss()  # bootstrap spikes don't count against the tiers
    rss0 = _peak_rss_bytes()
    cat = TieredCatalog.open(shard_dir, engine, pool_rows=POOL_ROWS,
                             item_freqs=freqs, delta_capacity=64)
    if mode == "tiered":
        serve_fn = cat.serve
        resident = cat.resident_bytes()
    else:
        ram = cat.to_ram_engine()  # the whole shard, resident

        def serve_fn(b):
            return ram.serve({k: np.asarray(v) for k, v in b.items()})

        resident = int(sum(np.asarray(x).nbytes for x in
                           (ram.item_table_q.values, ram.item_table_q.scales,
                            ram.item_sigs, ram.item_mask)))
        del cat
    t0 = time.perf_counter()
    digest, hits, lookups = _serve_stream(serve_fn, batches)  # + compile
    t1 = time.perf_counter()
    for _ in range(reps):
        _serve_stream(serve_fn, batches)
    steady = (time.perf_counter() - t1) / max(reps, 1)
    rss_delta = _peak_rss_bytes() - rss0
    n_q = len(queries)
    return {"mode": mode, "n": n_items, "status": "ok", "digest": digest,
            "qps": n_q / steady, "us_per_query": 1e6 * steady / n_q,
            "compile_and_first_s": t1 - t0,
            "rss_peak_delta_bytes": int(rss_delta),
            "resident_bytes": resident,
            "cache_hits": hits, "cache_lookups": lookups,
            "n_queries": n_q, "batch": BATCH}


def _spawn_cell(mode: str, n_items: int, shard_dir: str,
                repeats: int) -> dict:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")  # TPU plugin hangs in bare env
    env["TIERED_CATALOG_REPS"] = str(max(repeats, 1))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.tiered_catalog",
         "--cell", mode, str(n_items), shard_dir],
        env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        tail = proc.stderr.strip().splitlines()[-3:]
        print(f"# cell mode={mode} failed (rc={proc.returncode}): "
              f"{' | '.join(tail)}", file=sys.stderr)
        return {"mode": mode, "n": n_items, "status": "failed",
                "returncode": proc.returncode, "stderr_tail": tail}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _derived(row: dict) -> str:
    bits = [f"qps={row['qps']:.1f}",
            f"rss_delta={row['rss_peak_delta_bytes']}",
            f"resident_bytes={row['resident_bytes']}",
            f"cache_hit_rate={row['cache_hits'] / max(row['cache_lookups'], 1):.3f}"]
    if "rss_frac_of_allram" in row:
        bits.append(f"rss_frac_of_allram={row['rss_frac_of_allram']:.3f}")
    if "qps_frac_of_allram" in row:
        bits.append(f"qps_frac_of_allram={row['qps_frac_of_allram']:.2f}")
    if "digest_match" in row:
        bits.append(f"digest_match={row['digest_match']}")
    return ";".join(bits)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=None,
                    help=f"catalog rows (default {N_ITEMS})")
    ap.add_argument("--full", action="store_true",
                    help=f"run the headline {FULL_ITEMS}-item catalog "
                         f"(the nightly lane) with all gates hard")
    ap.add_argument("--repeats", type=int, default=REPS)
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--shard-dir", type=str, default=None,
                    help="where the shard epoch is written (default: a "
                         "fresh temp dir; reused if it already holds one)")
    ap.add_argument("--rss-budget", type=int, default=None, metavar="BYTES",
                    help="additionally exit 1 if the tiered cell's peak "
                         "RSS delta exceeds this absolute budget")
    ap.add_argument("--rss-frac", type=float, default=0.25,
                    help="tiered peak RSS must stay under this fraction "
                         "of the all-RAM cell's (hard assert)")
    ap.add_argument("--min-qps-frac", type=float, default=0.7,
                    help="tiered qps floor as a fraction of all-RAM qps "
                         "(hard assert)")
    ap.add_argument("--cell", nargs=3, metavar=("MODE", "N", "DIR"),
                    help="internal: run one serving cell and print JSON")
    args = ap.parse_args()
    if args.cell:
        print(json.dumps(_cell(args.cell[0], int(args.cell[1]),
                               args.cell[2])))
        return

    from benchmarks.bench_io import (
        check_row_schema,
        csv_rows_to_json,
        write_bench_json,
    )

    n = args.items if args.items is not None else (
        FULL_ITEMS if args.full else N_ITEMS)
    gates_on = n >= GATE_MIN_ITEMS
    root = args.shard_dir or tempfile.mkdtemp(prefix="tiered_catalog_")
    shard_dir = os.path.join(root, f"epoch_0_n{n}")
    if not os.path.exists(os.path.join(shard_dir, "meta.json")):
        print(f"# writing {n}-item shard to {shard_dir}", file=sys.stderr)
        write_catalog(shard_dir, n)
    # TieredCatalog.open expects epoch_* under a root
    cat_root = os.path.join(root, f"catalog_n{n}")
    os.makedirs(cat_root, exist_ok=True)
    link = os.path.join(cat_root, "epoch_0")
    if not os.path.exists(link):
        os.symlink(os.path.abspath(shard_dir), link)

    cells = [_spawn_cell(m, n, cat_root, args.repeats)
             for m in ("allram", "tiered")]
    allram, tiered = cells
    problems = []
    if any(c["status"] != "ok" for c in cells):
        problems.append("cell failed: "
                        + ", ".join(c["mode"] for c in cells
                                    if c["status"] != "ok"))
    else:
        tiered["digest_match"] = tiered["digest"] == allram["digest"]
        tiered["rss_frac_of_allram"] = (
            tiered["rss_peak_delta_bytes"]
            / max(allram["rss_peak_delta_bytes"], 1))
        tiered["qps_frac_of_allram"] = tiered["qps"] / allram["qps"]
        if not tiered["digest_match"]:
            problems.append(
                f"tiered digest {tiered['digest'][:16]} != allram "
                f"{allram['digest'][:16]} — tiering changed served bits")
        if not gates_on:
            print(f"# note: rss/qps fraction gates skipped at n={n} < "
                  f"{GATE_MIN_ITEMS} (fixed jit workspaces dominate; "
                  f"run --full for the hard contract)", file=sys.stderr)
        elif tiered["rss_frac_of_allram"] >= args.rss_frac:
            problems.append(
                f"tiered peak RSS {tiered['rss_peak_delta_bytes']} is "
                f"{tiered['rss_frac_of_allram']:.2f}x all-RAM "
                f"({allram['rss_peak_delta_bytes']}) >= {args.rss_frac}")
        if gates_on and tiered["qps_frac_of_allram"] < args.min_qps_frac:
            problems.append(
                f"tiered qps {tiered['qps']:.1f} is "
                f"{tiered['qps_frac_of_allram']:.2f}x all-RAM "
                f"({allram['qps']:.1f}) < {args.min_qps_frac}")
        if (args.rss_budget is not None
                and tiered["rss_peak_delta_bytes"] >= args.rss_budget):
            problems.append(
                f"tiered peak RSS {tiered['rss_peak_delta_bytes']} >= "
                f"budget {args.rss_budget}")

    out = []
    for row in cells:
        name = f"tiered_catalog/{row['mode']}/n{n}"
        if row["status"] != "ok":
            out.append((name, 0.0, "status=failed"))
        else:
            out.append((name, row["us_per_query"], _derived(row)))
    for name, us, derived in out:
        print(f"{name},{us:.3f},{derived}")
    check_row_schema(csv_rows_to_json(out))
    path = write_bench_json(
        "tiered_catalog", csv_rows_to_json(out), out_dir=args.out,
        cells=cells,
        config={"items": n, "boot_items": BOOT_ITEMS, "clusters": CLUSTERS,
                "radius": RADIUS, "n_candidates": N_CANDIDATES,
                "pool_rows": POOL_ROWS, "hot_rows": HOT_ROWS,
                "batch": BATCH, "n_batches": N_BATCHES,
                "zipf_exponent": ZIPF_EXPONENT, "noise": NOISE,
                "rss_frac": args.rss_frac, "min_qps_frac": args.min_qps_frac,
                "reps": args.repeats})
    print(f"# wrote {path}")
    for p in problems:
        print(f"# CONTRACT VIOLATION: {p}", file=sys.stderr)
    if problems:
        sys.exit(1)
    print(f"# tiered-catalog contract ok (rss "
          f"{tiered['rss_frac_of_allram']:.2f}x, qps "
          f"{tiered['qps_frac_of_allram']:.2f}x all-RAM, digests match)")


if __name__ == "__main__":
    main()
