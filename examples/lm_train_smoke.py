"""Train a ~100M-param LM for a few hundred steps with the full production
stack: fault-tolerant TrainLoop, atomic checkpointing (+auto-resume),
background-prefetched data pipeline, gradient accumulation, remat, chunked
cross-entropy, AdamW.

  PYTHONPATH=src python examples/lm_train_smoke.py --steps 200
  (re-run the same command to watch it resume from the checkpoint)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.data.lm_data import PrefetchIterator, synthetic_token_stream
from repro.distributed import training as tr
from repro.distributed.fault_tolerance import FaultPolicy, TrainLoop


def small_lm() -> ModelConfig:
    # ~100M params: 12L x 512d x 8H, vocab 8192
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=8192,
        dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", type=str, default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = small_lm()
    pcfg = ParallelConfig(remat="block", logit_chunk=64,
                          grad_accum={"smoke": 2})
    shape = ShapeConfig("smoke", "train", args.seq, args.batch)

    from repro.configs.base import param_count_dense
    print(f"model: {cfg.name} ~{param_count_dense(cfg)/1e6:.0f}M params")

    step_fn = jax.jit(tr.make_train_step(cfg, pcfg, shape, base_lr=3e-4,
                                         warmup=20, total_steps=args.steps),
                      donate_argnums=0)

    def batches():
        stream = synthetic_token_stream(cfg.vocab_size, args.seq,
                                        args.batch, seed=0)
        accum = pcfg.accum_for("smoke")
        mb = args.batch // accum
        for item in stream:
            yield {
                "tokens": jnp.asarray(
                    item["tokens"].reshape(accum, mb, args.seq)),
                "labels": jnp.asarray(
                    item["labels"].reshape(accum, mb, args.seq)),
            }

    data = PrefetchIterator(batches(), depth=4)
    ckpt = Checkpointer(args.ckpt, keep=2, async_=True)
    loop = TrainLoop(step_fn, ckpt, FaultPolicy(checkpoint_every=50))

    state, start = loop.resume_or_init(
        lambda: tr.init_train_state(cfg, pcfg, jax.random.key(0)))
    print(f"starting at step {start}")

    class LoggingData:
        def __iter__(self):
            return self

        def __next__(self):
            return next(data)

    final, end = loop.run(state, LoggingData(), args.steps,
                          start_step=start)
    losses = [r.metrics["loss"] for r in loop.records]
    if losses:
        print(f"steps {start}->{end}; loss {losses[0]:.3f} -> "
              f"{losses[-1]:.3f} (structured stream: should fall)")
    if loop.straggler_events:
        print("straggler steps:", loop.straggler_events)


if __name__ == "__main__":
    main()
