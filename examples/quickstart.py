"""Quickstart: the iMARS primitives in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py

Builds a small quantized embedding store, runs fused lookups/pooling, LSH +
fixed-radius Hamming NNS, threshold top-k, and prints what the iMARS fabric
would spend per query (the paper's Tables I-III composed live).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm, mapping
from repro.core.embedding import embedding_bag, init_table, table_to_dense
from repro.core.lsh import lsh_signature, make_lsh_projections
from repro.core.nns import fixed_radius_nns
from repro.core.topk import threshold_topk


def main():
    key = jax.random.key(0)
    print("== iMARS quickstart ==")

    # 1. int8 embedding table (one CMA bank) + fused pooled lookups
    table = init_table(key, n_rows=3000, dim=32)
    ids = jnp.array([[3, 17, 256, -1], [7, -1, -1, -1]])
    pooled = embedding_bag(table, ids, mode="sum")
    print(f"pooled lookups: ids {ids.shape} -> {pooled.shape}, "
          f"table stored int8 ({table.values.dtype})")

    # 2. LSH signatures + TCAM-style fixed-radius NNS
    proj = make_lsh_projections(jax.random.key(1), 32, 256)
    item_sigs = lsh_signature(table_to_dense(table), proj)
    user_vec = table_to_dense(table)[42:43] * 1.05 + 0.005 * jax.random.normal(
        jax.random.key(3), (1, 32))
    query_sig = lsh_signature(user_vec, proj)
    res = fixed_radius_nns(query_sig, item_sigs, radius=64, max_candidates=8)
    print(f"NNS: query matches {int(res.counts[0])} items within r=64; "
          f"nearest: id={int(res.indices[0, 0])} d={int(res.distances[0, 0])}")

    # 3. CTR-buffer threshold top-k
    ctr = jax.nn.sigmoid(jax.random.normal(jax.random.key(2), (1, 8)))
    top = threshold_topk(ctr, threshold=0.5, k=3)
    print(f"threshold top-k: {int(top.counts[0])} above 0.5 -> "
          f"{np.asarray(top.indices[0]).tolist()}")

    # 4. what the FeFET fabric would spend (paper Tables I-III)
    ml = mapping.movielens_mapping()
    print(f"\nTable I mapping (MovieLens): {ml.banks} banks / {ml.mats} mats"
          f" / {ml.cmas} CMAs  (paper: 7/8/54)")
    t3 = cm.table3_model()
    for stage, row in t3.items():
        print(f"Table III {stage:12s}: {row['model_latency_us']:.3f} us "
              f"{row['model_energy_uj']:.3f} uJ  "
              f"(paper: {row['paper_latency_us']:.2f} us "
              f"{row['paper_energy_uj']:.2f} uJ)")
    e2e = cm.end_to_end_movielens()
    print(f"end-to-end: {e2e['imars_qps']:.0f} qps, "
          f"{e2e['latency_speedup']:.1f}x latency / "
          f"{e2e['energy_reduction']:.0f}x energy vs GPU "
          f"(paper: 22025 qps, 16.8x / 713x)")


if __name__ == "__main__":
    main()
