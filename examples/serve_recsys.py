"""Serve a trained RecSys with batched requests through the full iMARS
pipeline (filtering NNS -> ranking -> CTR threshold top-k), reporting both
measured software throughput and the hardware cost model's per-query
latency/energy (the 22,025 qps / 16.8x / 713x headline numbers).

  PYTHONPATH=src python examples/serve_recsys.py [--batches 20]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.data import synthetic
from repro.serving.recsys_engine import RecSysEngine
from examples.train_recsys import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=1000)
    ap.add_argument("--items", type=int, default=600)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=20)
    args = ap.parse_args()

    data = synthetic.make_movielens(n_users=args.users, n_items=args.items)
    print("== training (quick) ==")
    params, cfg = train(data, args.steps)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=50,
                                top_k=10)

    serve = jax.jit(lambda b: engine.serve(b)[0])
    rng = np.random.default_rng(0)

    def make_batch():
        idx = rng.integers(0, data.n_users, args.batch)
        return {
            **{k: jnp.asarray(v[idx]) for k, v in data.user_feats.items()},
            "history": jnp.asarray(data.histories[idx]),
            "genre": jnp.asarray(data.genres[idx]),
        }

    # warmup + serve
    out = serve(make_batch())
    jax.block_until_ready(out)
    t0 = time.time()
    served = 0
    for _ in range(args.batches):
        out = serve(make_batch())
        served += args.batch
    jax.block_until_ready(out)
    dt = time.time() - t0

    print(f"\nserved {served} queries in {dt:.2f}s "
          f"({served / dt:.0f} qps measured on THIS CPU — software path)")
    e2e = cm.end_to_end_movielens(n_candidates=50)
    print(f"iMARS fabric model: {e2e['imars_qps']:.0f} qps/query-engine, "
          f"{e2e['imars_latency_us']:.1f} us, {e2e['imars_energy_uj']:.1f} uJ"
          f" per query -> {e2e['latency_speedup']:.1f}x / "
          f"{e2e['energy_reduction']:.0f}x vs the paper's GPU baseline")
    print("sample recommendations (first 3 users):")
    print(np.asarray(out)[:3])


if __name__ == "__main__":
    main()
