"""Serve a trained RecSys through the batched iMARS serving subsystem:
single-user queries go into the micro-batching queue, get bucketed into
fixed batch shapes, and run through one jitted serve step (hot-row-cached
UIET/ItET lookups -> filtering NNS -> ranking -> CTR threshold top-k).
Reports measured software throughput, the hot-cache hit rate, and the
hardware cost model's per-query latency/energy (the 22,025 qps / 16.8x /
713x headline numbers).

Every front-end is constructed through the one factory —
``make_server(engine, mode, **knobs)`` (docs/SERVING.md):

  * ``--mode sync``       the synchronous micro-batcher (default);
  * ``--mode pipelined``  the ring of in-flight buckets dispatched through
    the staged lookup -> scan -> rank steps, overlapping host-side
    batching with the device's NNS scan (bit-identical results; see
    benchmarks/async_serving.py for the measured speedup);
  * ``--mode concurrent`` the threaded multi-tenant front-end: bounded
    per-tenant queues + load shedding over the pipelined ring
    (bit-identical for every admitted query).

  PYTHONPATH=src python examples/serve_recsys.py [--queries 2000]
      [--mode sync|pipelined|concurrent] [--depth 2]
      [--prune on|off|auto] [--scan-block N] [--report]

``--prune`` drives the engine's block-summary pruning knob (`auto` prunes
whenever the scan streams; results are bit-identical either way) and
``--scan-block`` forces the streaming plan — the demo catalog is small
enough to route dense by default, where pruning never engages. The summary
line reports the mean summary blocks touched per query on a sample batch.
``--report`` prints the per-stage latency breakdown of the timed run from
the front-end's ticket span chains (docs/OBSERVABILITY.md).
"""
import argparse
import time

import numpy as np

from repro.core import cost_model as cm
from repro.data import synthetic
from repro.serving import RecSysEngine, make_server
from examples.train_recsys import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=1000)
    ap.add_argument("--items", type=int, default=600)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--hot-rows", type=int, default=128)
    ap.add_argument("--mode", choices=("sync", "pipelined", "concurrent"),
                    default="sync", help="front-end (make_server mode)")
    ap.add_argument("--pipeline", action="store_true",
                    help="deprecated alias for --mode pipelined")
    ap.add_argument("--depth", type=int, default=2,
                    help="in-flight ring depth (pipelined/concurrent)")
    ap.add_argument("--prune", choices=("on", "off", "auto"), default="auto",
                    help="block-summary pruning: on/off/auto "
                         "(auto prunes whenever the scan streams)")
    ap.add_argument("--scan-block", type=int, default=None,
                    help="streaming scan chunk (None routes by catalog "
                         "size; set e.g. 128 to stream the small demo "
                         "catalog so pruning engages)")
    ap.add_argument("--report", action="store_true",
                    help="print the per-stage latency breakdown of the "
                         "timed run (from the ticket span chains)")
    args = ap.parse_args()
    if args.pipeline:
        args.mode = "pipelined"

    data = synthetic.make_movielens(n_users=args.users, n_items=args.items)
    print("== training (quick) ==")
    params, cfg = train(data, args.steps)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=data.n_items)
    prune = {"on": True, "off": False, "auto": None}[args.prune]
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=50,
                                top_k=10, hot_rows=args.hot_rows,
                                item_freqs=freqs, prune=prune,
                                scan_block=args.scan_block)
    knobs = ({} if args.mode == "sync" else {"depth": args.depth})
    batcher = make_server(engine, args.mode, max_batch=args.batch, **knobs)
    if args.mode != "sync":
        print(f"== {args.mode} serving (ring depth {args.depth}) ==")

    rng = np.random.default_rng(0)

    def make_query(i):
        return {
            **{k: v[i] for k, v in data.user_feats.items()},
            "history": data.histories[i],
            "genre": data.genres[i],
        }

    # warmup: compile every bucket shape the timed run will hit
    # (full batches + the leftover-tail bucket)
    warm_sizes = {args.batch}
    if args.queries % args.batch:
        warm_sizes.add(args.queries % args.batch)
    for size in warm_sizes:
        batcher.serve_many([make_query(i) for i in
                            rng.integers(0, data.n_users, size)])
    # reset batch counters so the report covers the timed run only (the
    # concurrent front-end keeps its counters on the inner ring server);
    # draining the trace buffer drops the warmup tickets' span chains too
    counters = getattr(batcher, "_inner", batcher)
    counters.n_batches = counters.n_served = counters.n_padded = 0
    batcher.take_trace()

    idx = rng.integers(0, data.n_users, args.queries)
    t0 = time.time()
    served = batcher.serve_many([make_query(i) for i in idx])
    dt = time.time() - t0

    print(f"\nserved {len(served)} queries in {dt:.2f}s "
          f"({len(served) / dt:.0f} qps measured on THIS CPU — software path)")
    stats = batcher.stats()
    # blocks-touched sample: one filter-stage batch through the engine
    # directly (the front-ends consume the NNSResult before returning)
    sample = [make_query(i) for i in idx[: min(8, len(idx))]]
    batch = {k: np.stack([q[k] for q in sample]) for k in sample[0]}
    nns = engine.filter_stage(batch)
    if nns.blocks_touched is not None:
        nb = engine.block_summary.n_blocks
        bt = np.asarray(nns.blocks_touched)
        prune_note = (f"blocks touched {bt.mean():.1f}/{nb} per query "
                      f"(scan_frac {bt.mean() / nb:.3f})")
    else:
        prune_note = "pruning inactive (dense plan or --prune off)"
    print(f"micro-batches: {stats['n_batches']}, "
          f"padding fraction {stats['padding_fraction']:.3f}, "
          f"hot-cache hit rate {stats['cache_hit_rate']:.3f}, "
          f"{prune_note}")
    if args.report:
        from tools.obs_report import render_breakdown, stage_breakdown
        print("\n== per-stage breakdown (timed run) ==")
        print(render_breakdown(stage_breakdown(batcher.take_trace())))
    batcher.close()
    e2e = cm.end_to_end_movielens(n_candidates=50)
    print(f"iMARS fabric model: {e2e['imars_qps']:.0f} qps/query-engine, "
          f"{e2e['imars_latency_us']:.1f} us, {e2e['imars_energy_uj']:.1f} uJ"
          f" per query -> {e2e['latency_speedup']:.1f}x / "
          f"{e2e['energy_reduction']:.0f}x vs the paper's GPU baseline")
    print("sample recommendations (first 3 users):")
    print(np.stack([s.items for s in served[:3]]))


if __name__ == "__main__":
    main()
