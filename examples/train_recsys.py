"""End-to-end driver (the paper's workload): train YoutubeDNN on synthetic
MovieLens-1M, then reproduce the Sec. IV-B accuracy study — HR@10 under
(1) fp32 + cosine, (2) int8 + cosine, (3) int8 + LSH-Hamming (iMARS).

  PYTHONPATH=src python examples/train_recsys.py [--users 2000] [--steps 400]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import synthetic
from repro.models import recsys as rs
from repro.serving.recsys_engine import RecSysEngine, hit_rate


def train(data, steps: int, seed: int = 0):
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=data.histories.shape[1])
    params = rs.init_youtubednn(jax.random.key(seed), cfg)
    fil = jax.jit(jax.value_and_grad(lambda p, b: rs.filtering_loss(p, cfg, b)))
    rnk = jax.jit(jax.value_and_grad(lambda p, b: rs.ranking_loss(p, cfg, b)))
    t0 = time.time()
    for i, batch in enumerate(synthetic.movielens_batches(data, 256, steps)):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, g = fil(params, b)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.05 * gg, params, g)
        if i % 100 == 0:
            print(f"  filtering step {i:4d} loss {float(loss):.4f}")
    for i, batch in enumerate(
            synthetic.movielens_rank_batches(data, 128, 16, steps // 2)):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, g = rnk(params, b)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.05 * gg, params, g)
        if i % 100 == 0:
            print(f"  ranking   step {i:4d} loss {float(loss):.4f}")
    print(f"  trained in {time.time() - t0:.1f}s")
    return params, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=2000)
    ap.add_argument("--items", type=int, default=1000)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--radius", type=int, default=112)
    args = ap.parse_args()

    print("== generating synthetic MovieLens ==")
    data = synthetic.make_movielens(n_users=args.users, n_items=args.items)
    print("== training YoutubeDNN ==")
    params, cfg = train(data, args.steps)

    print("== accuracy study (paper Sec. IV-B) ==")
    engine = RecSysEngine.build(params, cfg, radius=args.radius,
                                n_candidates=64)
    rows = []
    for mode, label in (("fp32", "FP32 + cosine"),
                        ("int8", "int8 + cosine"),
                        ("lsh", "int8 + LSH-Hamming (iMARS)")):
        hr = hit_rate(engine, data, k=10, mode=mode)
        rows.append((label, hr))
        print(f"  HR@10 {label:28s}: {hr:.3f}")
    print("\npaper (real MovieLens-1M): 26.8% / 26.2% / 20.8% — synthetic "
          "data reproduces the ORDERING and the small-int8/larger-LSH drops")


if __name__ == "__main__":
    main()
