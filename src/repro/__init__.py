"""repro: iMARS (In-Memory-Computing for Recommendation Systems) on TPU, in JAX.

Pillar A: a faithful reproduction of the iMARS paper — quantized embedding
tables, LSH + fixed-radius Hamming NNS, hierarchical pooled reduction, the
two-stage RecSys pipeline (YoutubeDNN / DLRM) and the hardware cost model that
reproduces the paper's Tables I-III and end-to-end claims.

Pillar B: the paper's technique as a first-class feature of a multi-pod
training/serving framework: 10 LM architectures, pjit/GSPMD distribution
(DP/FSDP/TP/SP/EP + pod axis), int8 KV caches, fault-tolerant training,
dry-run + roofline tooling.
"""

__version__ = "1.0.0"
