"""Fault-tolerant checkpointing.

Design (multi-host-aware, exercised single-process here):
  * layout: <dir>/step_<N>/ with one .npy per pytree leaf (path-encoded
    filenames) + manifest.json (treedef fingerprint, shapes, dtypes,
    framework version).
  * atomicity: writes go to step_<N>.tmp-<nonce>/, fsync'd, then one
    os.rename — a crashed save can never shadow a good checkpoint, and
    `latest_step` only believes directories containing a COMMITTED marker.
  * elastic resume: leaves are stored as full logical arrays; restoring
    onto a different mesh/sharding is just device_put with the new
    sharding (resharding is free at load). On real multi-host, each
    process writes its addressable shards (process_index suffix) and the
    manifest records the global shape — the single-process path below is
    the process-0 slice of that protocol.
  * async: `Checkpointer(async_=True)` snapshots to host memory
    (device_get) synchronously — the step can proceed — and the file I/O
    runs on a background thread; `wait()` joins before the next save.
  * integrity: manifest stores per-leaf CRC32; restore verifies.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
import uuid
import zlib
from typing import Any

import jax
import numpy as np

COMMITTED = "COMMITTED"


def _leaf_filename(path_parts: list[str]) -> str:
    safe = "__".join(re.sub(r"[^A-Za-z0-9_.-]", "_", p) for p in path_parts)
    return f"{safe}.npy"


def _path_parts(keypath) -> list[str]:
    parts = []
    for p in keypath:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return parts


def save(directory: str | os.PathLike, step: int, tree: Any) -> pathlib.Path:
    """Atomic synchronous save."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp-{uuid.uuid4().hex[:8]}"
    tmp.mkdir(parents=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": []}
    for keypath, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = _leaf_filename(_path_parts(keypath))
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / COMMITTED).write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune any orphaned tmp dirs from crashed saves
    for orphan in directory.glob("step_*.tmp-*"):
        shutil.rmtree(orphan, ignore_errors=True)
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    best = None
    for d in directory.glob("step_*"):
        if not d.is_dir() or ".tmp-" in d.name:
            continue
        if not (d / COMMITTED).exists():
            continue
        m = re.match(r"step_(\d+)$", d.name)
        if m:
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore(directory: str | os.PathLike, step: int, template: Any,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of `template` (arrays or ShapeDtypeStruct).

    `shardings`: optional matching tree of NamedSharding for elastic
    placement onto a (possibly different) mesh.
    """
    directory = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((directory / "manifest.json").read_text())
    by_file = {l["file"]: l for l in manifest["leaves"]}

    leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_kp))
    out = []
    for (keypath, tmpl), shard in zip(leaves_kp, shard_leaves):
        fname = _leaf_filename(_path_parts(keypath))
        if fname not in by_file:
            raise FileNotFoundError(f"checkpoint missing leaf {fname}")
        arr = np.load(directory / fname)
        meta = by_file[fname]
        if verify:
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != meta["crc32"]:
                raise IOError(f"checksum mismatch for {fname}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out)


class Checkpointer:
    """Step-managed checkpointer with optional async I/O and retention."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3,
                 async_: bool = False):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self.async_ = async_
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any):
        self.wait()
        # snapshot to host synchronously — device buffers may be donated
        # by the very next step
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        if not self.async_:
            save(self.directory, step, host_tree)
            self._retain()
            return

        def _run():
            try:
                save(self.directory, step, host_tree)
                self._retain()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def _retain(self):
        steps = sorted(
            int(re.match(r"step_(\d+)$", d.name).group(1))
            for d in self.directory.glob("step_*")
            if d.is_dir() and ".tmp-" not in d.name
            and re.match(r"step_(\d+)$", d.name)
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.directory)

    def restore_latest(self, template: Any, shardings: Any = None):
        self.wait()
        step = self.latest()
        if step is None:
            return None, None
        return step, restore(self.directory, step, template, shardings)
