"""Arch configs: 10 assigned architectures + the paper's RecSys models."""
from repro.configs.base import (  # noqa: F401
    ArchBundle,
    ModelConfig,
    ParallelConfig,
    SHAPES,
    ShapeConfig,
)
from repro.configs.registry import ARCH_IDS, all_arches, get_arch  # noqa: F401
