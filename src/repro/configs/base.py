"""Config dataclasses: model architecture, parallelism plan, shapes."""
from __future__ import annotations

import dataclasses
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # attention variants
    rope_theta: float = 1e4
    rope_fraction: float = 1.0  # chatglm3: 0.5 (2d/partial rotary)
    rope_style: str = "standard"  # standard | mrope
    mrope_sections: tuple = ()  # qwen2-vl: (t, h, w) half-dim split
    qk_norm: bool = False  # qwen3
    qkv_bias: bool = False  # qwen2.5 / qwen2-vl
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "swiglu"  # swiglu | gelu
    # moe
    n_experts: int = 0
    moe_top_k: int = 0
    moe_layer_step: int = 1  # llama4-maverick: 2 (alternating dense/MoE)
    n_shared_experts: int = 0  # llama4: 1
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # ssm (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    # hybrid (zamba2): shared attention block every k mamba layers
    attn_every: int = 0
    # audio (musicgen)
    n_codebooks: int = 1
    # vlm (qwen2-vl): inputs include pre-computed patch embeddings (stub
    # frontend per the assignment)
    vision_tokens: int = 0
    # numerics
    dtype: str = "bfloat16"
    # embedding tables padded to a multiple (vocab-parallel divisibility;
    # the padded logit tail is masked in unembed)
    vocab_pad_multiple: int = 128
    # set by the launch layer so GQA kv heads shard exactly over the model
    # axis (kv repeated to n_kv_heads * kv_repeat contiguous heads)
    kv_repeat: int = 1
    # perf (§Perf iteration 1): constrain kv to the sequence-gathered layout
    # BEFORE the head-repeat so GSPMD emits a targeted all-gather instead of
    # an involuntary full rematerialization (replicate + repartition)
    opt_kv_layout: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rep_kv_heads(self) -> int:
        """KV heads after mesh-driven repetition (shardable by model axis)."""
        return self.n_kv_heads * self.kv_repeat

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How an arch maps onto the mesh (chosen per arch in its config file)."""

    fsdp: bool = False  # ZeRO-3: params sharded over data axis
    seq_shard: bool = False  # sequence-parallel residuals
    serve_weight_sharding: str = "tp"  # "tp" | "2d" (>=70B decode)
    remat: str = "block"  # none | block (checkpoint each layer)
    kv_cache_dtype: str = "bfloat16"  # "int8" = the paper's ET quantization
    opt_state_dtype: str = "float32"  # float32 | bfloat16 | int8
    grad_accum: Mapping[str, int] = dataclasses.field(
        default_factory=lambda: {"train_4k": 1}
    )
    logit_chunk: int = 0  # chunked vocab-sharded CE (0 = unchunked)
    grad_compression: bool = False  # int8 cross-pod gradient allreduce
    moe_shard_ff: bool = False  # §Perf: expert FF dim over data (no gathers)

    def accum_for(self, shape_name: str) -> int:
        return dict(self.grad_accum).get(shape_name, 1)

    def with_(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    model: ModelConfig
    parallel: ParallelConfig
    # shapes this arch skips (with reasons), per the assignment rules
    skip_shapes: Mapping[str, str] = dataclasses.field(default_factory=dict)


def param_count_dense(cfg: ModelConfig) -> int:
    """Approximate parameter count (embeddings + layers), for roofline N."""
    d, v = cfg.d_model, cfg.vocab_size
    n = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family in ("ssm",):
        per = _mamba_layer_params(cfg)
        return n + cfg.n_layers * per
    if cfg.family == "hybrid":
        per = _mamba_layer_params(cfg)
        attn = _attn_params(cfg) + _mlp_params(cfg)
        return n + cfg.n_layers * per + attn  # attn block is shared
    per = _attn_params(cfg)
    if cfg.n_experts:
        moe_layers = cfg.n_layers // cfg.moe_layer_step
        dense_layers = cfg.n_layers - moe_layers
        per_moe = cfg.n_experts * _mlp_params(cfg) + cfg.d_model * cfg.n_experts
        per_moe += cfg.n_shared_experts * _mlp_params(cfg)
        return (
            n
            + cfg.n_layers * per
            + dense_layers * _mlp_params(cfg)
            + moe_layers * per_moe
        )
    if cfg.family == "audio":
        n = cfg.n_codebooks * v * d * 2
    return n + cfg.n_layers * (per + _mlp_params(cfg))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k experts only) — for 6*N_active*D."""
    if not cfg.n_experts:
        return param_count_dense(cfg)
    d, v = cfg.d_model, cfg.vocab_size
    n = v * d * 2
    moe_layers = cfg.n_layers // cfg.moe_layer_step
    dense_layers = cfg.n_layers - moe_layers
    per_moe_active = (cfg.moe_top_k + cfg.n_shared_experts) * _mlp_params(cfg)
    return (
        n
        + cfg.n_layers * _attn_params(cfg)
        + dense_layers * _mlp_params(cfg)
        + moe_layers * per_moe_active
    )


def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    if not cfg.n_heads:
        return 0
    return d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2) + 2 * d


def _mlp_params(cfg: ModelConfig) -> int:
    mult = 3 if cfg.act == "swiglu" else 2
    return mult * cfg.d_model * cfg.d_ff


def _mamba_layer_params(cfg: ModelConfig) -> int:
    d, di = cfg.d_model, cfg.d_inner
    conv_dim = di + 2 * cfg.ssm_ngroups * cfg.ssm_state
    in_proj = d * (2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state + cfg.ssm_heads)
    return in_proj + conv_dim * cfg.ssm_conv + di * d + 3 * cfg.ssm_heads + 2 * d + di
