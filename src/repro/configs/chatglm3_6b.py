"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE 2d (partial rotary), GQA [arXiv:2406.12793; hf]."""
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig
from repro.configs.qwen2_vl_72b import FULL_ATTN_SKIP


def model_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65024,
        rope_fraction=0.5,  # chatglm's 2d rope: rotary on half the head dims
        qkv_bias=True,
    )


def bundle() -> ArchBundle:
    return ArchBundle(
        model=model_config(),
        parallel=ParallelConfig(
            seq_shard=True,
            fsdp=False,
            remat="block",
            kv_cache_dtype="int8",
            grad_accum={"train_4k": 1},
            logit_chunk=1024,
        ),
        skip_shapes={"long_500k": FULL_ATTN_SKIP},
    )
