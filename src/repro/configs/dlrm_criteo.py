"""The paper's own workload: Facebook DLRM ranking on Criteo Kaggle.

Bottom MLP 256-128-32, top MLP 256-64-1, 26 ETs x 28000 rows (Table I).
"""
from repro.models.recsys import DLRMConfig


def model_config() -> DLRMConfig:
    return DLRMConfig()
