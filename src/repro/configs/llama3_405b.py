"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA, 128k vocab [arXiv:2407.21783].

The heavyweight: FSDP + TP, int8 optimizer states (blockwise — the paper's
quantization applied to optimizer memory), int8 KV (required to fit
decode_32k on 256 v5e chips), 2D weight sharding for decode, 16-way gradient
accumulation for train_4k.
"""
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig
from repro.configs.qwen2_vl_72b import FULL_ATTN_SKIP


def model_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        family="dense",
        n_layers=126,
        d_model=16384,
        n_heads=128,
        n_kv_heads=8,
        head_dim=128,
        d_ff=53248,
        vocab_size=128256,
        rope_theta=5e5,
        # §Perf iteration 2: SP boundary before kv-repeat (EXPERIMENTS.md)
        opt_kv_layout=True,
    )


def bundle() -> ArchBundle:
    return ArchBundle(
        model=model_config(),
        parallel=ParallelConfig(
            fsdp=True,
            seq_shard=True,
            remat="block",
            kv_cache_dtype="int8",
            opt_state_dtype="int8",
            serve_weight_sharding="2d",
            grad_accum={"train_4k": 4},  # §Perf iteration 3/4
            logit_chunk=512,
            # int8 grad compression is exercised on the smaller archs; the
            # fp32 error-feedback buffer is not worth 405B params of HBM
            grad_compression=False,
        ),
        skip_shapes={"long_500k": FULL_ATTN_SKIP},
    )
