"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, shared expert, alternating
dense/MoE layers [hf:meta-llama/Llama-4-*]. Early-fusion multimodality is a
stub (text tokens only) per the assignment."""
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig
from repro.configs.qwen2_vl_72b import FULL_ATTN_SKIP


def model_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        rope_theta=5e5,
        n_experts=128,
        moe_top_k=1,
        moe_layer_step=2,  # alternating dense / MoE
        n_shared_experts=1,
        capacity_factor=1.25,
    )


def bundle() -> ArchBundle:
    return ArchBundle(
        model=model_config(),
        parallel=ParallelConfig(
            seq_shard=True,
            fsdp=True,
            remat="block",
            kv_cache_dtype="int8",
            opt_state_dtype="int8",
            serve_weight_sharding="2d",
            grad_accum={"train_4k": 2},  # §Perf iteration 3
            logit_chunk=512,
            moe_shard_ff=True,  # §Perf iteration 2: no expert-weight gathers
        ),
        skip_shapes={"long_500k": FULL_ATTN_SKIP},
    )
