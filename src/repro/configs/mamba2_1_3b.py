"""mamba2-1.3b [ssm]: 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060].

Runs long_500k (O(1)-state decode). The paper's NNS/TCAM component is
inapplicable (no retrieval path) — see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_conv=4,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        ssm_ngroups=1,
        tie_embeddings=True,
    )


def bundle() -> ArchBundle:
    return ArchBundle(
        model=model_config(),
        parallel=ParallelConfig(
            seq_shard=True,
            fsdp=False,
            remat="block",
            grad_accum={"train_4k": 1},
            logit_chunk=2048,
        ),
        skip_shapes={},
    )
