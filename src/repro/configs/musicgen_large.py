"""musicgen-large [audio]: 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens, 4 codebooks with delay
pattern [arXiv:2306.05284; hf]. EnCodec itself is a stub per the
assignment; inputs are 4-codebook token grids. The per-step sum of 4
codebook embeddings is the iMARS multi-table pooled ET lookup on the LM
hot path (DESIGN.md §4)."""
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig
from repro.configs.qwen2_vl_72b import FULL_ATTN_SKIP


def model_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        act="gelu",
        n_codebooks=4,
    )


def bundle() -> ArchBundle:
    return ArchBundle(
        model=model_config(),
        parallel=ParallelConfig(
            seq_shard=True,
            fsdp=False,
            remat="block",
            kv_cache_dtype="int8",  # §Perf iteration 1 (iMARS ET quantization)
            grad_accum={"train_4k": 1},
            logit_chunk=0,
        ),
        skip_shapes={"long_500k": FULL_ATTN_SKIP},
    )
