"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf]."""
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig
from repro.configs.qwen2_vl_72b import FULL_ATTN_SKIP


def model_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32064,
        n_experts=16,
        moe_top_k=2,
        moe_layer_step=1,
        capacity_factor=1.25,
        act="gelu",  # phi3.5 uses gated... simplified to gelu experts
    )


def bundle() -> ArchBundle:
    return ArchBundle(
        model=model_config(),
        parallel=ParallelConfig(
            seq_shard=True,
            fsdp=True,
            remat="block",
            kv_cache_dtype="int8",
            grad_accum={"train_4k": 2},
            logit_chunk=1024,
        ),
        skip_shapes={"long_500k": FULL_ATTN_SKIP},
    )
