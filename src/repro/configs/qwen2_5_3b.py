"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA, QKV bias, tied embeddings [hf:Qwen/Qwen2.5-*; hf]."""
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig
from repro.configs.qwen2_vl_72b import FULL_ATTN_SKIP


def model_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        head_dim=128,
        d_ff=11008,
        vocab_size=151936,
        rope_theta=1e6,
        qkv_bias=True,
        tie_embeddings=True,
    )


def bundle() -> ArchBundle:
    return ArchBundle(
        model=model_config(),
        parallel=ParallelConfig(
            seq_shard=True,
            fsdp=False,
            remat="block",
            kv_cache_dtype="bfloat16",
            grad_accum={"train_4k": 1},
            logit_chunk=1024,
        ),
        skip_shapes={"long_500k": FULL_ATTN_SKIP},
    )
