"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Vision frontend is a stub per the assignment: input_specs() provides
precomputed patch embeddings + M-RoPE positions.
"""
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig

FULL_ATTN_SKIP = (
    "long_500k skipped: pure full-attention arch (see DESIGN.md §4)"
)


def model_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        rope_theta=1e6,
        rope_style="mrope",
        mrope_sections=(16, 24, 24),
        qkv_bias=True,
        vision_tokens=256,
    )


def bundle() -> ArchBundle:
    return ArchBundle(
        model=model_config(),
        parallel=ParallelConfig(
            seq_shard=True,
            fsdp=True,
            remat="block",
            kv_cache_dtype="int8",
            opt_state_dtype="int8",
            serve_weight_sharding="2d",
            grad_accum={"train_4k": 4},
            logit_chunk=512,
        ),
        skip_shapes={"long_500k": FULL_ATTN_SKIP},
    )
