"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig
from repro.configs.qwen2_vl_72b import FULL_ATTN_SKIP


def model_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        rope_theta=1e6,
        qk_norm=True,
    )


def bundle() -> ArchBundle:
    return ArchBundle(
        model=model_config(),
        parallel=ParallelConfig(
            seq_shard=True,
            fsdp=False,
            remat="block",
            kv_cache_dtype="int8",
            grad_accum={"train_4k": 1},
            logit_chunk=1024,
        ),
        skip_shapes={"long_500k": FULL_ATTN_SKIP},
    )
