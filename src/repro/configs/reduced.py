"""Reduced-config factory: same family/topology, tiny dims — used by the
per-arch smoke tests (the FULL configs are exercised only via the dry-run)."""
from __future__ import annotations

from repro.configs.base import ModelConfig


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        vocab_size=128,
        dtype="float32",  # smoke tests check numerics, fp32 avoids bf16 noise
    )
    if cfg.n_heads:
        n_kv = 1 if cfg.n_kv_heads == 1 else 2
        kw.update(
            n_heads=4,
            n_kv_heads=min(4, max(n_kv, 4 // max(cfg.q_per_kv, 1))),
            head_dim=16,
            d_ff=128 if cfg.d_ff else 0,
        )
        if cfg.rope_style == "mrope":
            kw.update(mrope_sections=(2, 3, 3))  # sums to head_dim/2
    if cfg.n_experts:
        kw.update(n_experts=4, capacity_factor=2.0)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=8, ssm_chunk=8)
        # d_inner = 128 -> 16 heads of dim 8
    if cfg.attn_every:
        kw.update(n_layers=5, attn_every=2)  # exercises the remainder group
    if cfg.family == "audio":
        kw.update(n_codebooks=2, vocab_size=64)
    if cfg.family == "vlm":
        kw.update(vision_tokens=4)
    return cfg.with_(**kw)
