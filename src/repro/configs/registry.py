"""--arch registry: every assigned architecture + the paper's RecSys configs."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchBundle

ARCH_IDS = (
    "qwen2-vl-72b",
    "chatglm3-6b",
    "qwen3-8b",
    "qwen2.5-3b",
    "llama3-405b",
    "llama4-maverick-400b-a17b",
    "phi3.5-moe-42b-a6.6b",
    "mamba2-1.3b",
    "zamba2-1.2b",
    "musicgen-large",
)

_MODULES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen3-8b": "qwen3_8b",
    "qwen2.5-3b": "qwen2_5_3b",
    "llama3-405b": "llama3_405b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "mamba2-1.3b": "mamba2_1_3b",
    "zamba2-1.2b": "zamba2_1_2b",
    "musicgen-large": "musicgen_large",
}


def get_arch(arch_id: str) -> ArchBundle:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.bundle()


def all_arches() -> dict[str, ArchBundle]:
    return {a: get_arch(a) for a in ARCH_IDS}
