"""The paper's own workload: YoutubeDNN on MovieLens (filtering + ranking).

Model/ET dims from Table I; see models/recsys.py and core/mapping.py.
"""
from repro.models.recsys import YoutubeDNNConfig, default_youtubednn_config


def model_config() -> YoutubeDNNConfig:
    return default_youtubednn_config()
