"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]. Shared block applied before every 6 mamba layers
(+ once for the 2-layer remainder): 7 invocations, weights shared,
per-invocation KV cache. Runs long_500k (hybrid; decode attention uses the
sharded flash-decode path)."""
from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig


def model_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        ssm_conv=4,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        ssm_ngroups=1,
        attn_every=6,
    )


def bundle() -> ArchBundle:
    return ArchBundle(
        model=model_config(),
        parallel=ParallelConfig(
            seq_shard=True,
            fsdp=False,
            remat="block",
            kv_cache_dtype="int8",
            grad_accum={"train_4k": 1},
            logit_chunk=2048,
        ),
        skip_shapes={},
    )
