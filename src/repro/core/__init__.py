"""iMARS core: the paper's contribution as composable JAX modules.

quantization - int8 ET format (row-wise) + blockwise int8 (optimizer/grads)
lsh          - SRP signatures packed to uint32 lanes
nns          - fixed-radius Hamming NNS (TCAM analogue) + cosine refs
embedding    - quantized embedding-bag engine (CMA RAM mode + adders)
hierarchy    - two-level sharded reduction (intra-mat / intra-bank adder trees)
topk         - CTR-buffer threshold top-k
mapping      - Table I bank/mat/CMA mapping
cost_model   - Table II FoMs composed into Table III + end-to-end claims
"""
