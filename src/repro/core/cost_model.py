"""iMARS hardware cost model — reproduces Tables II & III and the end-to-end
claims (16.8x/713x MovieLens, 13.2x/57.8x Criteo) from array-level FoMs.

Structure (everything per one query input, like the paper):

  ET lookup stage (Table III):
    latency = H*(t_read + t_add)                 # worst case: H pooled lookups
              + t_intramat + rounds*t_intrabank  # adder-tree hierarchy
              + (n_ets + 1) * t_rsc              # serialized RSC transfers
    energy  = sum_lookups*(e_read + e_add + e_write)
              + per-ET adder energies
              + n_shots * e_shot(banks)          # bus/communication energy

  NNS (Sec. IV-C2): one parallel TCAM search over the signature CMAs.
  Crossbar DNN: ceil-tiled 256x128 MVMs, serialized per layer over the RSC.

Calibration (the paper gives Table II FoMs and Table I mapping but not the
communication constants or the pooling multiplicity; we fit FOUR global
constants against the SIX Table III observations and report residuals):

    t_rsc   = 6.3963 ns / 256-bit RSC transfer   (exact on Criteo latency)
    e_shot(b) = 21901 + 1164.9 * b  pJ / shot    (bus energy grows with bank
                                                  count = wire length; exact on
                                                  ML-filter + Criteo energy)
    H_ml    = 12 pooled lookups / query          (MovieLens history pooling)
    e_prio  = 5191 pJ                            (NNS priority encode + drive)

  Residuals on the held-out entries: ML-filter latency +2.6%, ML-rank latency
  +0.4%, ML-rank energy +0.3% — see tests/test_cost_model.py.

End-to-end (Sec. IV-C3): the iMARS side is structural (components above +
one calibrated per-candidate controller overhead t_ctrl = 447.7 ns); the GPU
side uses the paper's measured Table III entries plus *paper-implied* GPU DNN
costs derived from the published end-to-end ratios (the paper never lists GPU
DNN times separately). Both are labeled in the benchmark output.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import mapping as mp
from repro.utils import cdiv

# ---------------------------------------------------------------------------
# Table II — array-level figures of merit (energy pJ, latency ns)
# ---------------------------------------------------------------------------
ARRAY_FOM = {
    "cma_write": (49.1, 10.0),
    "cma_read": (3.2, 0.3),
    "cma_add": (108.0, 8.1),
    "cma_search": (13.8, 0.2),
    "intramat_add": (137.0, 14.7),
    "intrabank_add": (956.0, 44.2),
    "xbar_matmul": (13.8, 225.0),  # 256x128 crossbar
}

XBAR_IN, XBAR_OUT = 256, 128


@dataclasses.dataclass(frozen=True)
class Calibration:
    t_rsc_ns: float = 6.3963  # per 256-bit RSC transfer
    e_shot_base_pj: float = 21901.0  # bus energy intercept
    e_shot_per_bank_pj: float = 1164.9  # bus energy slope vs bank count
    history_lookups: int = 12  # MovieLens pooled lookups / query
    e_priority_pj: float = 5191.4  # NNS priority encoder + SL drivers
    t_ctrl_ns: float = 447.68  # per-candidate controller overhead


CAL = Calibration()


def e_shot(banks: int, cal: Calibration = CAL) -> float:
    return cal.e_shot_base_pj + cal.e_shot_per_bank_pj * banks


@dataclasses.dataclass(frozen=True)
class OpCost:
    latency_ns: float
    energy_pj: float

    @property
    def latency_us(self):
        return self.latency_ns / 1e3

    @property
    def energy_uj(self):
        return self.energy_pj / 1e6

    def __add__(self, other: "OpCost") -> "OpCost":
        return OpCost(self.latency_ns + other.latency_ns,
                      self.energy_pj + other.energy_pj)

    def scale(self, k: float) -> "OpCost":
        return OpCost(self.latency_ns * k, self.energy_pj * k)


ZERO = OpCost(0.0, 0.0)


# ---------------------------------------------------------------------------
# ET lookup + pooling (Table III rows)
# ---------------------------------------------------------------------------
def et_lookup_stage_cost(
    ets: Sequence[mp.ETSpec],
    stage: str,
    fabric_banks: int,
    pooled_lookups: int,
    cal: Calibration = CAL,
) -> OpCost:
    """Cost of all ET lookups + pooling for one input in one stage.

    Banks operate in parallel: latency is the dominant (pooled) ET chain plus
    the adder hierarchy plus serialized RSC transfers (one per ET used, +1 to
    deliver the result). Energy sums every lookup and every adder/bus shot.
    """
    used = [e for e in ets if stage in e.stages and e.kind != "ctr"]
    n_ets = len(used)
    e_read, t_read = ARRAY_FOM["cma_read"]
    e_add, t_add = ARRAY_FOM["cma_add"]
    e_write, _ = ARRAY_FOM["cma_write"]
    e_im, t_im = ARRAY_FOM["intramat_add"]
    e_ib, t_ib = ARRAY_FOM["intrabank_add"]

    # --- latency: dominant ET = the pooled one (worst case: same array) ---
    rounds = 1  # intra-bank adder tree rounds for the dominant ET
    latency = (
        pooled_lookups * (t_read + t_add)
        + t_im
        + rounds * t_ib
        + (n_ets + 1) * cal.t_rsc_ns
    )

    # --- energy: every lookup, every ET's adders, every bus shot ---
    total_lookups = pooled_lookups + (n_ets - 1)  # 1 lookup per non-pooled ET
    e_ops = total_lookups * (e_read + e_add + e_write)
    e_adders = 0.0
    n_ibc_shots = 0
    for et in used:
        mats = et.n_mats
        e_adders += e_im * mats + e_ib * max(1, cdiv(max(mats - 1, 1), 3))
        n_ibc_shots += mats
    n_shots = (n_ets + 1) + n_ibc_shots
    energy = e_ops + e_adders + n_shots * e_shot(fabric_banks, cal)
    return OpCost(latency, energy)


def nns_cost(sig_cmas: int, cal: Calibration = CAL) -> OpCost:
    """TCAM threshold search: all signature CMAs searched in parallel."""
    e_s, t_s = ARRAY_FOM["cma_search"]
    return OpCost(t_s, e_s * sig_cmas + cal.e_priority_pj)


def ctr_topk_cost(cal: Calibration = CAL) -> OpCost:
    """CTR-buffer threshold match + one RSC transfer."""
    e_s, t_s = ARRAY_FOM["cma_search"]
    return OpCost(t_s + cal.t_rsc_ns, e_s + e_shot(7, cal))


def crossbar_mlp_cost(dims: Sequence[int], fabric_banks: int,
                      cal: Calibration = CAL) -> OpCost:
    """Serialized crossbar MLP: per layer one tiled MVM + one RSC transfer."""
    e_x, t_x = ARRAY_FOM["xbar_matmul"]
    latency = energy = 0.0
    for din, dout in zip(dims[:-1], dims[1:]):
        tiles = cdiv(din, XBAR_IN) * cdiv(dout, XBAR_OUT)
        latency += t_x + cal.t_rsc_ns
        energy += tiles * e_x + e_shot(fabric_banks, cal)
    return OpCost(latency, energy)


# ---------------------------------------------------------------------------
# Paper-measured GPU constants (Table III + Sec. IV-C2) — NOT model outputs
# ---------------------------------------------------------------------------
GPU_PAPER = {
    # stage: (latency_us, energy_uj)
    "ml_filter_et": (9.27, 203.97),
    "ml_rank_et": (9.60, 211.26),
    "criteo_rank_et": (14.97, 329.34),
    "ml_nns_cosine": (13.6, 340.0),  # 0.34 mJ
    "ml_nns_lsh": (6.97, 150.0),  # 0.15 mJ
}

# Paper-implied GPU DNN costs (derived from the published end-to-end ratios;
# the paper does not list them separately — see module docstring).
GPU_IMPLIED = {
    "ml_dnn_filter": (16.536, 455.9),  # us, uJ
    "ml_dnn_rank_per_cand": (5.0, 130.0),
    "criteo_dnn": (12.434, 86.43),
}

PAPER_TABLE3_IMARS = {
    # stage: (latency_us, energy_uj) as published
    "ml_filter": (0.21, 0.40),
    "ml_rank": (0.21, 0.46),
    "criteo_rank": (0.24, 6.88),
}

PAPER_END_TO_END = {
    "ml_qps_gpu": 1311.0,
    "ml_qps_imars": 22025.0,
    "ml_latency_speedup": 16.8,
    "ml_energy_reduction": 713.0,
    "criteo_latency_speedup": 13.2,
    "criteo_energy_reduction": 57.8,
    "nns_latency_speedup": 3.8e4,
    "nns_energy_reduction": 2.8e4,
    "dnn_latency_speedup": 2.69,
}

N_CANDIDATES = 50  # filtering-stage output (paper: O(100) candidates)

# DNN stacks (Table I). MovieLens filtering tower input: 5 UIET embeddings
# (32 each) + pooled history (32) = 192; ranking input: user embedding (32) +
# item (32) + ranking UIETs -> 128 (Table I: "128-1").
ML_FILTER_DNN = (192, 128, 64, 32)
ML_RANK_DNN = (128, 1)
CRITEO_BOTTOM_DNN = (13, 256, 128, 32)
CRITEO_TOP_DNN = (383, 256, 64, 1)  # 27*26/2 pairwise dots + dense 32


# ---------------------------------------------------------------------------
# Table III model outputs
# ---------------------------------------------------------------------------
def movielens_et_costs(cal: Calibration = CAL) -> dict[str, OpCost]:
    ets = mp.MOVIELENS_ETS
    banks = mp.movielens_mapping().banks
    return {
        "ml_filter": et_lookup_stage_cost(
            ets, "filtering", banks, cal.history_lookups, cal),
        "ml_rank": et_lookup_stage_cost(
            ets, "ranking", banks, cal.history_lookups, cal),
    }


def criteo_et_costs(cal: Calibration = CAL) -> dict[str, OpCost]:
    ets = mp.CRITEO_ETS
    banks = mp.criteo_mapping().banks
    return {
        "criteo_rank": et_lookup_stage_cost(ets, "ranking", banks, 1, cal),
    }


def table3_model(cal: Calibration = CAL) -> dict[str, dict]:
    """Model vs paper for every Table III iMARS entry."""
    model = {**movielens_et_costs(cal), **criteo_et_costs(cal)}
    out = {}
    for stage, cost in model.items():
        p_lat, p_en = PAPER_TABLE3_IMARS[stage]
        g_lat, g_en = GPU_PAPER[stage + "_et"]
        out[stage] = {
            "model_latency_us": cost.latency_us,
            "paper_latency_us": p_lat,
            "latency_rel_err": cost.latency_us / p_lat - 1.0,
            "model_energy_uj": cost.energy_uj,
            "paper_energy_uj": p_en,
            "energy_rel_err": cost.energy_uj / p_en - 1.0,
            "speedup_vs_gpu": g_lat / cost.latency_us,
            "energy_reduction_vs_gpu": g_en / cost.energy_uj,
        }
    return out


def ml_nns_model(cal: Calibration = CAL) -> dict:
    sig_cmas = cdiv(3000, mp.CMA_ROWS)  # signature columns of the ItET
    cost = nns_cost(sig_cmas, cal)
    g_lat, g_en = GPU_PAPER["ml_nns_lsh"]
    return {
        "model_latency_us": cost.latency_us,
        "model_energy_uj": cost.energy_uj,
        "latency_speedup": g_lat / cost.latency_us,
        "energy_reduction": g_en / cost.energy_uj,
        "paper_latency_speedup": PAPER_END_TO_END["nns_latency_speedup"],
        "paper_energy_reduction": PAPER_END_TO_END["nns_energy_reduction"],
    }


# ---------------------------------------------------------------------------
# End-to-end (Sec. IV-C3)
# ---------------------------------------------------------------------------
def end_to_end_movielens(
    n_candidates: int = N_CANDIDATES, cal: Calibration = CAL
) -> dict:
    banks = mp.movielens_mapping().banks
    et = movielens_et_costs(cal)
    sig_cmas = cdiv(3000, mp.CMA_ROWS)

    dnn_f = crossbar_mlp_cost(ML_FILTER_DNN, banks, cal)
    dnn_r = crossbar_mlp_cost(ML_RANK_DNN, banks, cal)
    per_cand = et["ml_rank"] + dnn_r + OpCost(cal.t_ctrl_ns, 0.0)
    imars = (
        et["ml_filter"]
        + nns_cost(sig_cmas, cal)
        + dnn_f
        + per_cand.scale(n_candidates)
        + ctr_topk_cost(cal)
    )

    g_et_f = GPU_PAPER["ml_filter_et"]
    g_et_r = GPU_PAPER["ml_rank_et"]
    g_nns = GPU_PAPER["ml_nns_lsh"]
    g_dnn_f = GPU_IMPLIED["ml_dnn_filter"]
    g_dnn_r = GPU_IMPLIED["ml_dnn_rank_per_cand"]
    gpu_lat_us = (
        g_et_f[0] + g_nns[0] + g_dnn_f[0]
        + n_candidates * (g_et_r[0] + g_dnn_r[0])
    )
    gpu_en_uj = (
        g_et_f[1] + g_nns[1] + g_dnn_f[1]
        + n_candidates * (g_et_r[1] + g_dnn_r[1])
    )
    return {
        "imars_latency_us": imars.latency_us,
        "imars_energy_uj": imars.energy_uj,
        "imars_qps": 1e6 / imars.latency_us,
        "gpu_latency_us": gpu_lat_us,
        "gpu_energy_uj": gpu_en_uj,
        "gpu_qps": 1e6 / gpu_lat_us,
        "latency_speedup": gpu_lat_us / imars.latency_us,
        "energy_reduction": gpu_en_uj / imars.energy_uj,
        "paper_latency_speedup": PAPER_END_TO_END["ml_latency_speedup"],
        "paper_energy_reduction": PAPER_END_TO_END["ml_energy_reduction"],
        "paper_qps_imars": PAPER_END_TO_END["ml_qps_imars"],
        "paper_qps_gpu": PAPER_END_TO_END["ml_qps_gpu"],
    }


def end_to_end_criteo(cal: Calibration = CAL) -> dict:
    banks = mp.criteo_mapping().banks
    et = criteo_et_costs(cal)["criteo_rank"]
    dnn = crossbar_mlp_cost(CRITEO_BOTTOM_DNN, banks, cal) + crossbar_mlp_cost(
        CRITEO_TOP_DNN, banks, cal
    )
    imars = et + dnn + OpCost(cal.t_ctrl_ns, 0.0)

    g_et = GPU_PAPER["criteo_rank_et"]
    g_dnn = GPU_IMPLIED["criteo_dnn"]
    gpu_lat_us = g_et[0] + g_dnn[0]
    gpu_en_uj = g_et[1] + g_dnn[1]
    return {
        "imars_latency_us": imars.latency_us,
        "imars_energy_uj": imars.energy_uj,
        "gpu_latency_us": gpu_lat_us,
        "gpu_energy_uj": gpu_en_uj,
        "latency_speedup": gpu_lat_us / imars.latency_us,
        "energy_reduction": gpu_en_uj / imars.energy_uj,
        "paper_latency_speedup": PAPER_END_TO_END["criteo_latency_speedup"],
        "paper_energy_reduction": PAPER_END_TO_END["criteo_energy_reduction"],
    }


# ---------------------------------------------------------------------------
# Design-space exploration (Sec. III-A1 discussion: B, M, C trade-offs)
# ---------------------------------------------------------------------------
def design_space_lookup_cost(
    n_rows: int,
    pooled_lookups: int,
    cmas_per_mat: int,
    intrabank_fanin: int = 4,
    cal: Calibration = CAL,
) -> OpCost:
    """Latency/energy of one pooled ET lookup as a function of (C, fan-in).

    Larger C -> fewer mats but bigger intra-mat fan-in (the paper models this
    as added parasitic delay: we charge log2(C) gate levels on the tree);
    more mats -> more serialized intra-bank rounds (fan-in 4 per shot).
    """
    e_read, t_read = ARRAY_FOM["cma_read"]
    e_add, t_add = ARRAY_FOM["cma_add"]
    e_write, _ = ARRAY_FOM["cma_write"]
    e_im, t_im = ARRAY_FOM["intramat_add"]
    e_ib, t_ib = ARRAY_FOM["intrabank_add"]

    n_cmas = cdiv(n_rows, mp.CMA_ROWS)
    n_mats = cdiv(n_cmas, cmas_per_mat)
    # parasitic scaling of the intra-mat tree with its fan-in
    t_im_eff = t_im * (1 + 0.1 * math.log2(max(cmas_per_mat, 2)))
    rounds = max(1, cdiv(max(n_mats - 1, 1), intrabank_fanin - 1))
    latency = (
        pooled_lookups * (t_read + t_add)
        + t_im_eff
        + rounds * t_ib
        + 2 * cal.t_rsc_ns
    )
    energy = (
        pooled_lookups * (e_read + e_add + e_write)
        + e_im * n_mats
        + e_ib * rounds
        + (2 + n_mats) * e_shot(1, cal)
    )
    return OpCost(latency, energy)
