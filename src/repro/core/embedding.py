"""Quantized embedding-table engine — the iMARS ET substrate (Sec. III-A1).

Tables are stored row-wise int8 (`QuantizedTensor`), lookups/pooling go
through the fused dequant-gather-pool kernel (CMA RAM mode + in-memory
adders). `MultiTableState` is the software image of the bank structure: one
named table per sparse feature ("one feature per bank").
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core.quantization import (
    QuantizedTensor,
    dequantize_rowwise,
    quantize_rowwise,
)
from repro.kernels import ops


def init_table(key: jax.Array, n_rows: int, dim: int, scale: float = 0.05
               ) -> QuantizedTensor:
    dense = scale * jax.random.normal(key, (n_rows, dim), dtype=jnp.float32)
    return quantize_rowwise(dense)


def lookup(table: QuantizedTensor, ids: jax.Array) -> jax.Array:
    """Plain row lookup: ids (...,) -> (..., d) f32. -1 ids give zeros."""
    valid = (ids >= 0)[..., None]
    safe = jnp.maximum(ids, 0)
    rows = table.values[safe].astype(jnp.float32) * table.scales[safe]
    return jnp.where(valid, rows, 0.0)


def embedding_bag(
    table: QuantizedTensor,
    ids: jax.Array,  # (B, L) int32, -1 padded
    weights: jax.Array | None = None,
    mode: str = "sum",
) -> jax.Array:
    """Pooled lookup -> (B, d). mode in {sum, mean}."""
    pooled = ops.embedding_pool(table.values, table.scales, ids, weights)
    if mode == "mean":
        count = jnp.sum((ids >= 0).astype(jnp.float32), axis=-1, keepdims=True)
        pooled = pooled / jnp.maximum(count, 1.0)
    return pooled


def multi_table_pool(
    tables: Mapping[str, QuantizedTensor],
    features: Mapping[str, jax.Array],  # name -> (B, L) ids
    mode: str = "sum",
    combine: str = "concat",  # "concat" | "sum"
) -> jax.Array:
    """Pool every feature through its table; combine across features.

    combine="sum" requires equal dims (DLRM-style ADD pooling); "concat"
    is the YoutubeDNN-style feature concatenation.
    """
    outs = [embedding_bag(tables[name], features[name], mode=mode)
            for name in sorted(features.keys())]
    if combine == "sum":
        return sum(outs)
    return jnp.concatenate(outs, axis=-1)


def table_from_dense(dense: jax.Array) -> QuantizedTensor:
    return quantize_rowwise(dense)


def table_to_dense(table: QuantizedTensor) -> jax.Array:
    return dequantize_rowwise(table)
