"""Two-level hierarchical reduction — the iMARS adder trees on a TPU mesh.

Paper (Sec. III-A1): partial sums are accumulated inside each CMA (in-memory
adder), then across the C CMAs of a mat (intra-mat adder tree), then across
mats through a fan-in-4 intra-bank adder tree over the serialized IBC, and
finally blocks communicate over the RSC bus.

TPU image (Sec. 3 of DESIGN.md): VMEM-resident accumulation inside the fused
kernel (CMA level) -> deterministic fan-in tree reduce within a device
(intra-mat) -> psum/reduce-scatter over the `model` axis (intra-bank, the ICI
ring is the serialized adder bus) -> psum over the `pod` axis (RSC). The
row-sharded pooled lookup below is the complete ET dataflow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.quantization import QuantizedTensor
from repro.kernels import ops
from repro.utils import shard_map


def tree_sum(parts: jax.Array, fan_in: int = 4) -> jax.Array:
    """Deterministic fan-in-k tree sum over axis 0 (adder-tree semantics).

    Matches the paper's fixed accumulation order (counters, no routers), so
    results are bit-identical across runs regardless of parts count.
    """
    x = parts
    while x.shape[0] > 1:
        n = x.shape[0]
        pad = (-n) % fan_in
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
        x = x.reshape((x.shape[0] // fan_in, fan_in) + x.shape[1:]).sum(axis=1)
    return x[0]


def hierarchical_psum(x: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """Level-by-level psum (call inside shard_map): model -> data -> pod.

    Mirrors intra-bank (fast, local ring) before RSC (slow, cross-pod): each
    level completes before the next starts, exactly like the paper's
    serialized adder hierarchy.
    """
    for axis in axes:
        x = jax.lax.psum(x, axis)
    return x


def sharded_embedding_bag(
    mesh: jax.sharding.Mesh,
    axis: str,
    table: QuantizedTensor,  # rows sharded over `axis`
    ids: jax.Array,  # (B, L) global ids, replicated, -1 padded
    weights: jax.Array | None = None,
    extra_axes: tuple[str, ...] = (),
) -> jax.Array:
    """Row-sharded pooled lookup with two-level reduction -> (B, d) replicated.

    Each shard pools the subset of ids that live in its row range (intra-mat:
    VMEM accumulation in the fused kernel), then the partial bags are summed
    over `axis` (+ optional `extra_axes` for the pod level) with psum — the
    intra-bank adder tree / RSC bus.
    """
    n = table.values.shape[0]
    n_shards = mesh.shape[axis]
    assert n % n_shards == 0, (n, n_shards)
    per_shard = n // n_shards

    def local(table_vals, table_scales, ids_g, w):
        shard = jax.lax.axis_index(axis)
        lo = shard * per_shard
        local_ids = ids_g - lo
        in_range = jnp.logical_and(local_ids >= 0, local_ids < per_shard)
        in_range = jnp.logical_and(in_range, ids_g >= 0)
        local_ids = jnp.where(in_range, local_ids, -1)
        partial = ops.embedding_pool(table_vals, table_scales, local_ids, w)
        return hierarchical_psum(partial, (axis,) + extra_axes)

    w_spec = P() if weights is not None else None
    in_specs = (P(axis, None), P(axis, None), P(), w_spec)
    if weights is None:
        in_specs = in_specs[:3]

        def fn(tv, ts, ig):
            return local(tv, ts, ig, None)

        mapped = shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
        )
        return mapped(table.values, table.scales, ids)

    mapped = shard_map(
        local, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
    )
    return mapped(table.values, table.scales, ids, weights)
