"""Locality-sensitive hashing (signed random projections) — iMARS Sec. III-B.

The paper replaces cosine-distance NNS with Hamming-distance NNS over 256-bit
LSH signatures stored alongside each ItET row (2 CMAs per entry: 256-bit int8
embedding + 256-bit signature). We implement SRP-LSH: sign(x @ G) with G a
fixed Gaussian matrix, packed into uint32 lanes (8 words for 256 bits) so the
Hamming kernel can XOR + popcount whole vector registers.

For unit vectors, E[hamming(h(x), h(y))] = n_bits * angle(x, y) / pi — tested
as a property in tests/test_properties.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import cdiv

WORD_BITS = 32


def make_lsh_projections(key: jax.Array, dim: int, n_bits: int = 256) -> jax.Array:
    """Gaussian projection matrix (dim, n_bits)."""
    return jax.random.normal(key, (dim, n_bits), dtype=jnp.float32)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack (..., n_bits) {0,1} -> (..., n_bits/32) uint32. n_bits % 32 == 0."""
    *lead, n_bits = bits.shape
    assert n_bits % WORD_BITS == 0, n_bits
    words = bits.reshape(*lead, n_bits // WORD_BITS, WORD_BITS).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return jnp.sum(words * weights, axis=-1).astype(jnp.uint32)


def unpack_bits(words: jax.Array, n_bits: int) -> jax.Array:
    """Inverse of pack_bits -> (..., n_bits) int32 in {0,1}."""
    *lead, n_words = words.shape
    assert n_words * WORD_BITS >= n_bits
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*lead, n_words * WORD_BITS)[..., :n_bits].astype(jnp.int32)


def lsh_signature(x: jax.Array, projections: jax.Array) -> jax.Array:
    """SRP signature of x (..., dim) -> packed (..., n_bits/32) uint32."""
    bits = (x @ projections >= 0.0).astype(jnp.uint32)
    return pack_bits(bits)


def signature_words(n_bits: int) -> int:
    return cdiv(n_bits, WORD_BITS)


def expected_hamming(cos_sim: jax.Array, n_bits: int) -> jax.Array:
    """E[hamming] for SRP given cosine similarity (the LSH collision bound)."""
    theta = jnp.arccos(jnp.clip(cos_sim, -1.0, 1.0))
    return n_bits * theta / jnp.pi
