"""Embedding-table -> (banks, mats, CMAs) mapping — iMARS Table I.

Geometry (Sec. III-B / IV): CMAs are 256x256; each int8 32-dim embedding row
is 256 bits = one CMA row; the ItET additionally stores a 256-bit LSH
signature per entry ("2 CMAs to store a single entry"). One sparse feature
maps to one bank; CMAs per ET = width_cmas * ceil(rows/256); mats per ET =
ceil(cmas / C) with C = 32.

The MovieLens feature set is reconstructed from Table I's totals (the paper
does not list the features): 5 filtering UIETs (user_id 6040, gender 3,
age 7, occupation 21, zip bucket 250), +1 ranking-only UIET (genre 18), the
ItET (3000 items, embedding+signature), and the CTR buffer (1 CMA in its own
mat, co-located in the ItET bank). This reproduces exactly 7 banks / 8 mats /
54 CMAs; Criteo's 26 x 28000-row ETs reproduce 26 / 104 / 2860. Both are
asserted in tests/test_mapping.py.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.utils import cdiv

CMA_ROWS = 256
CMA_COLS = 256
CMAS_PER_MAT = 32  # C
MATS_PER_BANK = 4  # M (dimensioned for Criteo, Sec. IV)
INTRABANK_FANIN = 4


@dataclasses.dataclass(frozen=True)
class ETSpec:
    name: str
    n_rows: int
    dim: int = 32
    bits: int = 8
    lsh_bits: int = 0  # ItET stores signatures alongside embeddings
    stages: tuple = ("filtering",)  # which stages use it
    kind: str = "uiet"  # "uiet" | "itet" | "ctr"

    @property
    def row_bits(self) -> int:
        return self.dim * self.bits + self.lsh_bits

    @property
    def width_cmas(self) -> int:
        return cdiv(self.row_bits, CMA_COLS)

    @property
    def n_cmas(self) -> int:
        return self.width_cmas * cdiv(self.n_rows, CMA_ROWS)

    @property
    def n_mats(self) -> int:
        return cdiv(self.n_cmas, CMAS_PER_MAT)


@dataclasses.dataclass(frozen=True)
class MappingResult:
    banks: int
    mats: int
    cmas: int
    per_et: tuple


def map_recsys(ets: Sequence[ETSpec]) -> MappingResult:
    """One bank per sparse feature; CTR buffers share the ItET bank."""
    banks = sum(1 for et in ets if et.kind != "ctr")
    mats = sum(et.n_mats for et in ets)
    cmas = sum(et.n_cmas for et in ets)
    per_et = tuple(
        (et.name, et.n_cmas, et.n_mats, et.kind) for et in ets
    )
    return MappingResult(banks=banks, mats=mats, cmas=cmas, per_et=per_et)


# --- MovieLens 1M + YoutubeDNN (Table I, left) -----------------------------
MOVIELENS_ETS: tuple[ETSpec, ...] = (
    ETSpec("user_id", 6040, stages=("filtering", "ranking")),
    ETSpec("gender", 3, stages=("filtering", "ranking")),
    ETSpec("age", 7, stages=("filtering", "ranking")),
    ETSpec("occupation", 21, stages=("filtering", "ranking")),
    ETSpec("zip_bucket", 250, stages=("filtering", "ranking")),
    ETSpec("genre", 18, stages=("ranking",)),
    ETSpec("item", 3000, lsh_bits=256, stages=("filtering", "ranking"),
           kind="itet"),
    ETSpec("ctr_buffer", 128, stages=("ranking",), kind="ctr"),
)

# --- Criteo Kaggle + DLRM (Table I, right) ---------------------------------
CRITEO_ETS: tuple[ETSpec, ...] = tuple(
    ETSpec(f"cat_{i:02d}", 28000, stages=("ranking",)) for i in range(26)
)


def movielens_mapping() -> MappingResult:
    return map_recsys(MOVIELENS_ETS)


def criteo_mapping() -> MappingResult:
    return map_recsys(CRITEO_ETS)
