"""Nearest-neighbor search — the iMARS filtering-stage retrieval (Sec. III-B).

The paper replaces cosine top-k with *fixed-radius* Hamming NNS over 256-bit
LSH signatures (TCAM threshold match). We implement:

  * `fixed_radius_nns`       — single-device, two execution plans behind one
                               `scan_block` knob:
                                 dense     — (q, n) distance matrix via the
                                             Hamming kernel + threshold +
                                             top-k (fast for small DBs);
                                 streaming — fused blocked scan through
                                             `ops.streaming_nns`, O(q * K)
                                             memory for million-item catalogs.
                               `scan_block=None` routes automatically by DB
                               size (`STREAM_MIN_ITEMS`), `scan_block=0`
                               forces dense, any positive value forces
                               streaming with that chunk size. Both plans are
                               bit-identical.
  * `sharded_fixed_radius_nns` — the item database row-sharded over a mesh
                               axis: each shard scans locally (the "CMA bank")
                               — streaming *within* the shard composes with
                               sharding *across* devices — and contributes a
                               count-bounded candidate buffer that is
                               all-gathered: the communication pattern of the
                               paper's priority encoder + RSC. Optionally
                               *also* sharded over a query mesh axis
                               (`query_axis`): query blocks scan the banks in
                               parallel, composing both partitions.
  * `query_parallel_nns`     — queries sharded over a mesh axis with the DB
                               replicated: every device scans the full
                               catalog for its query block (the multi-bank
                               parallel-search mode of the paper's CMA
                               fabric, applied along the query dimension).
  * cosine references        — the paper's accuracy-baseline configs
                               (fp32/int8 cosine top-k).

Fixed-radius semantics are kept (not top-k) for the paper's reason: a radius
compare vectorizes to a pure elementwise op with no sort; we only sort the
(already tiny) bounded candidate set.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from repro.kernels.streaming_nns import BIG_DIST
from repro.utils import cdiv, pytree_dataclass, shard_map

# invalid-slot distance sentinel (single definition in
# kernels/streaming_nns.py), exported for tests
BIG = jnp.int32(BIG_DIST)
_BIG = BIG  # backwards-compatible alias

# dense materializes q*n int32 — above this DB size the O(q*K) streaming
# scan wins by default (a 256-query batch at 2**18 items is already 256 MiB)
STREAM_MIN_ITEMS = 1 << 18
DEFAULT_SCAN_BLOCK = 4096
# default BlockSummary granularity: one summary entry per 4096 rows (the
# default streaming chunk). Must stay a multiple of 128 so every viable
# Pallas tile divides it (see `build_block_summary`).
SUMMARY_BLOCK_ROWS = 4096


class NNSResult(NamedTuple):
    indices: jax.Array  # (q, max_candidates) int32, -1 padded
    distances: jax.Array  # (q, max_candidates) int32, BIG where invalid
    counts: jax.Array  # (q,) int32 — total matches within radius
    # (q,) int32 — summary blocks whose lower bound admitted the query, or
    # None when the scan ran unpruned (dense plan, no summary, prune=False)
    blocks_touched: jax.Array | None = None


# ---------------------------------------------------------------------------
# Block summaries: sound per-block Hamming lower bounds for scan pruning
# ---------------------------------------------------------------------------
def _popcount_u32(x: np.ndarray) -> np.ndarray:
    """Vectorized host-side popcount over uint32 arrays -> int32 counts."""
    x = x.astype(np.uint32)
    x = x - ((x >> np.uint32(1)) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> np.uint32(2))
                                       & np.uint32(0x33333333))
    x = (x + (x >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> np.uint32(24)).astype(np.int32)


@pytree_dataclass(meta_fields=("block_rows",))
class BlockSummary:
    """Per-block occupancy summary of a packed-signature DB, for pruning.

    For each block of `block_rows` consecutive DB rows it keeps, over the
    block's *eligible* rows (alive under the tombstone mask and below
    `n_valid`):

      * ``or_sigs`` / ``and_sigs`` — the bitwise OR / AND of the rows'
        packed signatures: any eligible row r satisfies
        ``and_sigs <= r <= or_sigs`` as bit sets;
      * ``min_pc`` / ``max_pc`` — per-word popcount range of the rows;
      * ``n_alive`` — eligible-row count (0 = the block can never match).

    `summary_block_bounds` turns these into a sound lower bound on the
    Hamming distance from any query to any eligible row of the block, so
    blocks whose bound exceeds the radius are skipped without changing a
    single output bit (see docs/KERNELS.md for the soundness argument).

    Soundness contract: the eligible-row set the summary was built over
    must be a SUPERSET of the rows the scan may match — then every pruned
    block is provably empty of matches. Equality keeps bounds tight;
    `update_block_summary` recomputes touched blocks exactly so tombstoned
    rows never loosen (or unsound-tighten) the bound.
    """

    or_sigs: jax.Array  # (n_blocks, words) uint32 — OR of eligible rows
    and_sigs: jax.Array  # (n_blocks, words) uint32 — AND of eligible rows
    min_pc: jax.Array  # (n_blocks, words) int32 — min per-word popcount
    max_pc: jax.Array  # (n_blocks, words) int32 — max per-word popcount
    n_alive: jax.Array  # (n_blocks,) int32 — eligible rows in the block
    block_rows: int = SUMMARY_BLOCK_ROWS

    @property
    def n_blocks(self) -> int:
        return self.or_sigs.shape[0]


# host-side builder passes this many blocks per vectorized sweep, bounding
# peak temp memory (64 blocks * 4096 rows * 8 words ~= 8 MiB per temporary)
_BUILD_CHUNK_BLOCKS = 64


def _summarize_blocks(sigs3: np.ndarray, elig3: np.ndarray):
    """(nb, block_rows, words) sigs + (nb, block_rows) eligibility ->
    the five per-block summary arrays (numpy)."""
    e = elig3[..., None]
    or_sigs = np.bitwise_or.reduce(
        np.where(e, sigs3, np.uint32(0)), axis=1).astype(np.uint32)
    and_sigs = np.bitwise_and.reduce(
        np.where(e, sigs3, np.uint32(0xFFFFFFFF)), axis=1).astype(np.uint32)
    pc = _popcount_u32(sigs3)
    min_pc = np.min(np.where(e, pc, np.int32(33)), axis=1).astype(np.int32)
    max_pc = np.max(np.where(e, pc, np.int32(-1)), axis=1).astype(np.int32)
    n_alive = elig3.sum(axis=1).astype(np.int32)
    return or_sigs, and_sigs, min_pc, max_pc, n_alive


def build_block_summary(
    db_sigs,  # (n, words) uint32 — packed signatures (numpy or jax)
    block_rows: int = SUMMARY_BLOCK_ROWS,
    *,
    db_mask=None,  # (n,) bool — rows eligible to match (tombstone mask)
    n_valid: int | None = None,  # rows >= n_valid are padding, ineligible
) -> BlockSummary:
    """Build a `BlockSummary` over `db_sigs` (pure, host-side).

    The eligibility set is ``db_mask AND (row < n_valid)`` — pass exactly
    what the scan will use so bounds stay tight; passing a superset is
    sound but looser. `block_rows` must be a positive multiple of 128 so
    any lane-aligned Pallas tile divides it (mask expansion stays a pure
    repeat). Runs in bounded chunks of blocks, so peak temporary memory is
    independent of the DB size.
    """
    block_rows = int(block_rows)
    if block_rows <= 0 or block_rows % 128:
        raise ValueError(
            f"block_rows must be a positive multiple of 128, got "
            f"{block_rows}")
    sigs = np.asarray(db_sigs)
    n, words = sigs.shape
    nb = max(1, cdiv(n, block_rows))
    elig = (np.ones(n, bool) if db_mask is None
            else np.asarray(db_mask, bool)[:n].copy())
    if n_valid is not None:
        elig &= np.arange(n) < int(n_valid)

    or_sigs = np.zeros((nb, words), np.uint32)
    and_sigs = np.full((nb, words), np.uint32(0xFFFFFFFF), np.uint32)
    min_pc = np.full((nb, words), 33, np.int32)
    max_pc = np.full((nb, words), -1, np.int32)
    n_alive = np.zeros((nb,), np.int32)
    for b0 in range(0, nb, _BUILD_CHUNK_BLOCKS):
        b1 = min(b0 + _BUILD_CHUNK_BLOCKS, nb)
        lo, hi = b0 * block_rows, min(b1 * block_rows, n)
        rows = (b1 - b0) * block_rows
        s = np.zeros((rows, words), np.uint32)
        e = np.zeros((rows,), bool)
        s[: hi - lo] = sigs[lo:hi]
        e[: hi - lo] = elig[lo:hi]
        (or_sigs[b0:b1], and_sigs[b0:b1], min_pc[b0:b1], max_pc[b0:b1],
         n_alive[b0:b1]) = _summarize_blocks(
            s.reshape(b1 - b0, block_rows, words),
            e.reshape(b1 - b0, block_rows))
    return BlockSummary(
        or_sigs=jnp.asarray(or_sigs), and_sigs=jnp.asarray(and_sigs),
        min_pc=jnp.asarray(min_pc), max_pc=jnp.asarray(max_pc),
        n_alive=jnp.asarray(n_alive), block_rows=block_rows)


def update_block_summary(summary: BlockSummary, db_sigs, db_mask,
                         touched_rows) -> BlockSummary:
    """Incrementally refresh a summary after rows changed eligibility.

    Recomputes — exactly, from `db_sigs`/`db_mask` — every block containing
    a row in `touched_rows` (host-side; cost is O(touched blocks), not
    O(n)). This is the upsert/delete maintenance rule: tombstoning a row
    must *tighten* (never loosen) the block's bound, and an incremental
    OR/AND cannot un-set bits, so touched blocks are rebuilt from scratch.
    The result is bit-identical to `build_block_summary` over the same
    (db_sigs, db_mask) — asserted by tests and benchmarks/catalog_churn.py.
    """
    rows = np.unique(np.asarray(touched_rows, np.int64).reshape(-1))
    sigs = np.asarray(db_sigs)
    n, words = sigs.shape
    br = summary.block_rows
    rows = rows[(rows >= 0) & (rows < n)]
    if rows.size == 0:
        return summary
    elig = (np.ones(n, bool) if db_mask is None
            else np.asarray(db_mask, bool)[:n])
    blocks = np.unique(rows // br)
    blocks = blocks[blocks < summary.n_blocks]
    or_sigs = np.asarray(summary.or_sigs).copy()
    and_sigs = np.asarray(summary.and_sigs).copy()
    min_pc = np.asarray(summary.min_pc).copy()
    max_pc = np.asarray(summary.max_pc).copy()
    n_alive = np.asarray(summary.n_alive).copy()
    for b in blocks:
        lo, hi = int(b) * br, min(int(b) * br + br, n)
        s = np.zeros((br, words), np.uint32)
        e = np.zeros((br,), bool)
        s[: hi - lo] = sigs[lo:hi]
        e[: hi - lo] = elig[lo:hi]
        (or_sigs[b], and_sigs[b], min_pc[b], max_pc[b],
         n_alive[b]) = (a[0] for a in _summarize_blocks(
            s[None], e[None]))
    return BlockSummary(
        or_sigs=jnp.asarray(or_sigs), and_sigs=jnp.asarray(and_sigs),
        min_pc=jnp.asarray(min_pc), max_pc=jnp.asarray(max_pc),
        n_alive=jnp.asarray(n_alive), block_rows=br)


def summary_block_bounds(query_sigs: jax.Array,
                         summary: BlockSummary) -> jax.Array:
    """(q, words) queries x summary -> (q, n_blocks) int32 lower bounds.

    For every (query, block) pair, a sound lower bound on the Hamming
    distance from the query to ANY eligible row of the block, combining
    two per-word bounds (the larger of the two per word, summed):

      * occupancy:  popcount(q & ~or) + popcount(~q & and) — bit positions
        where the query is 1 but no eligible row is (q & ~or), or where the
        query is 0 but every eligible row is 1 (~q & and), each contribute
        one mismatch to every row of the block;
      * popcount range: |popcount(q_w) - popcount(r_w)| <= d(q_w, r_w),
        and popcount(r_w) is within [min_pc, max_pc].

    Blocks with no eligible rows bound to `BIG` (always pruned).
    """
    pc = lambda x: jax.lax.population_count(x).astype(jnp.int32)  # noqa: E731
    q = query_sigs[:, None, :]  # (q, 1, words)
    occ = pc(q & ~summary.or_sigs[None]) + pc(~q & summary.and_sigs[None])
    pcq = pc(q)
    rng = jnp.maximum(pcq - summary.max_pc[None],
                      summary.min_pc[None] - pcq)
    per_word = jnp.maximum(occ, jnp.maximum(rng, 0))
    total = jnp.sum(per_word, axis=-1)
    return jnp.where(summary.n_alive[None] > 0, total, BIG)


def _prune_mask(query_sigs, summary, radius):
    """-> (prune (q, n_blocks) bool, blocks_touched (q,) int32)."""
    prune = summary_block_bounds(query_sigs, summary) > radius
    touched = jnp.sum((~prune).astype(jnp.int32), axis=-1)
    return prune, touched


def _plan_streams(n_rows: int, scan_block: int | None) -> bool:
    """Static mirror of `fixed_radius_nns`'s dense-vs-streaming routing."""
    if scan_block is None:
        return n_rows >= STREAM_MIN_ITEMS
    return scan_block != 0


def fixed_radius_nns(
    query_sigs: jax.Array,  # (q, words) uint32
    db_sigs: jax.Array,  # (n, words) uint32
    radius: int,
    max_candidates: int = 128,
    db_mask: jax.Array | None = None,  # (n,) bool — rows eligible to match
    *,
    scan_block: int | None = None,  # None=auto, 0=dense, >0=streaming chunk
    n_valid: jax.Array | int | None = None,  # rows >= n_valid never match
    superblock: int | None = None,  # streaming superblock rows (testing knob)
    summary: BlockSummary | None = None,  # block summary enabling pruning
    prune: bool | None = None,  # None=auto (prune when summary given), False=off
) -> NNSResult:
    """All db items within Hamming `radius` of each query (bounded, sorted).

    Args:
      query_sigs / db_sigs: (q, words) / (n, words) packed uint32 LSH
        signatures (words=8 for the paper's 256-bit signatures).
      radius: fixed match radius (the TCAM threshold), static.
      max_candidates: bounded candidate-set size K; output columns.
      db_mask: optional (n,) bool eligibility mask — rows where it is False
        (live-catalog tombstones) never match on either plan.
      scan_block: execution plan — None auto-routes by DB size
        (`STREAM_MIN_ITEMS`), 0 forces dense, >0 forces streaming with that
        chunk. Both plans return bit-identical results.
      n_valid: prefix count of real rows; rows >= n_valid never match
        (may be a traced scalar — used by the sharded paths for padding).
      superblock: streaming superblock size override (testing knob;
        results are superblock-invariant).
      summary: optional `BlockSummary` over `db_sigs` (built against an
        eligibility superset of this scan's (db_mask, n_valid)); enables
        block pruning on the streaming plan. Bit-identical results — the
        bound is sound — plus a per-query `blocks_touched` counter.
      prune: None (default) prunes whenever `summary` is given and the
        plan streams; False disables pruning even with a summary.
    Returns:
      NNSResult of (q, K) indices (-1 padded), (q, K) distances (`BIG`
      where invalid), (q,) total within-radius counts, and (pruned scans
      only) (q,) `blocks_touched`. Candidates are sorted by
      (distance, index) ascending — the exact dense threshold + top-k
      order, whatever the execution plan.
    """
    if isinstance(db_sigs, np.memmap):
        # out-of-core table handle: the host-driven scan loads admitted
        # summary blocks on demand instead of residing the DB (tiered
        # catalog cold shard). Same bits, different residency.
        return out_of_core_nns(
            query_sigs, db_sigs, radius, max_candidates, db_mask=db_mask,
            scan_block=scan_block, n_valid=n_valid, summary=summary,
            prune=prune)

    n, words = db_sigs.shape
    use_stream = _plan_streams(n, scan_block)
    block = DEFAULT_SCAN_BLOCK if not scan_block else scan_block

    if use_stream:
        prune_blocks = blocks_touched = block_rows = None
        if summary is not None and prune is not False:
            prune_blocks, blocks_touched = _prune_mask(
                query_sigs, summary, radius)
            block_rows = summary.block_rows
        indices, distances, counts = ops.streaming_nns(
            query_sigs, db_sigs, radius=radius,
            max_candidates=max_candidates, scan_block=block, n_valid=n_valid,
            superblock=superblock, db_mask=db_mask,
            prune_blocks=prune_blocks, prune_block_rows=block_rows)
        return NNSResult(indices=indices, distances=distances, counts=counts,
                         blocks_touched=blocks_touched)

    d = ops.hamming_distances(query_sigs, db_sigs)  # (q, n)
    within = d <= radius
    if n_valid is not None:
        within = jnp.logical_and(
            within, (jnp.arange(n) < n_valid)[None, :])
    if db_mask is not None:
        within = jnp.logical_and(within, db_mask[None, :])
    counts = jnp.sum(within, axis=-1).astype(jnp.int32)
    masked = jnp.where(within, d, BIG)
    # smallest distances first (threshold-match + priority encode)
    neg_top, idx = jax.lax.top_k(-masked, k=min(max_candidates, d.shape[-1]))
    dist = -neg_top
    valid = dist < BIG
    idx = jnp.where(valid, idx, -1)
    dist = jnp.where(valid, dist, BIG)
    if idx.shape[-1] < max_candidates:  # tiny db: pad out
        pad = max_candidates - idx.shape[-1]
        idx = jnp.pad(idx, ((0, 0), (0, pad)), constant_values=-1)
        dist = jnp.pad(dist, ((0, 0), (0, pad)), constant_values=int(BIG))
    return NNSResult(indices=idx, distances=dist, counts=counts)


# pre-jitted prune-mask for host-driven scans (radius static)
_prune_mask_jit = jax.jit(_prune_mask, static_argnums=(2,))

# rows gathered per jitted chunk scan of the out-of-core driver: large
# enough to amortize dispatch (32 calls at 8M rows), small enough that the
# resident chunk buffers stay ~8 MB each at 256-bit signatures (the gather
# copy, its device image, and one in-flight predecessor pin ~4 of these at
# peak, so the chunk size bounds the scan's whole RSS footprint)
OUTOFCORE_CHUNK_ROWS = 1 << 18


def out_of_core_nns(
    query_sigs: jax.Array,  # (q, words) uint32
    db_sigs: np.ndarray,  # (n, words) uint32 host ndarray / np.memmap
    radius: int,
    max_candidates: int = 128,
    db_mask=None,  # (n,) bool HOST array — tombstones
    *,
    scan_block: int | None = None,  # inner chunk-scan block (>0 or None)
    n_valid: int | None = None,
    summary: BlockSummary | None = None,
    prune: bool | None = None,
    chunk_rows: int = OUTOFCORE_CHUNK_ROWS,
) -> NNSResult:
    """Fixed-radius NNS over a host-resident (memmapped) signature DB.

    The scan itself is already O(q*K) resident; this entry removes the
    last O(n) residency — the DB — by gathering only summary blocks at
    least one query admits, one fixed-size chunk per jitted call. Blocks
    every query prunes never have their memmap pages touched, so the
    process RSS tracks the admitted working set, not the catalog size.
    Results (including `blocks_touched`) are bit-identical to the resident
    streaming scan with the same mask/summary: admitted blocks are scanned
    in full with genuine rows, ascending disjoint row ranges merge exactly
    (`merge_chunk_buffers`), and prune soundness makes scanning a block
    some queries pruned a no-op for those queries. `fixed_radius_nns`
    routes here automatically when `db_sigs` is an `np.memmap`. The
    `superblock` knob does not apply (chunks are far below key capacity).
    """
    block = DEFAULT_SCAN_BLOCK if not scan_block else scan_block
    prune_np = blocks_touched = block_rows = None
    if summary is not None and prune is not False:
        pm, blocks_touched = _prune_mask_jit(
            jnp.asarray(query_sigs), summary, radius)
        prune_np = np.asarray(pm)
        block_rows = summary.block_rows
    indices, distances, counts = ops.streaming_nns_outofcore(
        query_sigs, db_sigs, radius=radius, max_candidates=max_candidates,
        scan_block=block, n_valid=n_valid, db_mask=db_mask,
        prune_blocks=prune_np, prune_block_rows=block_rows,
        chunk_rows=chunk_rows)
    return NNSResult(indices=indices, distances=distances, counts=counts,
                     blocks_touched=blocks_touched)


# pre-jitted entry for the scan: knobs that fix shapes/plans are static,
# signatures and n_valid stay traced, so repeat calls at one batch shape
# never retrace in the caller
_fixed_radius_nns_jit = jax.jit(
    fixed_radius_nns,
    static_argnames=("radius", "max_candidates", "scan_block", "superblock",
                     "prune"))


def fixed_radius_nns_async(
    query_sigs: jax.Array,  # (q, words) uint32
    db_sigs: jax.Array,  # (n, words) uint32
    radius: int,
    max_candidates: int = 128,
    db_mask: jax.Array | None = None,
    *,
    scan_block: int | None = None,
    n_valid: jax.Array | int | None = None,
    superblock: int | None = None,
    summary: BlockSummary | None = None,
    prune: bool | None = None,
) -> NNSResult:
    """Non-blocking filtering scan: dispatch and return device futures.

    Same arguments and bit-identical results as `fixed_radius_nns`, but
    the call never synchronizes with the host: it dispatches one pre-jitted
    scan (dense or streaming per `scan_block`) and immediately returns an
    `NNSResult` of in-flight device arrays. Callers overlap host work (or
    further dispatches) with the scan and pay the sync only when they read
    a result — e.g. `np.asarray(res.indices)` or `jax.block_until_ready`.
    This is the entry the pipelined `serving.AsyncServer` pattern builds
    on; use it directly when driving the scan outside an engine.
    """
    return _fixed_radius_nns_jit(
        query_sigs, db_sigs, radius=radius, max_candidates=max_candidates,
        db_mask=db_mask, scan_block=scan_block, n_valid=n_valid,
        superblock=superblock, summary=summary, prune=prune)


def _pad_queries_to_axis(mesh, query_axis, query_sigs):
    """Pad the query batch to a multiple of the query-axis size.

    Returns (padded queries, pad count); `_slice_query_pad` undoes it on
    the result so pad rows never leave the shard_map.
    """
    q = query_sigs.shape[0]
    pad = (-q) % mesh.shape[query_axis]
    if pad:
        query_sigs = jnp.pad(query_sigs, ((0, pad), (0, 0)))
    return query_sigs, pad


def _slice_query_pad(res: NNSResult, pad: int) -> NNSResult:
    if not pad:
        return res
    q = res.counts.shape[0] - pad
    bt = None if res.blocks_touched is None else res.blocks_touched[:q]
    return NNSResult(indices=res.indices[:q], distances=res.distances[:q],
                     counts=res.counts[:q], blocks_touched=bt)


def sharded_fixed_radius_nns(
    mesh: jax.sharding.Mesh,
    axis: str,
    query_sigs: jax.Array,  # (q, words) replicated (or query-sharded)
    db_sigs: jax.Array,  # (n, words) row-sharded over `axis`
    radius: int,
    max_candidates: int = 128,
    n_valid: int | None = None,  # rows >= n_valid are padding, never match
    *,
    scan_block: int | None = None,  # forwarded to the per-shard scan
    query_axis: str | None = None,  # also shard queries over this mesh axis
    superblock: int | None = None,  # forwarded to the streaming scan
    db_mask: jax.Array | None = None,  # (n,) bool, row-sharded like db_sigs
    summary: BlockSummary | None = None,  # block summary over the padded DB
    prune: bool | None = None,  # None=auto, False=off
):
    """Fixed-radius NNS with the item DB sharded across the mesh.

    Each shard = one "bank" scanning its rows in parallel; per-shard bounded
    candidates (local priority encode) are all-gathered and re-selected.
    Within a shard the scan routes dense vs streaming via `scan_block`
    exactly like `fixed_radius_nns`, so sharding-over-devices composes with
    streaming-within-shard. Returned indices are global row ids. `n_valid`
    lets callers pad the DB to a multiple of the shard count without the pad
    rows ever matching; `db_mask` (optional, padded to the same length as
    `db_sigs` by the caller) additionally tombstones arbitrary rows — each
    bank sees its slice of the mask.

    `query_axis` additionally blocks the *query* batch over a second mesh
    axis: each (query-block, bank) device pair scans independently and the
    candidate gather stays confined to the bank axis, composing both
    partitions. Queries are padded to a multiple of the query-axis size and
    the pad rows sliced off the result.

    `summary` (a `BlockSummary` over the padded DB) enables block pruning
    inside each bank when the per-shard scan streams AND the shard size is
    a multiple of `summary.block_rows` (so each bank owns whole summary
    blocks); otherwise it is silently ignored (unpruned scan, no error).
    Per-bank `blocks_touched` counters psum into global per-query counts.
    """
    n = db_sigs.shape[0]
    n_shards = mesh.shape[axis]
    per_shard = n // n_shards
    local_k = min(max_candidates, per_shard)
    n_valid = n if n_valid is None else n_valid
    q_pad = 0
    if query_axis is not None:
        query_sigs, q_pad = _pad_queries_to_axis(mesh, query_axis,
                                                 query_sigs)
    use_prune = (
        summary is not None and prune is not False
        and _plan_streams(per_shard, scan_block)
        and per_shard % summary.block_rows == 0
        and summary.n_blocks * summary.block_rows == n)

    def local_scan(q_local, db_local, *rest):
        rest = list(rest)
        mask_local = rest.pop(0) if db_mask is not None else None
        sum_local = (BlockSummary(*rest, block_rows=summary.block_rows)
                     if use_prune else None)
        shard = jax.lax.axis_index(axis)
        # prefix count of real (non-padding) rows within this shard
        local_valid = jnp.clip(n_valid - shard * per_shard, 0, per_shard)
        res = fixed_radius_nns(q_local, db_local, radius, local_k,
                               scan_block=scan_block, n_valid=local_valid,
                               superblock=superblock, db_mask=mask_local,
                               summary=sum_local,
                               prune=True if use_prune else False)
        gidx = jnp.where(
            res.indices >= 0, res.indices + shard * per_shard, -1
        )
        # gather the bounded buffers from every shard (RSC bus)
        all_idx = jax.lax.all_gather(gidx, axis, axis=1, tiled=True)
        all_dist = jax.lax.all_gather(res.distances, axis, axis=1, tiled=True)
        counts = jax.lax.psum(res.counts, axis)
        blocks_touched = (jax.lax.psum(res.blocks_touched, axis)
                          if use_prune else None)
        # tiny shards can gather fewer slots than max_candidates: select
        # what exists, pad the rest with (-1, BIG)
        k = min(max_candidates, all_dist.shape[-1])
        neg_top, pos = jax.lax.top_k(-all_dist, k=k)
        dist = -neg_top
        idx = jnp.take_along_axis(all_idx, pos, axis=1)
        idx = jnp.where(dist < BIG, idx, -1)
        if k < max_candidates:
            pad = max_candidates - k
            idx = jnp.pad(idx, ((0, 0), (0, pad)), constant_values=-1)
            dist = jnp.pad(dist, ((0, 0), (0, pad)),
                           constant_values=int(BIG))
        return NNSResult(indices=idx, distances=dist, counts=counts,
                         blocks_touched=blocks_touched)

    q_spec = P(query_axis)  # P(None) == replicated when query_axis is None
    specs_in = (q_spec, P(axis, None))
    args = (query_sigs, db_sigs)
    if db_mask is not None:
        if db_mask.shape[0] != n:
            raise ValueError(
                f"db_mask must be padded like db_sigs: {db_mask.shape[0]} "
                f"!= {n}")
        specs_in = (*specs_in, P(axis))
        args = (*args, db_mask)
    if use_prune:
        # summary arrays row-shard with the DB: each bank sees the summary
        # blocks covering exactly its rows (per_shard % block_rows == 0)
        specs_in = (*specs_in, P(axis, None), P(axis, None), P(axis, None),
                    P(axis, None), P(axis))
        args = (*args, summary.or_sigs, summary.and_sigs, summary.min_pc,
                summary.max_pc, summary.n_alive)
    specs_out = NNSResult(
        indices=q_spec, distances=q_spec, counts=q_spec,
        blocks_touched=q_spec if use_prune else None)
    fn = shard_map(
        local_scan, mesh=mesh, in_specs=specs_in, out_specs=specs_out,
        check_vma=False,
    )
    return _slice_query_pad(fn(*args), q_pad)


def query_parallel_nns(
    mesh: jax.sharding.Mesh,
    query_axis: str,
    query_sigs: jax.Array,  # (q, words) sharded over `query_axis`
    db_sigs: jax.Array,  # (n, words) replicated
    radius: int,
    max_candidates: int = 128,
    *,
    scan_block: int | None = None,  # forwarded to the per-block scan
    n_valid: jax.Array | int | None = None,
    superblock: int | None = None,
    db_mask: jax.Array | None = None,  # (n,) bool, replicated like db_sigs
    summary: BlockSummary | None = None,  # replicated with the catalog
    prune: bool | None = None,  # None=auto, False=off
):
    """Fixed-radius NNS with the QUERY batch sharded over `mesh[query_axis]`.

    The catalog is replicated and every device scans all of it for its own
    query block — the dual of `sharded_fixed_radius_nns`: no cross-device
    candidate gather at all, so it parallelizes the streaming scan across
    host/device cores at zero communication cost. Queries are padded to a
    multiple of the axis size; pad rows are sliced off the result.
    `db_mask` tombstones rows and replicates with the catalog; `summary`
    (replicated too) enables block pruning when the scan streams.
    """
    padded, pad = _pad_queries_to_axis(mesh, query_axis, query_sigs)
    nv = jnp.asarray(
        db_sigs.shape[0] if n_valid is None else n_valid, jnp.int32)
    use_prune = (summary is not None and prune is not False
                 and _plan_streams(db_sigs.shape[0], scan_block))

    def local_scan(q_local, db_local, nv_local, *rest):
        rest = list(rest)
        mask_local = rest.pop(0) if db_mask is not None else None
        sum_local = (BlockSummary(*rest, block_rows=summary.block_rows)
                     if use_prune else None)
        return fixed_radius_nns(q_local, db_local, radius, max_candidates,
                                scan_block=scan_block, n_valid=nv_local,
                                superblock=superblock, db_mask=mask_local,
                                summary=sum_local,
                                prune=True if use_prune else False)

    q_spec = P(query_axis)
    specs_in = (q_spec, P(), P())
    args = (padded, db_sigs, nv)
    if db_mask is not None:
        specs_in = (*specs_in, P())
        args = (*args, db_mask)
    if use_prune:
        specs_in = (*specs_in, P(), P(), P(), P(), P())
        args = (*args, summary.or_sigs, summary.and_sigs, summary.min_pc,
                summary.max_pc, summary.n_alive)
    fn = shard_map(
        local_scan, mesh=mesh, in_specs=specs_in,
        out_specs=NNSResult(indices=q_spec, distances=q_spec, counts=q_spec,
                            blocks_touched=q_spec if use_prune else None),
        check_vma=False,
    )
    return _slice_query_pad(fn(*args), pad)


# ---------------------------------------------------------------------------
# Delta-aware NNS (live catalogs: read-only base + bounded delta shard)
# ---------------------------------------------------------------------------
# empty-delta-slot sentinel: sorts AFTER every real item id, so a delta shard
# kept sorted-by-id has its live slots in a contiguous ascending prefix and
# `searchsorted` membership probes stay valid (serving/catalog.py)
EMPTY_ID = 2**31 - 1


def delta_scan(
    query_sigs: jax.Array,  # (q, words) uint32
    delta_sigs: jax.Array,  # (D, words) uint32 — the delta shard signatures
    delta_ids: jax.Array,  # (D,) int32 — global item id per slot, EMPTY_ID
    radius: int,
    max_candidates: int = 128,
) -> NNSResult:
    """Scan the delta shard; returned indices are GLOBAL item ids.

    The shard is bounded (D rows), so the dense plan is always right.
    Precondition (kept by `serving/catalog.py`): live slots are sorted by
    item id — slot order == id order, so the bounded (distance, slot)
    truncation selects exactly the entries a (distance, id) truncation
    would, and the merge below stays bit-exact vs a from-scratch rebuild.
    Empty slots (`EMPTY_ID`) never match and never count.
    """
    k = min(max_candidates, delta_sigs.shape[0])
    res = fixed_radius_nns(query_sigs, delta_sigs, radius, k,
                           db_mask=delta_ids != EMPTY_ID, scan_block=0)
    gids = jnp.where(res.indices >= 0,
                     delta_ids[jnp.maximum(res.indices, 0)], -1)
    if k < max_candidates:
        pad = max_candidates - k
        gids = jnp.pad(gids, ((0, 0), (0, pad)), constant_values=-1)
        dist = jnp.pad(res.distances, ((0, 0), (0, pad)),
                       constant_values=int(BIG))
        return NNSResult(indices=gids, distances=dist, counts=res.counts)
    return NNSResult(indices=gids, distances=res.distances,
                     counts=res.counts)


def merge_delta_candidates(base: NNSResult, delta: NNSResult,
                           max_candidates: int) -> NNSResult:
    """Merge base-scan and delta-scan candidate buffers, bit-exactly.

    Both buffers carry global item ids; an id appears in at most one of
    them (a base row overwritten by a delta row is tombstoned out of the
    base scan). The exact global order is lexicographic (distance, id) —
    the dense rebuild order — which one stable distance sort alone cannot
    recover from the concatenation, because delta ids (overwrites land
    anywhere in the id space) interleave with base ids. So: pre-permute the
    concatenated buffers into ascending-id order (one stable argsort on id,
    invalid slots pushed to the end), then reuse
    `kernels.streaming_nns.merge_candidate_buffers` — its stable sort on
    distance now breaks ties by ascending id, reproducing the exact
    (distance, id) order. Counts add (the id sets are disjoint).
    """
    from repro.kernels.streaming_nns import merge_candidate_buffers

    ids = jnp.concatenate([base.indices, delta.indices], axis=1)
    dist = jnp.concatenate([base.distances, delta.distances], axis=1)
    order = jnp.argsort(jnp.where(ids < 0, jnp.int32(EMPTY_ID), ids),
                        axis=-1, stable=True)
    ids = jnp.take_along_axis(ids, order, axis=1)
    dist = jnp.take_along_axis(dist, order, axis=1)
    idx, d = merge_candidate_buffers(ids, dist, max_candidates)
    # the bounded delta scans dense (never pruned): the merged result
    # carries the base scan's blocks_touched counter through unchanged
    return NNSResult(indices=idx, distances=d,
                     counts=base.counts + delta.counts,
                     blocks_touched=base.blocks_touched)


def query_parallel_delta_scan(
    mesh: jax.sharding.Mesh,
    query_axis: str,
    query_sigs: jax.Array,  # (q, words) sharded over `query_axis`
    delta_sigs: jax.Array,  # (D, words) replicated
    delta_ids: jax.Array,  # (D,) int32 replicated, EMPTY_ID = free slot
    radius: int,
    max_candidates: int = 128,
) -> NNSResult:
    """`delta_scan` with the QUERY batch sharded over `mesh[query_axis]`.

    The delta shard is bounded and replicated with the catalog, so each
    device scans all of it for its own query block — zero communication,
    the exact dual of `query_parallel_nns`. The scan is per-query
    independent, so results bit-match the replicated `delta_scan` while
    doing 1/P of the work per device instead of all of it on every device
    (the ROADMAP delta-scan sharding item). Queries are padded to a
    multiple of the axis size; pad rows are sliced off the result.
    """
    padded, pad = _pad_queries_to_axis(mesh, query_axis, query_sigs)

    def local_scan(q_local, dsigs, dids):
        return delta_scan(q_local, dsigs, dids, radius, max_candidates)

    q_spec = P(query_axis)
    fn = shard_map(
        local_scan, mesh=mesh, in_specs=(q_spec, P(), P()),
        out_specs=NNSResult(indices=q_spec, distances=q_spec,
                            counts=q_spec, blocks_touched=None),
        check_vma=False,
    )
    return _slice_query_pad(fn(padded, delta_sigs, delta_ids), pad)


def delta_aware_nns(
    query_sigs: jax.Array,  # (q, words) uint32
    db_sigs: jax.Array,  # (n, words) uint32 — read-only base epoch
    delta_sigs: jax.Array,  # (D, words) uint32 — bounded delta shard
    delta_ids: jax.Array,  # (D,) int32 — global ids, EMPTY_ID = free slot
    radius: int,
    max_candidates: int = 128,
    *,
    db_mask: jax.Array | None = None,  # (n,) bool — base tombstones
    scan_block: int | None = None,
    n_valid: jax.Array | int | None = None,
    superblock: int | None = None,
    summary: BlockSummary | None = None,  # block summary over the base
    prune: bool | None = None,
) -> NNSResult:
    """Fixed-radius NNS over (read-only base) + (bounded delta shard).

    The base scans with its usual execution plan (dense / streaming /
    superblocked, with tombstoned rows masked, optionally block-pruned via
    `summary`), the delta scans dense, and one `merge_candidate_buffers`
    reuse fuses the two bounded buffers — results bit-match
    `fixed_radius_nns` over a from-scratch rebuilt table (delta rows folded
    in, tombstones dropped). This is the serving entry the live-catalog
    engine routes through while updates are pending.
    """
    base = fixed_radius_nns(query_sigs, db_sigs, radius, max_candidates,
                            db_mask=db_mask, scan_block=scan_block,
                            n_valid=n_valid, superblock=superblock,
                            summary=summary, prune=prune)
    delta = delta_scan(query_sigs, delta_sigs, delta_ids, radius,
                       max_candidates)
    return merge_delta_candidates(base, delta, max_candidates)


# ---------------------------------------------------------------------------
# Cosine baselines (paper accuracy configs 1 & 2)
# ---------------------------------------------------------------------------
def cosine_topk(
    query_vecs: jax.Array,  # (q, d) f32
    db_vecs: jax.Array,  # (n, d) f32
    k: int,
):
    """Exact cosine top-k (FAISS-equivalent flat search)."""
    qn = query_vecs / jnp.maximum(
        jnp.linalg.norm(query_vecs, axis=-1, keepdims=True), 1e-12
    )
    dn = db_vecs / jnp.maximum(
        jnp.linalg.norm(db_vecs, axis=-1, keepdims=True), 1e-12
    )
    sims = qn @ dn.T
    vals, idx = jax.lax.top_k(sims, k)
    return vals, idx
