"""int8 quantization — the iMARS embedding-table data format (Sec. III-B).

The paper quantizes all embedding tables to int8 (32 dims x 8 bits = one
256-bit CMA row). We implement:

  * row-wise symmetric int8 (one scale per table row) — the ET format; each
    quantized row is the software image of one CMA row.
  * block-wise symmetric int8 over flattened tensors — used for optimizer
    states and gradient compression (the same idea applied beyond the paper).

Both are pytree-registered containers so they pass transparently through
jit / shard_map / checkpointing.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, cdiv

INT8_MAX = 127.0


@pytree_dataclass
class QuantizedTensor:
    """Row-wise symmetric int8 tensor: `values[i, :] * scales[i]` ~ original."""

    values: jax.Array  # (n, d) int8
    scales: jax.Array  # (n, 1) float32

    @property
    def shape(self):
        return self.values.shape

    @property
    def dtype(self):
        return self.values.dtype


@pytree_dataclass(meta_fields=("orig_shape", "block"))
class BlockQuantizedTensor:
    """Block-wise symmetric int8 over the flattened tensor.

    `orig_shape`/`block` are static metadata.
    """

    values: jax.Array  # (n_blocks, block) int8
    scales: jax.Array  # (n_blocks, 1) float32
    orig_shape: tuple = ()
    block: int = 256

    @property
    def shape(self):
        return self.orig_shape


def quantize_rowwise(x: jax.Array) -> QuantizedTensor:
    """Symmetric per-row int8 quantization. x: (..., d) -> rows = leading dims."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return QuantizedTensor(values=q, scales=scale.astype(jnp.float32))


def dequantize_rowwise(q: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    return (q.values.astype(jnp.float32) * q.scales).astype(dtype)


def quantize_blockwise(x: jax.Array, block: int = 256) -> BlockQuantizedTensor:
    """Symmetric block-wise int8 over flattened x (padded to block multiple)."""
    orig_shape = tuple(x.shape)
    flat = x.reshape(-1)
    n = flat.shape[0]
    n_blocks = cdiv(max(n, 1), block)
    pad = n_blocks * block - n
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(n_blocks, block)
    absmax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(blocks / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return BlockQuantizedTensor(
        values=q, scales=scale.astype(jnp.float32), orig_shape=orig_shape, block=block
    )


def dequantize_blockwise(q: BlockQuantizedTensor, dtype=jnp.float32) -> jax.Array:
    n = math.prod(q.orig_shape) if q.orig_shape else 0
    flat = (q.values.astype(jnp.float32) * q.scales).reshape(-1)[:n]
    return flat.reshape(q.orig_shape).astype(dtype)


def quantize_symmetric_int8(x: jax.Array, axis=-1):
    """Return (int8 values, f32 scales broadcastable along `axis`)."""
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def rowwise_quant_error_bound(q: QuantizedTensor) -> jax.Array:
    """Max abs error of row-wise quantization is scale/2 per element."""
    return q.scales / 2.0
