"""CTR-buffer threshold top-k (iMARS Sec. III-C step 2e).

The paper stores (CTR, item index) pairs in a CMA and retrieves the final
top-k by threshold-match against an all-1s query. Software semantics: select
items with score >= threshold, return up to k of them, highest first; with
threshold = -inf this degrades to plain top-k (the paper's functional goal).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TopKResult(NamedTuple):
    scores: jax.Array  # (..., k) f32, -inf padded
    indices: jax.Array  # (..., k) int32, -1 padded
    counts: jax.Array  # (...,) int32 — matches above threshold


def threshold_topk(scores: jax.Array, threshold: float, k: int) -> TopKResult:
    mask = scores >= threshold
    counts = jnp.sum(mask, axis=-1).astype(jnp.int32)
    masked = jnp.where(mask, scores, -jnp.inf)
    vals, idx = jax.lax.top_k(masked, k=min(k, scores.shape[-1]))
    valid = jnp.isfinite(vals)
    idx = jnp.where(valid, idx, -1)
    if idx.shape[-1] < k:
        pad = k - idx.shape[-1]
        pad_widths = [(0, 0)] * (idx.ndim - 1) + [(0, pad)]
        idx = jnp.pad(idx, pad_widths, constant_values=-1)
        vals = jnp.pad(vals, pad_widths, constant_values=-jnp.inf)
    return TopKResult(scores=vals, indices=idx, counts=counts)
