"""Data pipelines: synthetic RecSys datasets + LM token streams."""
