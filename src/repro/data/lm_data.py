"""LM token pipeline: deterministic synthetic stream with background
prefetch (double-buffered host-side loading — the straggler-mitigation hook:
a slow host never stalls the step as long as the prefetch queue is ahead).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


def synthetic_token_stream(
    vocab_size: int,
    seq_len: int,
    batch_size: int,
    seed: int = 0,
    n_codebooks: int = 0,
) -> Iterator[dict]:
    """Markov-ish synthetic tokens (next-token structure so loss can fall)."""
    rng = np.random.default_rng(seed)
    step = 0
    while True:
        shape = (
            (batch_size, n_codebooks, seq_len + 1)
            if n_codebooks
            else (batch_size, seq_len + 1)
        )
        base = rng.integers(0, vocab_size, shape)
        # plant structure: even positions predict the next token
        toks = base.copy()
        toks[..., 1::2] = (toks[..., 0::2][..., : toks[..., 1::2].shape[-1]]
                           + 1) % vocab_size
        yield {
            "tokens": toks[..., :-1].astype(np.int32),
            "labels": toks[..., 1:].astype(np.int32),
            "step": step,
        }
        step += 1


class PrefetchIterator:
    """Background-thread prefetcher with a bounded queue.

    `depth` batches are loaded ahead; `get(timeout)` raises on a stuck
    producer so the fault-tolerant trainer can log the straggler and retry.
    """

    def __init__(self, it: Iterator, depth: int = 4):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        except Exception as e:  # surfaced on next get()
            self._err = e
        finally:
            self._q.put(self._done)

    def get(self, timeout: float | None = None):
        item = self._q.get(timeout=timeout)
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def __iter__(self):
        return self

    def __next__(self):
        try:
            return self.get()
        except StopIteration:
            raise
