"""Deterministic synthetic datasets matching the paper's workloads.

MovieLens-1M-like: 6040 users x 3000 items with latent-factor preference
structure, demographic features, and per-user watch histories; leave-one-out
test split (the YoutubeDNN HR evaluation protocol). Criteo-like: 13 dense +
26 categorical (28000 rows each) with a planted logistic CTR model.

Real MovieLens/Criteo are not available offline; generators keep the
cardinalities and marginal statistics so the mapping (Table I) and the
accuracy *ordering* (Sec. IV-B) are reproducible. See DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class MovieLensSynth:
    n_users: int
    n_items: int
    user_feats: dict  # name -> (n_users,) int arrays
    histories: np.ndarray  # (n_users, H) item ids, -1 padded
    train_labels: np.ndarray  # (n_users,) next-item label for training
    test_labels: np.ndarray  # (n_users,) held-out item (leave-one-out)
    genres: np.ndarray  # (n_users,) favourite genre id
    item_factors: np.ndarray  # (n_items, d) ground-truth latents


def make_movielens(
    n_users: int = 6040,
    n_items: int = 3000,
    history_len: int = 20,
    latent_dim: int = 16,
    seed: int = 0,
) -> MovieLensSynth:
    rng = np.random.default_rng(seed)
    # latent structure: users cluster around genre archetypes
    n_genres = 18
    genre_centers = rng.normal(size=(n_genres, latent_dim))
    item_genre = rng.integers(0, n_genres, size=n_items)
    item_factors = genre_centers[item_genre] + 0.6 * rng.normal(
        size=(n_items, latent_dim))
    user_genre = rng.integers(0, n_genres, size=n_users)
    user_factors = genre_centers[user_genre] + 0.5 * rng.normal(
        size=(n_users, latent_dim))

    # per-user preference sampling (top-biased) -> watch history + labels.
    # history/train labels come from the NOISY preference order (diverse
    # watching); the held-out TEST label is the best CLEAN-score unseen item
    # — predictable from the latent structure (not memorizable from the
    # train label), which is what the HR protocol measures.
    scores = user_factors @ item_factors.T  # (U, I)
    noise = rng.gumbel(size=scores.shape) * 1.5
    order = np.argsort(-(scores + noise), axis=1)
    seq = order[:, : history_len + 1]
    histories = seq[:, :history_len].astype(np.int32)
    train_labels = seq[:, history_len].astype(np.int32)
    clean = scores.copy()
    np.put_along_axis(clean, seq, -np.inf, axis=1)  # exclude seen items
    test_labels = np.argmax(clean, axis=1).astype(np.int32)

    user_feats = {
        "user_id": np.arange(n_users, dtype=np.int32),
        "gender": rng.integers(0, 3, n_users).astype(np.int32),
        "age": rng.integers(0, 7, n_users).astype(np.int32),
        "occupation": rng.integers(0, 21, n_users).astype(np.int32),
        "zip_bucket": rng.integers(0, 250, n_users).astype(np.int32),
    }
    return MovieLensSynth(
        n_users=n_users, n_items=n_items, user_feats=user_feats,
        histories=histories, train_labels=train_labels,
        test_labels=test_labels, genres=user_genre.astype(np.int32),
        item_factors=item_factors,
    )


def serving_queries(data: MovieLensSynth, idx) -> list[dict]:
    """Single-user serving query dicts for users `idx` — the submit()
    schema of `serving.MicroBatcher` / `AsyncServer` (user feature scalars
    + history vector + genre). One definition so benchmarks and tests
    can't drift from the batcher's expected query layout."""
    return [{**{k: v[i] for k, v in data.user_feats.items()},
             "history": data.histories[i], "genre": data.genres[i]}
            for i in idx]


def movielens_batches(data: MovieLensSynth, batch_size: int, n_steps: int,
                      seed: int = 1):
    """Training batch iterator for the filtering model."""
    rng = np.random.default_rng(seed)
    for _ in range(n_steps):
        idx = rng.integers(0, data.n_users, batch_size)
        yield {
            **{k: v[idx] for k, v in data.user_feats.items()},
            "history": data.histories[idx],
            "genre": data.genres[idx],
            "label": data.train_labels[idx],
        }


def movielens_rank_batches(data: MovieLensSynth, batch_size: int,
                           n_cand: int, n_steps: int, seed: int = 2):
    """Ranking batches: candidates = 1 positive + sampled negatives."""
    rng = np.random.default_rng(seed)
    for _ in range(n_steps):
        idx = rng.integers(0, data.n_users, batch_size)
        neg = rng.integers(0, data.n_items, (batch_size, n_cand - 1))
        pos = data.train_labels[idx][:, None]
        cands = np.concatenate([pos, neg], axis=1).astype(np.int32)
        labels = np.zeros_like(cands)
        labels[:, 0] = 1
        perm = rng.permuted(np.arange(n_cand)[None].repeat(batch_size, 0),
                            axis=1)
        cands = np.take_along_axis(cands, perm, 1)
        labels = np.take_along_axis(labels, perm, 1)
        yield {
            **{k: v[idx] for k, v in data.user_feats.items()},
            "history": data.histories[idx],
            "genre": data.genres[idx],
            "cand_items": cands,
            "cand_labels": labels,
        }


# ---------------------------------------------------------------------------
# Criteo-like
# ---------------------------------------------------------------------------
def make_criteo_batches(
    batch_size: int,
    n_steps: int,
    n_dense: int = 13,
    n_sparse: int = 26,
    cardinality: int = 28000,
    seed: int = 0,
):
    """Planted logistic CTR model over dense + hashed categorical features."""
    rng = np.random.default_rng(seed)
    w_dense = rng.normal(size=n_dense) * 0.5
    cat_effect = rng.normal(size=(n_sparse, 64)) * 0.4  # low-rank cat effects
    for _ in range(n_steps):
        dense = rng.normal(size=(batch_size, n_dense)).astype(np.float32)
        sparse = rng.integers(
            0, cardinality, (batch_size, n_sparse)).astype(np.int32)
        logit = dense @ w_dense
        for j in range(n_sparse):
            logit += cat_effect[j, sparse[:, j] % 64]
        prob = 1.0 / (1.0 + np.exp(-(logit - 1.0)))
        label = (rng.random(batch_size) < prob).astype(np.int32)
        yield {"dense": dense, "sparse": sparse, "label": label}
