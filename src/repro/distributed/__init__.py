"""Distribution substrate: sharding rules, collectives, fault tolerance."""
