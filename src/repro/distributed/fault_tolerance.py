"""Fault-tolerant training loop: checkpoint/restart, per-step retry,
straggler detection, fault injection for tests.

Node-failure semantics on a real cluster: a dead host kills the step; the
job restarts (possibly elastically with a different data-parallel degree),
`TrainLoop` resumes from the last committed checkpoint, and the restore
path reshards onto whatever mesh the restarted job has (checkpointer stores
full logical arrays). Everything in that sentence is exercised by
tests/test_fault_tolerance.py on CPU: kill mid-run -> restart -> bitwise
continuation; restore onto a different mesh size.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class FaultPolicy:
    max_retries_per_step: int = 2
    checkpoint_every: int = 50
    straggler_factor: float = 3.0  # step slower than EMA*factor -> straggler
    ema_alpha: float = 0.2
    data_timeout_s: float = 60.0


@dataclasses.dataclass
class StepRecord:
    step: int
    metrics: dict
    duration_s: float
    retries: int = 0
    straggler: bool = False


class TrainLoop:
    """Drives train_step with checkpoint/restart + retry + straggler log.

    fault_hook: optional callable(step) raising to simulate transient
    failures (used by tests; on real hardware this is where preemption
    signals surface).
    """

    def __init__(
        self,
        train_step: Callable[[Any, dict], tuple[Any, dict]],
        checkpointer: Checkpointer,
        policy: FaultPolicy = FaultPolicy(),
        fault_hook: Callable[[int], None] | None = None,
    ):
        self.train_step = train_step
        self.ckpt = checkpointer
        self.policy = policy
        self.fault_hook = fault_hook
        self.records: list[StepRecord] = []
        self.straggler_events: list[int] = []
        self._ema: float | None = None

    def resume_or_init(self, init_state_fn: Callable[[], Any],
                       shardings: Any = None):
        template = jax.eval_shape(init_state_fn)
        step, state = self.ckpt.restore_latest(template, shardings)
        if state is None:
            log.info("no checkpoint found; initializing fresh state")
            return init_state_fn(), 0
        log.info("resumed from checkpoint step %d", step)
        return state, int(step)

    def run(self, state: Any, data: Iterator[dict], n_steps: int,
            start_step: int = 0):
        step = start_step
        it = iter(data)
        while step < n_steps:
            batch = next(it)
            retries = 0
            while True:
                try:
                    if self.fault_hook is not None:
                        self.fault_hook(step)
                    t0 = time.monotonic()
                    state, metrics = self.train_step(state, batch)
                    metrics = {k: float(np.asarray(v))
                               for k, v in metrics.items()}
                    dt = time.monotonic() - t0
                    break
                except _TRANSIENT as e:
                    retries += 1
                    if retries > self.policy.max_retries_per_step:
                        # unrecoverable on this incarnation: persist and die;
                        # the restart path picks up from the last checkpoint
                        self.ckpt.wait()
                        raise
                    log.warning("step %d failed (%s); retry %d",
                                step, e, retries)

            straggler = False
            if self._ema is not None and dt > self.policy.straggler_factor \
                    * self._ema:
                straggler = True
                self.straggler_events.append(step)
                # mitigation: defer non-critical work (metrics flush /
                # checkpoint) out of the slow step's shadow
                log.warning("straggler step %d: %.3fs vs EMA %.3fs",
                            step, dt, self._ema)
            self._ema = dt if self._ema is None else (
                self.policy.ema_alpha * dt
                + (1 - self.policy.ema_alpha) * self._ema)

            self.records.append(StepRecord(step=step, metrics=metrics,
                                           duration_s=dt, retries=retries,
                                           straggler=straggler))
            step += 1
            if step % self.policy.checkpoint_every == 0 and not straggler:
                self.ckpt.save(step, state)
        self.ckpt.save(step, state)
        self.ckpt.wait()
        return state, step


class SimulatedTransientFailure(RuntimeError):
    pass


_TRANSIENT = (SimulatedTransientFailure,)
