"""Logical-axis sharding rules: how params/activations map onto the mesh.

Mesh axes: ("data", "model") single pod, ("pod", "data", "model") multi-pod.

Param dims are tagged with logical tokens:
    "tp"   -> model axis           (TP: heads / mlp / vocab dims)
    "fsdp" -> data axes if FSDP    (ZeRO-3 storage sharding; all-gathered
              is enabled, else None  per layer inside the scan — overlap via
                                     XLA async collectives pipelining)
    "ep"   -> model axis           (expert dim of MoE weight stacks)
    None   -> replicated

Activation constraint points use logical names resolved through the active
`ShardingRules` (a contextvar set by the train/serve step builders):
    act_batch  -> (pod?, data)     act_heads -> model
    act_seq    -> model if seq_shard (sequence parallelism) else None
    act_mlp    -> model            act_experts -> model
    act_vocab  -> model            act_kv_seq -> data for long-context decode
`constrain()` is a no-op outside a rules context, so model code runs
unchanged on a single device (smoke tests) and under jit+mesh (dry-run).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import re
from typing import Any, Mapping

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    data_axes: tuple = ("data",)  # ("pod","data") in multi-pod
    model_axis: str = "model"
    fsdp: bool = False
    seq_shard: bool = False
    kv_seq_data: bool = False  # long-context decode: KV seq over data
    batch_data: bool = True  # decode "2d" mode may replicate batch
    # False when rep_kv_heads doesn't divide the model axis (e.g. llama4's
    # 40 heads on a 16-way axis): attention activations replicate over
    # `model` and the KV cache seq-shards over `model` instead (flash-decode
    # layout); attention WEIGHTS stay channel-sharded either way.
    shard_heads: bool = True
    # §Perf: shard expert weights' FF dim (not d_model) over the data axes,
    # so expert compute runs on local shards + a small psum instead of
    # all-gathering expert weights (decode: 6.2 GB/step -> ~0)
    moe_ff_fsdp: bool = False

    def param_axis(self, token: str | None):
        if token == "tp" or token == "ep":
            return self.model_axis
        if token == "fsdp":
            return self.data_axes if self.fsdp else None
        return None

    def param_spec(self, tokens: tuple) -> P:
        return P(*[self.param_axis(t) for t in tokens])

    def act_axis(self, name: str | None):
        if name is None:
            return None
        return {
            "act_batch": self.data_axes if self.batch_data else None,
            "act_seq": self.model_axis if self.seq_shard else None,
            "act_kv_seq": self.data_axes if self.kv_seq_data else None,
            "act_heads": self.model_axis if self.shard_heads else None,
            "act_mlp": self.model_axis,
            "act_experts": self.model_axis,
            "act_vocab": self.model_axis,
            "act_embed": None,
        }[name]

    def act_spec(self, names: tuple) -> P:
        return P(*[self.act_axis(n) for n in names])


_ACTIVE_RULES: contextvars.ContextVar[ShardingRules | None] = (
    contextvars.ContextVar("repro_sharding_rules", default=None)
)


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    token = _ACTIVE_RULES.set(rules)
    try:
        yield
    finally:
        _ACTIVE_RULES.reset(token)


def active_rules() -> ShardingRules | None:
    return _ACTIVE_RULES.get()


def constrain(x: jax.Array, names: tuple) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without rules."""
    rules = _ACTIVE_RULES.get()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.act_spec(names))


# ---------------------------------------------------------------------------
# Param path -> logical tokens (regex on "/"-joined tree path)
# ---------------------------------------------------------------------------
PARAM_PATTERNS: list[tuple[str, tuple]] = [
    # embeddings / heads: vocab over model, embed over fsdp
    (r"embed(/codebooks)?$", ("tp", "fsdp")),
    (r"lm_head(/\d+)?$", ("fsdp", "tp")),
    # attention
    (r"attn/wq/w$", ("fsdp", "tp")),
    (r"attn/wk/w$", ("fsdp", "tp")),
    (r"attn/wv/w$", ("fsdp", "tp")),
    (r"attn/wo/w$", ("tp", "fsdp")),
    (r"attn/w[qkv]/b$", ("tp",)),
    (r"attn/wo/b$", (None,)),
    (r"attn/(q|k)_norm$", (None,)),
    # dense mlp
    (r"mlp/w(i|g)/w$", ("fsdp", "tp")),
    (r"mlp/wo/w$", ("tp", "fsdp")),
    (r"mlp/w./b$", (None,)),
    # moe: expert-stacked weights -> EP over model, inner dims over fsdp
    (r"moe/w(i|g)$", ("ep", "fsdp", None)),
    (r"moe/wo$", ("ep", None, "fsdp")),
    (r"moe/router$", (None, None)),
    (r"moe/shared/w(i|g)/w$", ("fsdp", "tp")),
    (r"moe/shared/wo/w$", ("tp", "fsdp")),
    # mamba2
    (r"ssm/in_proj$", ("fsdp", "tp")),
    (r"ssm/out_proj$", ("tp", "fsdp")),
    (r"ssm/conv_w$", (None, "tp")),
    (r"ssm/conv_b$", ("tp",)),
    (r"ssm/(A_log|D|dt_bias)$", (None,)),
    (r"ssm/norm_w$", ("tp",)),
    # norms / everything small
    (r"(norm|norm1|norm2|final_norm)(/w)?$", (None,)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def logical_tokens_for(path_str: str, ndim: int) -> tuple:
    for pattern, tokens in PARAM_PATTERNS:
        if re.search(pattern, path_str):
            if len(tokens) != ndim:
                # rank mismatch (e.g. stacked-by-layer leading dim): pad left
                return (None,) * (ndim - len(tokens)) + tuple(tokens)
            return tokens
    return (None,) * ndim


_MOE_FF_SWAP = [
    (re.compile(r"moe/w(i|g)$"), ("ep", None, "fsdp")),  # F over data
    (re.compile(r"moe/wo$"), ("ep", "fsdp", None)),
]


def param_partition_specs(params: Any, rules: ShardingRules):
    """Tree of PartitionSpec matching `params` (stacked layer dims -> None)."""

    def spec(path, leaf):
        ps = _path_str(path)
        tokens = logical_tokens_for(ps, leaf.ndim)
        if rules.moe_ff_fsdp:
            for pat, swapped in _MOE_FF_SWAP:
                if pat.search(ps):
                    tokens = ((None,) * (leaf.ndim - len(swapped))
                              + tuple(swapped))
                    break
        return rules.param_spec(tokens)

    return jax.tree_util.tree_map_with_path(spec, params)


def param_shardings(params: Any, mesh, rules: ShardingRules):
    specs = param_partition_specs(params, rules)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)
