"""train_step: gradient-accumulation scan + remat + chunked vocab-sharded
cross-entropy + AdamW (configurable state precision) + optional int8
gradient compression with error feedback.

The logits for a 405B model at (32, 4096) microbatch would be 34 GB — the
chunked CE never materializes them: per sequence chunk, logits are computed
vocab-sharded (P(batch, None, model)), reduced with fp32 logsumexp, and
dropped. This is the "hierarchical adder tree" shape again: partial
(per-shard) reductions followed by a small cross-shard combine.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.distributed.sharding import constrain
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.optim import adamw
from repro.optim.compression import compress_decompress, init_error_buffer
from repro.utils import pytree_dataclass


@pytree_dataclass
class TrainState:
    params: Any
    opt: adamw.AdamWState
    step: jax.Array
    err_buf: Any = None  # int8 grad-compression error feedback (optional)


def init_train_state(cfg: ModelConfig, pcfg: ParallelConfig, key) -> TrainState:
    params = tf.init_params(cfg, key)
    opt = adamw.init_adamw_state(params, pcfg.opt_state_dtype)
    err = init_error_buffer(params) if pcfg.grad_compression else None
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32), err_buf=err)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def _ce_from_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL, fp32. logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_cross_entropy(params, cfg: ModelConfig, hidden: jax.Array,
                          labels: jax.Array, chunk: int) -> jax.Array:
    """hidden (B, S, D), labels (B, S) -> mean NLL without (B, S, V) logits."""
    B, S, D = hidden.shape
    if chunk <= 0 or S % chunk != 0 or S == chunk:
        return _ce_from_logits(tf.unembed(params, cfg, hidden), labels)
    n = S // chunk
    h = hidden.reshape(B, n, chunk, D).swapaxes(0, 1)  # (n, B, c, D)
    l = labels.reshape(B, n, chunk).swapaxes(0, 1)

    # remat the chunk: otherwise the scan's backward SAVES the per-chunk
    # logits — i.e. the full (B, S, V) fp32 logits we are avoiding
    @jax.checkpoint
    def chunk_nll(hc, lc):
        logits = tf.unembed(params, cfg, hc)  # (B, c, V) vocab-sharded
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], -1)[..., 0]
        return jnp.sum(lse - gold)

    def body(acc, xs):
        hc, lc = xs
        return acc + chunk_nll(hc, lc), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (h, l))
    return total / (B * S)


def lm_loss(params, cfg: ModelConfig, pcfg: ParallelConfig,
            batch: dict) -> tuple[jax.Array, dict]:
    out = tf.forward(params, cfg, batch, mode="train", remat=pcfg.remat,
                     logits_mode="none")
    labels = batch["labels"]
    if cfg.family == "audio":
        logits = tf.unembed(params, cfg, out.hidden)  # (B, S, K, V)
        nll = _ce_from_logits(jnp.moveaxis(logits, 2, 1), labels)
    else:
        nll = chunked_cross_entropy(params, cfg, out.hidden, labels,
                                    pcfg.logit_chunk)
    loss = nll + cfg.router_aux_weight * out.aux_loss
    return loss, {"nll": nll, "aux": out.aux_loss}


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig,
                    shape: ShapeConfig, *, base_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    grad_shardings: Any = None
                    ) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    """Returns train_step(state, batch); batch leaves have a leading
    gradient-accumulation axis: tokens (accum, mb, ...).

    grad_shardings (§Perf iteration 4): constraining each microbatch's
    gradients to the FSDP-sharded accumulator spec lets XLA emit
    reduce-scatters instead of full all-reduces inside the accumulation
    scan — 16x less gradient traffic on a 16-wide data axis.
    """
    lr_fn = adamw.cosine_schedule(base_lr, warmup, total_steps)
    accum = pcfg.accum_for(shape.name)

    def _constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, grads, grad_shardings)

    def train_step(state: TrainState, batch: dict):
        params = state.params

        def loss_fn(p, mb):
            return lm_loss(p, cfg, pcfg, mb)

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def accum_body(carry, mb):
            g_acc, loss_acc = carry
            (loss, _aux), grads = grad_fn(params, mb)
            grads = _constrain_grads(grads)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (g_acc, loss_acc + loss), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if accum == 1:
            mb = jax.tree_util.tree_map(lambda x: x[0], batch)
            (loss, _aux), grads = grad_fn(params, mb)
            grads = _constrain_grads(grads)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
        else:
            (grads, loss_sum), _ = jax.lax.scan(
                accum_body, (g0, jnp.zeros((), jnp.float32)), batch)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss_sum / accum

        new_err = state.err_buf
        if pcfg.grad_compression and state.err_buf is not None:
            grads, new_err = compress_decompress(grads, state.err_buf)

        grads, gnorm = adamw.clip_by_global_norm(grads, 1.0)
        lr = lr_fn(state.step)
        new_params, new_opt = adamw.adamw_update(
            grads, state.opt, params, lr,
            state_dtype=pcfg.opt_state_dtype)
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1, err_buf=new_err)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# RecSys filtering-model train step (the online-learning path)
# ---------------------------------------------------------------------------
def init_recsys_train_state(params: Any) -> TrainState:
    """Optimizer state for the YoutubeDNN filtering model.

    Reuses the LM `TrainState` container (params + AdamW state + step);
    no error buffer — the filtering model's gradients are never
    int8-compressed (they feed the quantize-at-ingestion catalog path,
    which quantizes the *parameters*, not the gradients).
    """
    return TrainState(params=params, opt=adamw.init_adamw_state(params),
                      step=jnp.zeros((), jnp.int32), err_buf=None)


def make_recsys_train_step(cfg: rs.YoutubeDNNConfig, *, lr: float = 3e-3,
                           weight_decay: float = 0.0
                           ) -> Callable[[TrainState, dict],
                                         tuple[TrainState, jax.Array]]:
    """One jitted filtering-model gradient step: ``(state, batch) ->
    (state', loss)``.

    The exact training computation of ``benchmarks/accuracy_hr.py``
    (full-softmax `recsys.filtering_loss` + AdamW at a flat lr) packaged
    as a reusable step so `serving/online.py` trains *the same model the
    engine was built from* — the train-while-serve bit-match contract
    (live folds vs a cold rebuild of the current params) only holds when
    online steps and the offline pretraining share one loss and update
    rule. Batches come from `data.synthetic.movielens_batches`.
    """

    @jax.jit
    def train_step(state: TrainState, batch: dict):
        loss, grads = jax.value_and_grad(
            lambda p: rs.filtering_loss(p, cfg, batch))(state.params)
        params, opt = adamw.adamw_update(grads, state.opt, state.params, lr,
                                         weight_decay=weight_decay)
        return TrainState(params=params, opt=opt, step=state.step + 1,
                          err_buf=None), loss

    return train_step
