"""Pallas TPU kernels (+ pure-jnp oracles and dispatching wrappers).

Layout (per the kernel contract):
  <name>.py  - pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py     - kernel backend registry + jit'd public wrappers
               (pallas/interpret/ref dispatch, per-op env overrides)
  ref.py     - pure-jnp oracles (ground truth for allclose tests)
"""
from repro.kernels import ops, ref  # noqa: F401
