"""Pallas TPU kernel: fused int8 dequant-gather-pool (embedding bag).

This is the TPU-native image of the iMARS CMA RAM-mode lookup + in-memory
adder + intra-mat adder tree (Sec. III-A1): each grid step DMAs exactly one
int8 table row (one "CMA row") from HBM into VMEM via a scalar-prefetched
index, dequantizes it, and accumulates into the output block that stays
resident in VMEM across the pooling dimension — partial sums never round-trip
to HBM, which is the in-memory-computing property the paper is after.

Grid: (bags, d_blocks, slots) with `slots` innermost so the (1, block_d)
output tile is revisited consecutively while accumulating (Pallas keeps it in
VMEM between steps). Padding slots carry id 0 / weight 0.

The table stays int8 in HBM: bytes touched per bag = L rows * d bytes — 4x
less than an f32 table, which is exactly the memory-roofline win quantization
buys (the paper's density argument, restated in bytes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils import cdiv


def _pool_kernel(ids_ref, table_ref, scales_ref, w_ref, out_ref, *, n_slots):
    slot = pl.program_id(2)

    @pl.when(slot == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    row = table_ref[...].astype(jnp.float32)  # (1, block_d)
    scale = scales_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0]
    out_ref[...] += row * (scale * w)


@functools.partial(
    jax.jit, static_argnames=("block_d", "interpret")
)
def embedding_pool_pallas(
    table_values: jax.Array,  # (n, d) int8
    table_scales: jax.Array,  # (n, 1) f32
    ids: jax.Array,  # (B, L) int32, -1 padding
    weights: jax.Array | None = None,  # (B, L) f32
    *,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    n, d = table_values.shape
    B, L = ids.shape
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)

    valid = (ids >= 0).astype(jnp.float32)
    w = valid if weights is None else weights.astype(jnp.float32) * valid
    safe_ids = jnp.maximum(ids, 0).astype(jnp.int32)
    flat_ids = safe_ids.reshape(-1)

    grid = (B, d // block_d, L)

    kernel = functools.partial(_pool_kernel, n_slots=L)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # one table row block per step, row chosen by prefetched id
                pl.BlockSpec(
                    (1, block_d), lambda b, k, l, ids: (ids[b * L + l], k)
                ),
                pl.BlockSpec((1, 1), lambda b, k, l, ids: (ids[b * L + l], 0)),
                pl.BlockSpec((1, 1), lambda b, k, l, ids: (b, l)),
            ],
            out_specs=pl.BlockSpec((1, block_d), lambda b, k, l, ids: (b, k)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )(flat_ids, table_values, table_scales, w)
    return out
