"""Pallas TPU kernel: causal flash attention (forward).

Blocked online-softmax attention with running (m, l, acc) state held in VMEM
scratch across the kv grid dimension. Used for 32k prefill on TPU; the
numerical contract is kernels/ref.py::blocked_attention_ref (and the full
softmax oracle), asserted in tests across shape/dtype sweeps.

Grid: (batch*heads, q_blocks, kv_blocks), kv innermost. Causal blocks past
the diagonal are skipped via pl.when (Pallas has no ragged grids; the skip
makes them no-ops — on TPU Mosaic still schedules the step, so the optimized
serving path additionally clamps the kv extent per q block in the wrapper).

Backward pass: training on TPU uses jax.custom_vjp with the blocked ref as
the bwd rule (remat-style recompute); a hand-written bwd kernel is left as a
documented non-goal — the fwd kernel is the serving hot path this paper
cares about.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils import cdiv

_NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    out_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    kv_len: int,
    q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # block is active iff its first kv index <= last (offset) q index
        run = ki * block_k <= q_offset + qi * block_q + block_q - 1

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = s * scale  # (bq, bk)

        rows = q_offset + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0
        )
        cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < kv_len
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        out_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "scale", "block_q", "block_k", "q_offset", "interpret"
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # (bh, sq, d)
    k: jax.Array,  # (bh, sk, d)
    v: jax.Array,  # (bh, sk, d)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """q_offset: position of q[0] within the kv sequence (chunked prefill);
    causal masking compares (q_offset + i) vs kv index j."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = (d**-0.5) if scale is None else scale

    sqp = cdiv(sq, block_q) * block_q
    skp = cdiv(sk, block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, sqp - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skp - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skp - sk), (0, 0)))

    grid = (bh, sqp // block_q, skp // block_k)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        kv_len=sk,
        q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :]
