"""Pallas TPU kernel: Hamming distance sweep over packed LSH signatures.

TPU adaptation of the iMARS TCAM threshold search (Sec. III-A/B): the analog
O(1) matchline compare becomes a VPU-rate XOR + popcount sweep over uint32
lanes. Signatures are packed 256 bits -> 8 x uint32, so one (block_n, 8)
VMEM tile covers block_n items; the kernel emits raw distances and the
threshold (fixed-radius) selection stays in plain XLA (it is a trivial
compare + top-k over the int32 distance matrix).

Block geometry: db tile (block_n, words) and query tile (block_q, words) live
in VMEM; output tile is (block_q, block_n) int32. With block_n = 1024 and
words = 8 the working set is ~32 KiB db + 4 MiB out per step — well inside
the ~16 MiB v5e VMEM, and the lane dimension (block_n) is a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils import cdiv


def _hamming_kernel(q_ref, db_ref, out_ref):
    q = q_ref[...]  # (block_q, words) uint32
    db = db_ref[...]  # (block_n, words) uint32
    x = jnp.bitwise_xor(q[:, None, :], db[None, :, :])  # (bq, bn, w)
    out_ref[...] = jnp.sum(
        jax.lax.population_count(x).astype(jnp.int32), axis=-1
    )


@functools.partial(jax.jit, static_argnames=("block_q", "block_n", "interpret"))
def hamming_distances_pallas(
    queries: jax.Array,  # (q, words) uint32
    db: jax.Array,  # (n, words) uint32
    *,
    block_q: int = 8,
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """(q, n) int32 Hamming distances between packed signatures."""
    q, words = queries.shape
    n, words2 = db.shape
    assert words == words2, (words, words2)

    # pad to block multiples; padded db rows produce garbage distances that
    # the wrapper slices away.
    qp = cdiv(q, block_q) * block_q
    np_ = cdiv(n, block_n) * block_n
    queries_p = jnp.pad(queries, ((0, qp - q), (0, 0)))
    db_p = jnp.pad(db, ((0, np_ - n), (0, 0)))

    grid = (qp // block_q, np_ // block_n)
    out = pl.pallas_call(
        _hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, words), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, words), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, np_), jnp.int32),
        interpret=interpret,
    )(queries_p, db_p)
    return out[:q, :n]
