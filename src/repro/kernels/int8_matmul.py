"""Pallas TPU kernel: int8 x int8 -> int32 MXU matmul with per-row/col scales.

TPU adaptation of the iMARS crossbar MVM (Sec. III-A2): the analog
current-summed matrix-vector product becomes an int8 systolic matmul on the
MXU with int32 accumulation and per-channel dequantization — the same
quantization contract (int8 weights and activations, higher-precision
accumulate) the paper's crossbars assume.

Blocking: (block_m, block_k) x (block_k, block_n) tiles with an int32 VMEM
scratch accumulator; k is the innermost grid dimension so the accumulator is
revisited consecutively. All block dims default to MXU-aligned multiples of
128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils import cdiv


def _matmul_kernel(x_ref, w_ref, sx_ref, sw_ref, out_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        out_ref[...] = (
            acc_ref[...].astype(jnp.float32) * sx_ref[...] * sw_ref[...]
        )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def int8_matmul_pallas(
    x: jax.Array,  # (m, k) int8
    w: jax.Array,  # (k, n) int8
    x_scale: jax.Array,  # (m, 1) f32
    w_scale: jax.Array,  # (1, n) f32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2

    mp, np_, kp = (
        cdiv(m, block_m) * block_m,
        cdiv(n, block_n) * block_n,
        cdiv(k, block_k) * block_k,
    )
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    sxp = jnp.pad(x_scale, ((0, mp - m), (0, 0)))
    swp = jnp.pad(w_scale, ((0, 0), (0, np_ - n)))

    grid = (mp // block_m, np_ // block_n, kp // block_k)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(xp, wp, sxp, swp)
    return out[:m, :n]
