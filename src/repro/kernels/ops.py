"""jit'd public wrappers with a kernel backend registry.

Every kernel is registered once via `register_kernel(name, ref=..., pallas=...)`
and all public ops share one dispatch path instead of copy-pasted mode
branches. Three backends per op:

  * ``pallas``    — compiled Pallas kernel (the TPU fast path)
  * ``interpret`` — the same kernel through the Pallas interpreter
                    (CPU correctness checks of the real kernel code)
  * ``ref``       — the pure-jnp oracle in kernels/ref.py (fast to compile,
                    same numerics; the default CPU execution path)

Mode resolution, most-specific first:

  1. ``REPRO_PALLAS_<OP>`` — per-op override, e.g.
     ``REPRO_PALLAS_STREAMING_NNS=interpret`` or
     ``REPRO_PALLAS_HAMMING_DISTANCES=ref``
  2. ``REPRO_PALLAS`` — global override (``pallas`` | ``interpret`` | ``ref``)
  3. auto: ``pallas`` on TPU backends, ``ref`` everywhere else

Model code always calls the public wrappers below and never cares which
backend ran. Ops with no Pallas implementation fall back to their ref.
"""
from __future__ import annotations

import functools
import math
import mmap
import os
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.embedding_pool import embedding_pool_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hamming_nns import hamming_distances_pallas
from repro.kernels.int8_matmul import int8_matmul_pallas
from repro.kernels.streaming_nns import (
    BIG_DIST,
    merge_chunk_buffers,
    streaming_nns_pallas,
)
from repro.utils import round_up

_MODES = ("pallas", "interpret", "ref")


class KernelOp(NamedTuple):
    ref: Callable
    pallas: Callable | None  # called with an extra interpret= kwarg


_REGISTRY: dict[str, KernelOp] = {}


def register_kernel(name: str, *, ref: Callable,
                    pallas: Callable | None = None) -> None:
    """Register one kernel's backends under `name` (see module docstring)."""
    if name in _REGISTRY:
        raise ValueError(f"kernel {name!r} already registered")
    _REGISTRY[name] = KernelOp(ref=ref, pallas=pallas)


def registered_kernels() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def kernel_mode(name: str) -> str:
    """'pallas' | 'interpret' | 'ref' for op `name` (env overrides, then auto)."""
    for env in (f"REPRO_PALLAS_{name.upper()}", "REPRO_PALLAS"):
        value = os.environ.get(env, "")
        if value in _MODES:
            return value
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def dispatch(name: str, *args, **kwargs):
    """Route one op call to its registered backend for the current mode."""
    op = _REGISTRY[name]
    mode = kernel_mode(name)
    if mode == "ref" or op.pallas is None:
        return op.ref(*args, **kwargs)
    return op.pallas(*args, interpret=(mode == "interpret"), **kwargs)


# ---------------------------------------------------------------------------
# per-op pallas adapters (block sizing + input massaging live here)
# ---------------------------------------------------------------------------
def _hamming_block_n(n: int) -> int:
    """DB-block rows: 1024 cap, 128-lane aligned, never rounded past the
    128-aligned row count (n=300 used to get a 512 block via next-pow2)."""
    return min(1024, max(128, round_up(n, 128)))


def _hamming_pallas(queries, db, *, interpret):
    return hamming_distances_pallas(
        queries, db, block_n=_hamming_block_n(db.shape[0]),
        interpret=interpret)


def _embedding_pool_pallas(table_values, table_scales, ids, weights=None, *,
                           interpret):
    d = table_values.shape[1]
    block_d = d if d <= 512 else 512
    if d % block_d != 0:
        block_d = d  # fall back to unblocked when not divisible
    valid = (ids >= 0).astype(jnp.float32)
    w = valid if weights is None else weights.astype(jnp.float32) * valid
    return embedding_pool_pallas(
        table_values, table_scales, ids, w, block_d=block_d,
        interpret=interpret)


def _flash_attention_pallas(q, k, v, *, causal=True, scale=None, interpret):
    b, h, sq, d = q.shape
    out = flash_attention_pallas(
        q.reshape(b * h, sq, d),
        k.reshape(b * h, k.shape[2], d),
        v.reshape(b * h, v.shape[2], d),
        causal=causal, scale=scale, interpret=interpret)
    return out.reshape(b, h, sq, d)


def _streaming_nns_ref(queries, db, *, radius, max_candidates, scan_block,
                       n_valid, superblock=None, db_mask=None,
                       prune_blocks=None, prune_block_rows=None):
    return ref.streaming_nns_ref(
        queries, db, radius, max_candidates, scan_block=scan_block,
        n_valid=n_valid, superblock=superblock, db_mask=db_mask,
        prune_blocks=prune_blocks, prune_block_rows=prune_block_rows)


# the kernel's rank-select merge materializes an (block_q, m, m) compare with
# m = block_n + padded-K; 512 rows keeps that ~13 MiB — inside VMEM. The
# `scan_block` knob sizes the *ref* lax.scan chunk; the pallas tile is
# derived independently (128-lane aligned, capped) so any host-side chunk —
# huge or oddly-sized — maps to a viable on-chip merge tile. Results are
# block-size invariant, so the remap never changes output.
_STREAM_PALLAS_MAX_BLOCK_N = 512


def _streaming_nns_pallas(queries, db, *, radius, max_candidates, scan_block,
                          n_valid, superblock=None, db_mask=None,
                          prune_blocks=None, prune_block_rows=None,
                          interpret):
    limit = db.shape[0] if n_valid is None else n_valid
    block_n = min(max(128, round_up(scan_block, 128)),
                  _STREAM_PALLAS_MAX_BLOCK_N)
    if superblock is not None:
        # superblock boundaries must land on block boundaries: lane-align the
        # override, then shrink the tile to a 128-multiple dividing it (any
        # superblock <= capacity yields identical results, so the remap is
        # output-invariant exactly like the scan_block -> block_n remap)
        superblock = max(128, round_up(superblock, 128))
        block_n = math.gcd(block_n, superblock)
    if prune_blocks is not None:
        # summary blocks must cover whole kernel tiles so the per-cell prune
        # mask expands by pure repetition; block_rows is a multiple of 128
        # by construction (core.nns.build_block_summary), so the gcd stays
        # lane-aligned and the remap stays output-invariant
        block_n = math.gcd(block_n, int(prune_block_rows))
    return streaming_nns_pallas(
        queries, db, jnp.asarray(limit, jnp.int32), db_mask, radius=radius,
        max_candidates=max_candidates, block_n=block_n,
        superblock=superblock, prune_blocks=prune_blocks,
        prune_block_rows=prune_block_rows, interpret=interpret)


register_kernel("hamming_distances", ref=ref.hamming_distance_ref,
                pallas=_hamming_pallas)
register_kernel("embedding_pool", ref=ref.embedding_pool_ref,
                pallas=_embedding_pool_pallas)
register_kernel("int8_matmul", ref=ref.int8_matmul_ref,
                pallas=int8_matmul_pallas)
register_kernel("flash_attention", ref=ref.blocked_attention_ref,
                pallas=_flash_attention_pallas)
register_kernel("streaming_nns", ref=_streaming_nns_ref,
                pallas=_streaming_nns_pallas)


# ---------------------------------------------------------------------------
# public ops
# ---------------------------------------------------------------------------
def embedding_pool(table_values, table_scales, ids, weights=None):
    """Fused int8 dequant-gather-pool: (n,d) int8 table, (B,L) ids -> (B,d)."""
    return dispatch("embedding_pool", table_values, table_scales, ids, weights)


def hamming_distances(queries, db):
    """(q,w) x (n,w) packed uint32 signatures -> (q,n) int32 distances."""
    return dispatch("hamming_distances", queries, db)


def streaming_nns(queries, db, *, radius, max_candidates,
                  scan_block=4096, n_valid=None, superblock=None,
                  db_mask=None, prune_blocks=None, prune_block_rows=None):
    """Streaming fixed-radius NNS over the full DB, O(q*max_candidates) mem.

    Returns (indices, distances, counts) bit-matching the dense
    hamming_distances -> threshold -> top_k path; `n_valid` (dynamic ok)
    masks trailing padding rows, `scan_block` sets the scan chunk size.
    DBs beyond the packed-key capacity (4.19M rows at 256-bit signatures)
    scan as multiple superblocks transparently; `superblock` shrinks the
    superblock size below capacity (a pure execution knob for tests —
    results are superblock-invariant). `db_mask` ((n,) bool, optional)
    marks per-row eligibility — the tombstone mask of the live-catalog
    layer; False rows never match and never count.

    `prune_blocks` ((q, nb) bool, True = skip) + `prune_block_rows` (rows
    per summary block, a multiple of 128) carry the core `BlockSummary`
    pruning decision: both backends skip chunks/blocks every query prunes
    (lax.cond in the ref, pl.when predication in the kernel). The caller
    (core.nns.fixed_radius_nns) guarantees the mask is sound, so outputs
    stay bit-identical to the unpruned scan on either backend.
    """
    return dispatch("streaming_nns", queries, db, radius=radius,
                    max_candidates=max_candidates, scan_block=scan_block,
                    n_valid=n_valid, superblock=superblock, db_mask=db_mask,
                    prune_blocks=prune_blocks,
                    prune_block_rows=prune_block_rows)


# chunk scans allowed on the async dispatch queue at once; each pins its
# (chunk_rows, words) input buffer until it retires
_OUTOFCORE_INFLIGHT = 2


def madvise_dontneed(arr) -> bool:
    """Drop a memmapped array's resident page cache (MADV_DONTNEED).

    The out-of-core scan copies the pages it needs before scanning, so
    dropping them immediately keeps a shard's resident set at O(one
    gather) instead of accumulating every admitted page across batches.
    No-op (returns False) for plain ndarrays or platforms without
    madvise; the data is never modified, only evicted.
    """
    mm = getattr(arr, "_mmap", None)
    if mm is None or not hasattr(mmap, "MADV_DONTNEED"):
        return False
    try:
        mm.madvise(mmap.MADV_DONTNEED)
        return True
    except (ValueError, OSError):
        return False


def madvise_random(arr) -> bool:
    """Disable kernel readahead on a memmapped array (MADV_RANDOM).

    Scattered candidate-row gathers fault one 4KB page at a time, but a
    default (MADV_NORMAL) mapping pulls up to 128KB of readahead per
    fault — a few thousand scattered faults can drag hundreds of MB of
    dead neighbours into the page cache. Out-of-core access to a shard
    is either scattered (candidate rows) or an explicit block-sized
    gather copy (the streaming scan), so readahead never helps and the
    resident set shrinks ~30x with it off. Same no-op guards as
    `madvise_dontneed`.
    """
    mm = getattr(arr, "_mmap", None)
    if mm is None or not hasattr(mmap, "MADV_RANDOM"):
        return False
    try:
        mm.madvise(mmap.MADV_RANDOM)
        return True
    except (ValueError, OSError):
        return False


@functools.partial(jax.jit,
                   static_argnames=("radius", "max_candidates", "scan_block"))
def _outofcore_chunk_scan(queries, chunk, n_rows, db_mask, row_map, *,
                          radius, max_candidates, scan_block):
    """One resident-chunk scan of the out-of-core driver: the usual
    streaming dispatch plus the local->global row remap (row_map is the
    monotonically increasing gather index, so the remap preserves the
    buffer's (distance, row) sort order)."""
    idx, dist, counts = dispatch(
        "streaming_nns", queries, chunk, radius=radius,
        max_candidates=max_candidates, scan_block=scan_block,
        n_valid=n_rows, db_mask=db_mask)
    gidx = jnp.where(idx >= 0, jnp.take(row_map, jnp.clip(idx, 0, None)), -1)
    return gidx, dist, counts


def streaming_nns_outofcore(queries, db, *, radius, max_candidates,
                            scan_block=4096, n_valid=None, db_mask=None,
                            prune_blocks=None, prune_block_rows=None,
                            chunk_rows=1 << 18):
    """`streaming_nns` over a host-resident (typically `np.memmap`) DB.

    The driver walks the signature DB in admitted summary blocks: blocks
    every query prunes are never gathered, so their memmap pages are never
    touched — the resident set is O(admitted blocks), not O(n). Admitted
    blocks are compacted into fixed-(q, chunk_rows) buffers (zero-padded,
    padding masked ineligible via `n_valid`/`db_mask`) so the whole scan
    compiles once; each buffer holds only genuine DB rows, so no per-query
    prune mask is needed downstream — prune soundness guarantees a pruned
    block contains no matches for that query, hence scanning it anyway is
    a no-op on the output. Per-chunk buffers merge exactly via
    `merge_chunk_buffers` (ascending disjoint row ranges).

    `db`: (n, words) uint32 ndarray/memmap. `db_mask`/`prune_blocks` are
    host arrays. Returns (indices, distances, counts) bit-identical to the
    resident `streaming_nns` with the same mask and a sound prune mask.

    Two bounds keep peak RSS at O(chunk), not O(admitted set): at most
    `_OUTOFCORE_INFLIGHT` chunk scans ride the async dispatch queue (each
    pins its (chunk_rows, words) input buffer until it retires), and a
    memmapped `db`'s page cache is dropped (MADV_DONTNEED) after each
    group's gather copy — the admitted pages of group g are dead the
    moment the copy exists, so they never accumulate across groups or
    batches.
    """
    n = int(db.shape[0])
    q = int(queries.shape[0])
    limit = n if n_valid is None else int(n_valid)
    queries = jnp.asarray(queries)
    mask_np = None if db_mask is None else np.asarray(db_mask, bool)

    if prune_blocks is not None:
        br = int(prune_block_rows)
        prune_np = np.asarray(prune_blocks, bool)
        kept = np.nonzero(~prune_np.all(axis=0))[0]
    else:
        br = max(1, int(chunk_rows))
        kept = np.arange(-(-n // br))
    kept = kept[kept * br < limit]

    if kept.size == 0 or limit <= 0:
        return (jnp.full((q, max_candidates), -1, jnp.int32),
                jnp.full((q, max_candidates), BIG_DIST, jnp.int32),
                jnp.zeros((q,), jnp.int32))

    group = max(1, int(chunk_rows) // br)  # admitted blocks per jit call
    cap = group * br
    chunks, counts = [], jnp.zeros((q,), jnp.int32)
    for g in range(0, kept.size, group):
        blk = kept[g:g + group]
        idx = (blk[:, None] * br + np.arange(br)).reshape(-1)
        within = idx < limit
        idx_c = np.minimum(idx, n - 1)
        rows = np.asarray(db[idx_c])  # memmap gather: pages of kept blocks
        elig = within if mask_np is None else (within & mask_np[idx_c])
        n_rows = rows.shape[0]
        if n_rows < cap:  # final short group: zero-pad to the fixed shape
            rows = np.concatenate(
                [rows, np.zeros((cap - n_rows,) + rows.shape[1:], rows.dtype)])
            elig = np.concatenate([elig, np.zeros(cap - n_rows, bool)])
            idx_c = np.concatenate(
                [idx_c, np.zeros(cap - n_rows, idx_c.dtype)])
        gidx, dist, c = _outofcore_chunk_scan(
            queries, jnp.asarray(rows), jnp.int32(n_rows),
            jnp.asarray(elig), jnp.asarray(idx_c.astype(np.int32)),
            radius=radius, max_candidates=max_candidates,
            scan_block=scan_block)
        del rows
        madvise_dontneed(db)
        chunks.append((gidx, dist))
        counts = counts + c
        if len(chunks) >= _OUTOFCORE_INFLIGHT:
            chunks[-_OUTOFCORE_INFLIGHT][0].block_until_ready()
    gidx, dist = merge_chunk_buffers(chunks, max_candidates)
    return gidx, dist, counts


def int8_matmul(x, w, x_scale, w_scale):
    """int8 (m,k) @ int8 (k,n) with per-row/col f32 scales -> f32 (m,n)."""
    return dispatch("int8_matmul", x, w, x_scale, w_scale)


def flash_attention(q, k, v, *, causal=True, scale=None):
    """(b,h,s,d) attention; flash kernel on TPU, blocked ref elsewhere."""
    return dispatch("flash_attention", q, k, v, causal=causal, scale=scale)
