"""jit'd public wrappers with backend dispatch for every kernel.

On TPU the Pallas kernels run compiled (interpret=False); on CPU (this
container) `REPRO_PALLAS=interpret` runs them through the Pallas interpreter
for correctness, and the default is the pure-jnp reference (fast to compile,
same numerics) — model code always calls through here and never cares.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.embedding_pool import embedding_pool_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hamming_nns import hamming_distances_pallas
from repro.kernels.int8_matmul import int8_matmul_pallas


def _mode() -> str:
    """'pallas' | 'interpret' | 'ref'."""
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("pallas", "interpret", "ref"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def embedding_pool(table_values, table_scales, ids, weights=None):
    """Fused int8 dequant-gather-pool: (n,d) int8 table, (B,L) ids -> (B,d)."""
    mode = _mode()
    if mode == "ref":
        return ref.embedding_pool_ref(table_values, table_scales, ids, weights)
    d = table_values.shape[1]
    block_d = d if d <= 512 else 512
    if d % block_d != 0:
        block_d = d  # fall back to unblocked when not divisible
    valid = (ids >= 0).astype(jnp.float32)
    w = valid if weights is None else weights.astype(jnp.float32) * valid
    return embedding_pool_pallas(
        table_values,
        table_scales,
        ids,
        w,
        block_d=block_d,
        interpret=(mode == "interpret"),
    )


def hamming_distances(queries, db):
    """(q,w) x (n,w) packed uint32 signatures -> (q,n) int32 distances."""
    mode = _mode()
    if mode == "ref":
        return ref.hamming_distance_ref(queries, db)
    n = db.shape[0]
    block_n = 1024 if n >= 1024 else max(128, 1 << (n - 1).bit_length())
    return hamming_distances_pallas(
        queries, db, block_n=block_n, interpret=(mode == "interpret")
    )


def int8_matmul(x, w, x_scale, w_scale):
    """int8 (m,k) @ int8 (k,n) with per-row/col f32 scales -> f32 (m,n)."""
    mode = _mode()
    if mode == "ref":
        return ref.int8_matmul_ref(x, w, x_scale, w_scale)
    return int8_matmul_pallas(
        x, w, x_scale, w_scale, interpret=(mode == "interpret")
    )


def flash_attention(q, k, v, *, causal=True, scale=None):
    """(b,h,s,d) attention; flash kernel on TPU, blocked ref elsewhere."""
    mode = _mode()
    if mode == "ref":
        return ref.blocked_attention_ref(q, k, v, causal=causal, scale=scale)
    b, h, sq, d = q.shape
    out = flash_attention_pallas(
        q.reshape(b * h, sq, d),
        k.reshape(b * h, k.shape[2], d),
        v.reshape(b * h, v.shape[2], d),
        causal=causal,
        scale=scale,
        interpret=(mode == "interpret"),
    )
    return out.reshape(b, h, sq, d)
