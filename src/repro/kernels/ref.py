"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the ground truth for the per-kernel allclose tests and the CPU
execution path of the framework (kernels/ops.py dispatches here when not on
TPU). They are written for clarity first, but the blocked attention variant
is production-grade (online softmax, O(S) memory) because it is the actual
CPU/compile-time path for 32k prefill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.streaming_nns import (
    BIG_DIST,
    big_key,
    key_shift,
    merge_candidate_buffers,
    pack_key,
    superblock_rows,
    unpack_key,
)


# ---------------------------------------------------------------------------
# Embedding pool (iMARS CMA RAM-mode lookup + in-memory adder pooling)
# ---------------------------------------------------------------------------
def embedding_pool_ref(
    table_values: jax.Array,  # (n, d) int8
    table_scales: jax.Array,  # (n, 1) f32
    ids: jax.Array,  # (B, L) int32, -1 = padding
    weights: jax.Array | None = None,  # (B, L) f32
) -> jax.Array:
    """Fused int8 dequant-gather-pool -> (B, d) f32."""
    valid = (ids >= 0).astype(jnp.float32)
    safe_ids = jnp.maximum(ids, 0)
    rows = table_values[safe_ids].astype(jnp.float32)  # (B, L, d)
    scales = table_scales[safe_ids]  # (B, L, 1)
    w = valid if weights is None else weights * valid
    return jnp.einsum("bld,bl->bd", rows * scales, w)


# ---------------------------------------------------------------------------
# Hamming distance (iMARS TCAM threshold search)
# ---------------------------------------------------------------------------
def hamming_distance_ref(queries: jax.Array, db: jax.Array) -> jax.Array:
    """queries (q, w) uint32, db (n, w) uint32 -> (q, n) int32 distances."""
    x = jnp.bitwise_xor(queries[:, None, :], db[None, :, :])
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Streaming fixed-radius NNS (iMARS TCAM search + priority encoder, fused)
# ---------------------------------------------------------------------------
def streaming_nns_ref(
    queries: jax.Array,  # (q, w) uint32
    db: jax.Array,  # (n, w) uint32
    radius: int,
    max_candidates: int,
    *,
    scan_block: int = 4096,
    n_valid: jax.Array | int | None = None,
    superblock: int | None = None,  # rows per superblock (testing override)
    db_mask: jax.Array | None = None,  # (n,) bool — 0/False rows never match
    prune_blocks: jax.Array | None = None,  # (q, nb) bool — True = skip block
    prune_block_rows: int | None = None,  # rows per summary block
):
    """`lax.scan`-chunked streaming NNS oracle, O(q * max_candidates) memory.

    Bit-matches the dense path (hamming_distance_ref -> threshold -> top_k):
    returns (indices, distances, counts) with the `max_candidates` nearest
    matches per query sorted by (distance, index), padded with (-1, 2**30).
    Candidates are tracked as packed int32 keys `dist << shift | row` (see
    kernels/streaming_nns.py for the encoding) so one top_k per chunk merges
    the running buffer with the chunk's matches exactly.

    Mirrors the kernel's wide-key scheme: DBs larger than the packed-key
    capacity scan as superblocks of `superblock_rows` rows each, whose row
    bits hold superblock-local offsets; global ids are reconstructed from
    the superblock offset and the per-superblock top-K buffers are merged
    with one stable sort on distance (`merge_candidate_buffers`). No row
    cap remains beyond int32 indexing. `db_mask` mirrors the kernel's
    optional row-eligibility operand (live-catalog tombstones): masked
    rows never match and never count.

    `prune_blocks` ((q, nb) bool, `prune_block_rows` rows per summary
    block) mirrors the kernel's block-pruning cells (core.nns
    `BlockSummary` bounds): a scan chunk whose rows are all inside blocks
    pruned for EVERY query is skipped via `lax.cond` — zero distance work —
    which cannot change outputs because the bound is sound (pruned blocks
    hold no within-radius rows for any query). Rows beyond the summary's
    coverage are always scanned.
    """
    q, words = queries.shape
    n = db.shape[0]
    shift = key_shift(words)  # the one key encoding, shared with the kernel
    big = big_key(words)
    sb_rows = superblock_rows(words, superblock=superblock)
    limit = jnp.minimum(
        jnp.asarray(n if n_valid is None else n_valid, jnp.int32), n)

    row_needed = None
    if prune_blocks is not None:
        # per-row "some query still needs this row": expand the per-block
        # mask (ORed over queries) by block_rows, pad uncovered tail rows
        # with True (a stale/short summary is sound, never wrong)
        needed_b = jnp.any(jnp.logical_not(prune_blocks), axis=0)  # (nb,)
        cover = needed_b.shape[0] * int(prune_block_rows)
        row_needed = jnp.repeat(needed_b, int(prune_block_rows))
        if cover < n:
            row_needed = jnp.concatenate(
                [row_needed, jnp.ones((n - cover,), jnp.bool_)])
        else:
            row_needed = row_needed[:n]

    def scan_superblock(db_s, limit_s, mask_s, needed_s):
        """One packed-key lax.scan over <= sb_rows rows -> ((q, K), (q,))."""
        n_s = db_s.shape[0]
        # chunks never need to exceed the superblock: an oversized
        # scan_block would round the padding up to itself and scan the
        # (all-masked) pad rows too
        block = max(1, min(scan_block, n_s))
        n_blocks = max(1, -(-n_s // block))
        pad = n_blocks * block - n_s
        db_p = jnp.pad(db_s, ((0, pad), (0, 0))) if pad else db_s
        blocks = db_p.reshape(n_blocks, block, words)
        if mask_s is None:
            mask_blocks = jnp.ones((n_blocks, 1), jnp.bool_)  # broadcast no-op
        else:
            mask_p = jnp.pad(mask_s, (0, pad)) if pad else mask_s
            mask_blocks = mask_p.reshape(n_blocks, block).astype(jnp.bool_)

        def scan_chunk(keys, counts, db_blk, mask_blk, j):
            d = hamming_distance_ref(queries, db_blk)  # (q, block)
            lidx = j * block + jnp.arange(block, dtype=jnp.int32)
            within = jnp.logical_and(d <= radius, (lidx < limit_s)[None, :])
            within = jnp.logical_and(within, mask_blk[None, :])
            counts = counts + jnp.sum(within, axis=-1).astype(jnp.int32)
            new_keys = jnp.where(
                within, pack_key(d, lidx[None, :], words), big)
            merged = jnp.concatenate([keys, new_keys], axis=1)
            neg_top, _ = jax.lax.top_k(-merged, max_candidates)
            return -neg_top, counts

        if needed_s is None:
            def step(carry, blk):
                db_blk, mask_blk, j = blk
                return scan_chunk(*carry, db_blk, mask_blk, j), None

            xs = (blocks, mask_blocks,
                  jnp.arange(n_blocks, dtype=jnp.int32))
        else:
            needed_p = (jnp.pad(needed_s, (0, pad)) if pad else needed_s)
            chunk_needed = jnp.any(
                needed_p.reshape(n_blocks, block), axis=1)

            def step(carry, blk):
                db_blk, mask_blk, needed, j = blk
                # pruned chunk: the sound bound guarantees zero matches
                # here, so skipping is a pure execution shortcut
                return jax.lax.cond(
                    needed,
                    lambda c: scan_chunk(*c, db_blk, mask_blk, j),
                    lambda c: c, carry), None

            xs = (blocks, mask_blocks, chunk_needed,
                  jnp.arange(n_blocks, dtype=jnp.int32))

        keys0 = jnp.full((q, max_candidates), big, jnp.int32)
        counts0 = jnp.zeros((q,), jnp.int32)
        (keys, counts), _ = jax.lax.scan(step, (keys0, counts0), xs)
        return keys, counts

    all_idx, all_dist = [], []
    counts = jnp.zeros((q,), jnp.int32)
    for off in range(0, max(n, 1), sb_rows):
        db_s = db[off:off + sb_rows]
        keys, cnt = scan_superblock(
            db_s, jnp.clip(limit - off, 0, db_s.shape[0]),
            None if db_mask is None else db_mask[off:off + sb_rows],
            None if row_needed is None else row_needed[off:off + sb_rows])
        dist, local = unpack_key(keys, words)
        valid = keys < big
        all_idx.append(jnp.where(valid, local + off, -1))
        all_dist.append(jnp.where(valid, dist, jnp.int32(BIG_DIST)))
        counts = counts + cnt
    if len(all_idx) == 1:
        return all_idx[0], all_dist[0], counts
    indices, distances = merge_candidate_buffers(
        jnp.concatenate(all_idx, axis=1), jnp.concatenate(all_dist, axis=1),
        max_candidates)
    return indices, distances, counts


# ---------------------------------------------------------------------------
# int8 matmul (iMARS crossbar MVM analogue)
# ---------------------------------------------------------------------------
def int8_matmul_ref(
    x: jax.Array,  # (m, k) int8
    w: jax.Array,  # (k, n) int8
    x_scale: jax.Array,  # (m, 1) f32
    w_scale: jax.Array,  # (1, n) f32
) -> jax.Array:
    acc = jax.lax.dot_general(
        x,
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * x_scale * w_scale


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attention_ref(
    q: jax.Array,  # (b, h, sq, d)
    k: jax.Array,  # (b, h, sk, d)
    v: jax.Array,  # (b, h, sk, d)
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Full-materialization softmax attention (oracle)."""
    d = q.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        rows = jnp.arange(sq)[:, None] + q_offset
        cols = jnp.arange(sk)[None, :]
        s = jnp.where(cols <= rows, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def blocked_attention_ref(
    q: jax.Array,  # (b, h, sq, d)
    k: jax.Array,  # (b, h, sk, d)
    v: jax.Array,  # (b, h, sk, d)
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    block_k: int = 1024,
) -> jax.Array:
    """Online-softmax attention, O(sq * block_k) memory (flash-style, pure jnp).

    This is the production CPU/lowering path for long sequences; it is also
    the numerical contract the Pallas flash kernel must match.
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = (d**-0.5) if scale is None else scale
    qf = q.astype(jnp.float32) * scale

    n_blocks = -(-sk // block_k)
    pad = n_blocks * block_k - sk
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    kf = kf.reshape(b, h, n_blocks, block_k, d)
    vf = vf.reshape(b, h, n_blocks, block_k, d)

    rows = jnp.arange(sq)[:, None] + q_offset  # (sq, 1)

    def body(carry, blk):
        m_prev, l_prev, acc_prev = carry
        kb, vb, blk_idx = blk
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb)  # (b,h,sq,block_k)
        cols = blk_idx * block_k + jnp.arange(block_k)[None, :]
        mask = cols <= rows if causal else (cols < sk)
        # always mask k-padding
        mask = jnp.logical_and(mask, cols < sk)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # guard -inf rows (no valid key yet)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(jnp.where(jnp.isfinite(m_prev), m_prev - m_safe, -jnp.inf))
        alpha = jnp.where(jnp.isfinite(m_prev), alpha, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((b, h, sq), dtype=jnp.float32)
    acc0 = jnp.zeros((b, h, sq, d), dtype=jnp.float32)
    kb = jnp.moveaxis(kf, 2, 0)  # (n_blocks, b, h, block_k, d)
    vb = jnp.moveaxis(vf, 2, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kb, vb, jnp.arange(n_blocks))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (b, h, 1, d)
    k: jax.Array,  # (b, h, s, d)
    v: jax.Array,  # (b, h, s, d)
    length_mask: jax.Array | None = None,  # (b, s) bool — valid cache slots
    scale: float | None = None,
) -> jax.Array:
    d = q.shape[-1]
    scale = (d**-0.5) if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if length_mask is not None:
        s = jnp.where(length_mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
