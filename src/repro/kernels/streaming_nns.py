"""Pallas TPU kernel: streaming fused Hamming fixed-radius NNS.

The dense filtering path (`ops.hamming_distances` -> threshold -> top-k)
materializes the whole (q, n) int32 distance matrix, which is the capacity
wall of the pipeline at million-item catalogs. This kernel is the streaming
image of the iMARS TCAM search + priority encoder (Sec. III-A/B): one blocked
scan over the signature DB that fuses

  (1) XOR-popcount distance over packed uint32 signature lanes,
  (2) the fixed-radius threshold compare (matchline),
  (3) bounded candidate selection (priority encode) into a running
      per-query buffer of the `max_candidates` best matches,

so peak memory is O(q * max_candidates) regardless of DB size.

Candidate bookkeeping packs (distance, db_row) into one int32 sort key,
``key = dist << shift | row`` with ``shift = 31 - bitlen(32 * words + 1)``
(256-bit signatures -> 9 distance bits, 22 row bits, DBs up to 4.19M rows).
Ascending key order is exactly the dense path's (distance, index) order —
`jax.lax.top_k` breaks ties by lower index — so the streaming result is
bit-identical to the dense `fixed_radius_nns` output.

The per-block merge keeps the buffer sorted: concatenate the resident buffer
with the block's candidate keys, compute each element's rank with one
all-pairs compare (rank = #strictly-smaller keys; valid keys are unique so
ranks are collision-free), and scatter rank < K survivors back via a
min-reduction over a one-hot slot mask — all elementwise/reduce ops that
Mosaic lowers without needing an in-kernel sort. Blocks with no matches (the
common case at selective radii) skip the merge entirely under `pl.when`.

Grid: (q_blocks, n_blocks) with the DB dimension innermost and *sequential*
— the (block_q, K) output tile is revisited across the scan and stays
resident in VMEM, the same accumulator pattern as the embedding-pool kernel.
`n_valid` rides along as a dynamic (1, 1) scalar operand so the sharded path
can mask per-shard padding rows with a traced value.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils import cdiv, round_up

# THE invalid-slot distance sentinel: core/nns.py (dense padding) and
# kernels/ref.py (oracle decode) both import it, so the bit-match invariant
# between every path hangs off this one definition.
BIG_DIST = 2**30


def key_shift(words: int) -> int:
    """Bits reserved for the db row index in the packed (dist, row) key."""
    return 31 - (32 * words + 1).bit_length()


def big_key(words: int) -> int:
    """Sentinel key strictly greater than every valid (dist, row) key."""
    return (32 * words + 1) << key_shift(words)


def max_streamable_items(words: int) -> int:
    """Largest DB the packed int32 key can index (4.19M rows at words=8)."""
    return 1 << key_shift(words)


def _streaming_nns_kernel(limit_ref, q_ref, db_ref, keys_ref, counts_ref,
                          *, radius, shift, big):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        keys_ref[...] = jnp.full(keys_ref.shape, big, jnp.int32)
        counts_ref[...] = jnp.zeros(counts_ref.shape, jnp.int32)

    q = q_ref[...]  # (block_q, words) uint32
    db = db_ref[...]  # (block_n, words) uint32
    block_n = db.shape[0]
    x = jnp.bitwise_xor(q[:, None, :], db[None, :, :])
    d = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    gidx = j * block_n + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    within = jnp.logical_and(d <= radius, gidx < limit_ref[0, 0])
    counts_ref[...] += jnp.sum(within.astype(jnp.int32), axis=1, keepdims=True)

    @pl.when(jnp.any(within))
    def _merge():
        new_keys = jnp.where(within, d * (1 << shift) + gidx, big)
        merged = jnp.concatenate([keys_ref[...], new_keys], axis=1)  # (bq, m)
        rank = jnp.sum(
            (merged[:, None, :] < merged[:, :, None]).astype(jnp.int32),
            axis=-1,
        )  # (bq, m): unique for valid keys, >= K only for sentinels beyond K
        n_slots = keys_ref.shape[1]
        slot = jax.lax.broadcasted_iota(
            jnp.int32, (*merged.shape, n_slots), 2)
        take = jnp.logical_and(rank[..., None] == slot,
                               (merged < big)[..., None])
        keys_ref[...] = jnp.min(
            jnp.where(take, merged[..., None], big), axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("radius", "max_candidates", "block_q", "block_n",
                     "interpret"),
)
def streaming_nns_pallas(
    queries: jax.Array,  # (q, words) uint32
    db: jax.Array,  # (n, words) uint32
    n_valid: jax.Array,  # () int32 — rows >= n_valid never match (dynamic)
    *,
    radius: int,
    max_candidates: int,
    block_q: int = 8,
    block_n: int = 512,
    interpret: bool = False,
):
    """Streaming fixed-radius NNS -> (indices, distances, counts).

    Bit-matches the dense hamming->threshold->top_k path: indices/distances
    are the `max_candidates` nearest matches sorted by (distance, index),
    padded with (-1, BIG_DIST); counts are total matches within radius.
    """
    q, words = queries.shape
    n, words2 = db.shape
    assert words == words2, (words, words2)
    shift = key_shift(words)
    if n > (1 << shift):
        raise ValueError(
            f"db rows {n} exceed streaming key capacity {1 << shift} at "
            f"words={words}; shard the db first")

    # the resident buffer is lane-padded; extra slots decode to padding
    k_pad = max(128, round_up(max_candidates, 128))
    qp = round_up(q, block_q)
    np_ = round_up(n, block_n)
    queries_p = jnp.pad(queries, ((0, qp - q), (0, 0))) if qp > q else queries
    db_p = jnp.pad(db, ((0, np_ - n), (0, 0))) if np_ > n else db
    limit = jnp.reshape(
        jnp.minimum(jnp.asarray(n_valid, jnp.int32), n), (1, 1))

    kernel = functools.partial(
        _streaming_nns_kernel, radius=radius, shift=shift,
        big=big_key(words))
    keys, counts = pl.pallas_call(
        kernel,
        grid=(qp // block_q, np_ // block_n),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_q, words), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, words), lambda i, j: (j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_q, k_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((qp, k_pad), jnp.int32),
            jax.ShapeDtypeStruct((qp, 1), jnp.int32),
        ),
        interpret=interpret,
    )(limit, queries_p, db_p)

    keys = keys[:q, :max_candidates]  # buffer is sorted: first K = best K
    valid = keys < big_key(words)
    indices = jnp.where(valid, keys & ((1 << shift) - 1), -1)
    distances = jnp.where(valid, keys >> shift, BIG_DIST)
    return indices, distances, counts[:q, 0]
