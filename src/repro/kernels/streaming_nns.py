"""Pallas TPU kernel: streaming fused Hamming fixed-radius NNS.

The dense filtering path (`ops.hamming_distances` -> threshold -> top-k)
materializes the whole (q, n) int32 distance matrix, which is the capacity
wall of the pipeline at million-item catalogs. This kernel is the streaming
image of the iMARS TCAM search + priority encoder (Sec. III-A/B): one blocked
scan over the signature DB that fuses

  (1) XOR-popcount distance over packed uint32 signature lanes,
  (2) the fixed-radius threshold compare (matchline),
  (3) bounded candidate selection (priority encode) into a running
      per-query buffer of the `max_candidates` best matches,

so peak memory is O(q * max_candidates) regardless of DB size.

Candidate bookkeeping packs (distance, db_row) into one int32 sort key,
``key = dist << shift | row`` with ``shift = 31 - bitlen(32 * words + 1)``
(256-bit signatures -> 9 distance bits, 22 row bits). Ascending key order is
exactly the dense path's (distance, index) order — `jax.lax.top_k` breaks
ties by lower index — so the streaming result is bit-identical to the dense
`fixed_radius_nns` output.

**Wide keys (DBs past the packed-key capacity).** A single packed key can
only index `2**shift` rows (4.19M at 256-bit signatures). Instead of paying
for two-word (dist, row_hi/row_lo) keys everywhere, the scan is split into
*superblocks* of at most `2**shift` rows: the row bits of every key hold the
offset *within the current superblock* (so the in-kernel rank-select merge
stays pure int32), each superblock accumulates its own resident candidate
buffer, and full row ids are reconstructed on the host as
``superblock * superblock_rows + local_row``. The per-superblock top-K
buffers are then merged host-side by one *stable* sort on distance
(`merge_candidate_buffers`): each buffer is already (dist, row)-sorted and
superblock row ranges are disjoint and ascending, so stability alone
reproduces the exact global (distance, index) order. DB capacity becomes
int32 row ids (2**31 rows) rather than `2**shift`.

The per-block merge keeps the buffer sorted: concatenate the resident buffer
with the block's candidate keys, compute each element's rank with one
all-pairs compare (rank = #strictly-smaller keys; valid keys are unique so
ranks are collision-free), and scatter rank < K survivors back via a
min-reduction over a one-hot slot mask — all elementwise/reduce ops that
Mosaic lowers without needing an in-kernel sort. Blocks with no matches (the
common case at selective radii) skip the merge entirely under `pl.when`.

Grid: (q_blocks, n_blocks) with the DB dimension innermost and *sequential*
— the (1, block_q, K) candidate tile is revisited across its superblock's
blocks and stays resident in VMEM, the same accumulator pattern as the
embedding-pool kernel; it re-initializes when the scan crosses into the next
superblock. `n_valid` rides along as a dynamic (1, 1) scalar operand so the
sharded path can mask per-shard padding rows with a traced value.

**Row eligibility (`db_mask`).** The live-catalog layer (serving/catalog.py)
tombstones base rows that were deleted or overwritten by a delta row; those
rows must never match, wherever they sit in the DB — a prefix count
(`n_valid`) cannot express that. An optional (1, n) int32 mask operand rides
the scan blocked along the DB dimension exactly like the signature rows
((1, block_n) lane-aligned tiles, zero-padded past `n`): the matchline AND
is one extra elementwise compare per block, so masked and unmasked scans
cost the same. When no mask is passed the operand is omitted entirely (a
separate pallas_call signature), so frozen catalogs pay nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils import cdiv, round_up

# THE invalid-slot distance sentinel: core/nns.py (dense padding) and
# kernels/ref.py (oracle decode) both import it, so the bit-match invariant
# between every path hangs off this one definition.
BIG_DIST = 2**30


def key_shift(words: int) -> int:
    """Bits reserved for the db row index in the packed (dist, row) key."""
    return 31 - (32 * words + 1).bit_length()


def big_key(words: int) -> int:
    """Sentinel key strictly greater than every valid (dist, row) key."""
    return (32 * words + 1) << key_shift(words)


def max_streamable_items(words: int) -> int:
    """Rows one packed int32 key can index == the max superblock size
    (4.19M at words=8). DBs beyond this scan as multiple superblocks."""
    return 1 << key_shift(words)


def pack_key(dist, row, words: int):
    """Pack (dist, superblock-local row) into one int32 sort key.

    Total preorder: key(a) < key(b) iff (dist_a, row_a) < (dist_b, row_b)
    lexicographically, for any dist <= 32*words and row < 2**key_shift.
    Works on ints and on jnp arrays alike.
    """
    return dist * (1 << key_shift(words)) + row


def unpack_key(key, words: int):
    """Inverse of `pack_key`: key -> (dist, superblock-local row)."""
    shift = key_shift(words)
    return key >> shift, key & ((1 << shift) - 1)


def superblock_rows(words: int, block_n: int = 1,
                    superblock: int | None = None) -> int:
    """Rows per superblock: the packed-key capacity (or the `superblock`
    testing override, clamped to it) floored to a multiple of `block_n` so
    superblock boundaries land on kernel block boundaries."""
    cap = max_streamable_items(words)
    sb = cap if superblock is None else min(int(superblock), cap)
    sb = (sb // block_n) * block_n
    if sb <= 0:
        raise ValueError(
            f"superblock {superblock} smaller than one block ({block_n} "
            f"rows) at words={words}")
    return sb


def merge_candidate_buffers(indices: jax.Array, distances: jax.Array,
                            max_candidates: int):
    """Merge per-superblock sorted candidate buffers into the global top-K.

    `indices` / `distances` are (q, S*K), the S per-superblock buffers
    concatenated in ascending-superblock order. Each buffer is sorted by
    (distance, row) with invalid slots (-1, BIG_DIST) at its tail, and the
    row ranges of successive superblocks are disjoint and ascending — so ONE
    stable sort on distance alone reproduces the exact lexicographic
    (distance, row) order: among equal distances, stability preserves
    ascending-superblock (hence ascending-row) order.
    """
    order = jnp.argsort(distances, axis=-1, stable=True)
    order = order[:, :max_candidates]
    return (jnp.take_along_axis(indices, order, axis=1),
            jnp.take_along_axis(distances, order, axis=1))


def merge_chunk_buffers(chunks, max_candidates: int):
    """Merge the per-chunk buffers of a host-driven out-of-core scan.

    `chunks` is a list of (indices, distances) pairs — each (q, K) with
    GLOBAL row ids — produced by scanning ascending, disjoint row ranges of
    one memmapped DB. That is exactly the superblock-merge precondition
    (per-buffer (dist, row) sort, invalids at the tail, ascending disjoint
    row ranges across buffers), so `merge_candidate_buffers` is exact here
    too: the out-of-core scan bit-matches the resident scan by the same
    argument that makes the multi-superblock kernel exact. An empty chunk
    list (every block pruned) yields the all-sentinel result.
    """
    if not chunks:
        raise ValueError("merge_chunk_buffers: no chunks (caller emits "
                         "the empty result for fully-pruned scans)")
    if len(chunks) == 1:
        idx, dist = chunks[0]
        return idx[:, :max_candidates], dist[:, :max_candidates]
    idx = jnp.concatenate([c[0] for c in chunks], axis=1)
    dist = jnp.concatenate([c[1] for c in chunks], axis=1)
    return merge_candidate_buffers(idx, dist, max_candidates)


def _streaming_nns_kernel(limit_ref, q_ref, db_ref, keys_ref, counts_ref,
                          *, radius, shift, big, blocks_per_sb,
                          mask_ref=None, scan_ref=None):
    j = pl.program_id(1)

    # buffer inits stay OUTSIDE the prune predicate: a superblock whose
    # every block is pruned for this query tile must still emit the empty
    # (all-sentinel) buffer and zero counts, not garbage
    @pl.when(j % blocks_per_sb == 0)
    def _init_keys():  # fresh candidate buffer per superblock
        keys_ref[...] = jnp.full(keys_ref.shape, big, jnp.int32)

    @pl.when(j == 0)
    def _init_counts():
        counts_ref[...] = jnp.zeros(counts_ref.shape, jnp.int32)

    def _scan_block():
        q = q_ref[...]  # (block_q, words) uint32
        db = db_ref[...]  # (block_n, words) uint32
        block_n = db.shape[0]
        x = jnp.bitwise_xor(q[:, None, :], db[None, :, :])
        d = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
        gidx = j * block_n + iota  # global row id (int32-safe to 2**31 rows)
        within = jnp.logical_and(d <= radius, gidx < limit_ref[0, 0])
        if mask_ref is not None:  # tombstoned rows never match (matchline AND)
            within = jnp.logical_and(within, (mask_ref[...] != 0)[0][None, :])
        counts_ref[...] += jnp.sum(within.astype(jnp.int32), axis=1,
                                   keepdims=True)

        @pl.when(jnp.any(within))
        def _merge():
            # row bits carry the superblock-LOCAL offset: int32 keys
            lidx = (j % blocks_per_sb) * block_n + iota
            new_keys = jnp.where(within, d * (1 << shift) + lidx, big)
            merged = jnp.concatenate([keys_ref[0], new_keys], axis=1)
            rank = jnp.sum(
                (merged[:, None, :] < merged[:, :, None]).astype(jnp.int32),
                axis=-1,
            )  # (bq, m): unique for valid keys, >= K only past-K sentinels
            n_slots = keys_ref.shape[2]
            slot = jax.lax.broadcasted_iota(
                jnp.int32, (*merged.shape, n_slots), 2)
            take = jnp.logical_and(rank[..., None] == slot,
                                   (merged < big)[..., None])
            keys_ref[0] = jnp.min(
                jnp.where(take, merged[..., None], big), axis=1)

    if scan_ref is None:
        _scan_block()
    else:
        # block-summary pruning: this (query-tile, db-block) cell was
        # proven empty of matches by the sound bound — skip all of it
        pl.when(scan_ref[0, 0] != 0)(_scan_block)


def _masked_streaming_nns_kernel(limit_ref, q_ref, db_ref, mask_ref,
                                 keys_ref, counts_ref, **kw):
    """Mask-carrying variant: same body, one extra (1, block_n) operand."""
    _streaming_nns_kernel(limit_ref, q_ref, db_ref, keys_ref, counts_ref,
                          mask_ref=mask_ref, **kw)


def _pruned_streaming_nns_kernel(limit_ref, q_ref, db_ref, scan_ref,
                                 keys_ref, counts_ref, **kw):
    """Prune-carrying variant: same body, one extra (1, 1) cell operand."""
    _streaming_nns_kernel(limit_ref, q_ref, db_ref, keys_ref, counts_ref,
                          scan_ref=scan_ref, **kw)


def _masked_pruned_streaming_nns_kernel(limit_ref, q_ref, db_ref, mask_ref,
                                        scan_ref, keys_ref, counts_ref,
                                        **kw):
    """Mask + prune variant: both extra operands, same body."""
    _streaming_nns_kernel(limit_ref, q_ref, db_ref, keys_ref, counts_ref,
                          mask_ref=mask_ref, scan_ref=scan_ref, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("radius", "max_candidates", "block_q", "block_n",
                     "superblock", "prune_block_rows", "interpret"),
)
def streaming_nns_pallas(
    queries: jax.Array,  # (q, words) uint32
    db: jax.Array,  # (n, words) uint32
    n_valid: jax.Array,  # () int32 — rows >= n_valid never match (dynamic)
    db_mask: jax.Array | None = None,  # (n,) bool/int — 0 rows never match
    *,
    radius: int,
    max_candidates: int,
    block_q: int = 8,
    block_n: int = 512,
    superblock: int | None = None,  # rows per superblock (testing override)
    prune_blocks: jax.Array | None = None,  # (q, nb) bool — True = skip
    prune_block_rows: int | None = None,  # rows per summary block
    interpret: bool = False,
):
    """Streaming fixed-radius NNS -> (indices, distances, counts).

    Bit-matches the dense hamming->threshold->top_k path: indices/distances
    are the `max_candidates` nearest matches sorted by (distance, index),
    padded with (-1, BIG_DIST); counts are total matches within radius.
    DBs larger than the packed-key capacity scan as multiple superblocks
    whose candidate buffers are merged host-side (see module docstring).
    `db_mask` marks per-row eligibility (tombstones); None scans unmasked
    through a mask-free kernel signature.

    **Block pruning.** `prune_blocks` ((q, nb) bool from the core
    `BlockSummary` bounds, `prune_block_rows` rows per summary block,
    which must be a multiple of `block_n`) adds a (1, 1) int32 cell
    operand gridded per (query-tile, db-block): when every query of the
    tile prunes the block, the whole kernel body is predicated off with
    `pl.when` — no distance, no merge, no count. The candidate/count
    buffer inits stay outside the predicate so fully-pruned superblocks
    still emit well-formed (empty) buffers. Sound bound => bit-identical
    outputs.
    """
    q, words = queries.shape
    n, words2 = db.shape
    assert words == words2, (words, words2)
    shift = key_shift(words)
    big = big_key(words)
    sb_rows = superblock_rows(words, block_n, superblock)
    blocks_per_sb = sb_rows // block_n

    # the resident buffer is lane-padded; extra slots decode to padding
    k_pad = max(128, round_up(max_candidates, 128))
    qp = round_up(q, block_q)
    np_ = round_up(n, block_n)
    n_blocks = np_ // block_n
    n_sb = cdiv(n_blocks, blocks_per_sb)
    queries_p = jnp.pad(queries, ((0, qp - q), (0, 0))) if qp > q else queries
    db_p = jnp.pad(db, ((0, np_ - n), (0, 0))) if np_ > n else db
    limit = jnp.reshape(
        jnp.minimum(jnp.asarray(n_valid, jnp.int32), n), (1, 1))

    operands = [limit, queries_p, db_p]
    in_specs = [
        pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        pl.BlockSpec((block_q, words), lambda i, j: (i, 0)),
        pl.BlockSpec((block_n, words), lambda i, j: (j, 0)),
    ]
    if db_mask is not None:
        mask = jnp.reshape(db_mask.astype(jnp.int32), (1, n))
        if np_ > n:  # pad rows ineligible (n_valid already excludes them)
            mask = jnp.pad(mask, ((0, 0), (0, np_ - n)))
        operands.append(mask)
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j: (0, j)))
    if prune_blocks is not None:
        if prune_block_rows is None or prune_block_rows % block_n:
            raise ValueError(
                f"prune_block_rows ({prune_block_rows}) must be a multiple "
                f"of block_n ({block_n}) — the ops adapter aligns them")
        # per-(query-tile, kernel-block) scan cells: a kernel block scans
        # unless EVERY query of its tile prunes the covering summary block
        needed = jnp.logical_not(prune_blocks)  # (q, nb)
        if qp > q:  # pad queries contribute nothing
            needed = jnp.pad(needed, ((0, qp - q), (0, 0)))
        nb = needed.shape[1]
        needed = jnp.any(needed.reshape(qp // block_q, block_q, nb), axis=1)
        cells = jnp.repeat(needed, prune_block_rows // block_n, axis=1)
        if cells.shape[1] < n_blocks:  # rows beyond coverage always scan
            cells = jnp.pad(
                cells, ((0, 0), (0, n_blocks - cells.shape[1])),
                constant_values=True)
        else:
            cells = cells[:, :n_blocks]
        operands.append(cells.astype(jnp.int32))
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j: (i, j)))
    body = {
        (False, False): _streaming_nns_kernel,
        (True, False): _masked_streaming_nns_kernel,
        (False, True): _pruned_streaming_nns_kernel,
        (True, True): _masked_pruned_streaming_nns_kernel,
    }[(db_mask is not None, prune_blocks is not None)]

    kernel = functools.partial(
        body, radius=radius, shift=shift, big=big,
        blocks_per_sb=blocks_per_sb)
    keys, counts = pl.pallas_call(
        kernel,
        grid=(qp // block_q, n_blocks),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, block_q, k_pad),
                         lambda i, j: (j // blocks_per_sb, i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n_sb, qp, k_pad), jnp.int32),
            jax.ShapeDtypeStruct((qp, 1), jnp.int32),
        ),
        interpret=interpret,
    )(*operands)

    # buffers are sorted: first K slots of each superblock = its best K
    keys = keys[:, :q, :max_candidates]  # (n_sb, q, K)
    dist, local = unpack_key(keys, words)
    valid = keys < big
    offsets = (jnp.arange(n_sb, dtype=jnp.int32) * sb_rows)[:, None, None]
    indices = jnp.where(valid, local + offsets, -1)
    distances = jnp.where(valid, dist, BIG_DIST)
    if n_sb > 1:  # wide DB: merge the per-superblock buffers
        indices = jnp.moveaxis(indices, 0, 1).reshape(q, -1)
        distances = jnp.moveaxis(distances, 0, 1).reshape(q, -1)
        indices, distances = merge_candidate_buffers(
            indices, distances, max_candidates)
    else:
        indices, distances = indices[0], distances[0]
    return indices, distances, counts[:q, 0]
