import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production mesh (16x16 single pod / 2x16x16 multi-pod) with 512
placeholder host devices; record memory_analysis, cost_analysis, and
trip-count-corrected HLO stats (FLOPs / HBM bytes / collective bytes) into
experiments/dryrun/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse  # noqa: E402
import gzip  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mem_dict(mem) -> dict:
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "generated_code_bytes": mem.generated_code_size_in_bytes,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             override_parallel: dict | None = None,
             hlo_path: pathlib.Path | None = None,
             override_model: dict | None = None) -> dict:
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_arch
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step

    t0 = time.time()
    bundle = get_arch(arch)
    if override_parallel or override_model:
        bundle = type(bundle)(
            model=bundle.model.with_(**(override_model or {})),
            parallel=bundle.parallel.with_(**(override_parallel or {})),
            skip_shapes=bundle.skip_shapes,
        )
    mesh_name = "multi" if multi_pod else "single"
    if shape_name in dict(bundle.skip_shapes):
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": dict(bundle.skip_shapes)[shape_name],
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.devices.size

    with mesh:
        built = build_step(bundle, shape_name, mesh)
        lowered = built.fn.lower(*built.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo_text = compiled.as_text()
        if hlo_path is not None:
            with gzip.open(hlo_path, "wt") as f:
                f.write(hlo_text)
        stats = analyze_hlo(hlo_text, total_devices=n_devices)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
        "n_devices": n_devices,
        "memory": _mem_dict(mem),
        "xla_cost_analysis": {
            "flops_single_body": cost.get("flops", 0.0),
            "bytes_accessed_single_body": cost.get("bytes accessed", 0.0),
        },
        "hlo": {
            "flops": stats.flops,
            "hbm_bytes": stats.hbm_bytes,
            "collective_bytes": stats.collective_bytes,
            "collective_count": stats.collective_count,
            "per_collective": stats.per_collective,
        },
        "timings_s": {"lower": round(t_lower, 2), "compile": round(t_compile, 2)},
        "kv_repeat": built.cfg.kv_repeat,
    }
    return result


def cell_path(arch, shape, mesh_name, tag="") -> pathlib.Path:
    safe = arch.replace(".", "_").replace("/", "_")
    suffix = f"__{tag}" if tag else ""
    return OUT_DIR / f"{safe}__{shape}__{mesh_name}{suffix}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", type=str, default="",
                    help="variant tag for perf-iteration runs")
    ap.add_argument("--override", type=str, default=None,
                    help="JSON dict of ParallelConfig overrides")
    ap.add_argument("--model-override", type=str, default=None,
                    help="JSON dict of ModelConfig overrides")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute HLO stats from saved .hlo.gz (no compile)")
    args = ap.parse_args()

    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCH_IDS

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    arches = ARCH_IDS if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh]
    override = json.loads(args.override) if args.override else None
    override_model = (json.loads(args.model_override)
                      if args.model_override else None)

    if args.reanalyze:
        from repro.launch.hlo_analysis import analyze_hlo

        for arch in arches:
            for shape in shapes:
                for mp in meshes:
                    mesh_name = "multi" if mp else "single"
                    path = cell_path(arch, shape, mesh_name, args.tag)
                    hlo_path = path.with_suffix(".hlo.gz")
                    if not (path.exists() and hlo_path.exists()):
                        continue
                    res = json.loads(path.read_text())
                    with gzip.open(hlo_path, "rt") as f:
                        text = f.read()
                    stats = analyze_hlo(text, res.get("n_devices", 1))
                    res["hlo"] = {
                        "flops": stats.flops,
                        "hbm_bytes": stats.hbm_bytes,
                        "collective_bytes": stats.collective_bytes,
                        "collective_count": stats.collective_count,
                        "per_collective": stats.per_collective,
                    }
                    path.write_text(json.dumps(res, indent=1))
                    print(f"[reanalyzed] {path.name}")
        return

    failures = 0
    for arch in arches:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                path = cell_path(arch, shape, mesh_name, args.tag)
                if path.exists() and not args.force:
                    print(f"[skip-cached] {path.name}")
                    continue
                print(f"[run] {arch} x {shape} x {mesh_name} ...",
                      flush=True)
                try:
                    res = run_cell(
                        arch, shape, mp, override,
                        hlo_path=path.with_suffix(".hlo.gz"),
                        override_model=override_model)
                except Exception as e:  # record the failure — it is a bug
                    failures += 1
                    res = {
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                if args.tag:
                    res["tag"] = args.tag
                path.write_text(json.dumps(res, indent=1))
                print(f"  -> {res['status']}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
