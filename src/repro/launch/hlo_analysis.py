"""HLO-text analyzer: trip-count-aware FLOPs / HBM bytes / collective bytes.

Why not compiled.cost_analysis()? XLA's HloCostAnalysis visits every while
body ONCE — a 126-layer scanned transformer would be undercounted 126x (and
the gradient-accumulation scan by another 8-16x). The compiled HLO annotates
`backend_config={"known_trip_count":"N"}` on while ops, so we parse the
module, build the call graph (while body/condition, fusion `calls`,
reduction `to_apply`), propagate multiplicities from ENTRY, and accumulate:

  * dot FLOPs: 2 * |result| * prod(contracting dims)   (anywhere, any depth)
  * collective bytes: operand bytes per op kind (all-gather operands are
    result/groups, reduce-scatter operands are result*groups, all-reduce /
    all-to-all / collective-permute operands equal result), weighted by the
    multiplicity of the enclosing computation
  * HBM bytes: operand+result bytes of materializing top-level ops (fusions,
    dots, collectives, copies, dynamic slices); fusion *sub*computations are
    excluded — fused intermediates never touch HBM

Validated against hand-computed counts in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\("
)
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# ops whose operands/results cross HBM on a TPU-like pipeline. Plain
# elementwise / layout ops are EXCLUDED: the XLA:CPU module leaves them
# unfused at top level, but a TPU compile fuses them into neighbors —
# counting them models a no-fusion machine and inflated HBM traffic ~50x
# in early measurements (see EXPERIMENTS.md §Dry-run notes).
_MATERIALIZING = (
    "fusion", "dot", "convolution", "copy", "dynamic-update-slice",
    "dynamic-slice", "gather", "scatter", "concatenate", "pad", "slice",
    "custom-call", "sort",
) + COLLECTIVE_OPS


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    symtab: dict  # %name -> type_str


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if (stripped.startswith("ENTRY") or
                (not line.startswith(" ") and "->" in line and "{" in line)):
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        # tuple types embed /*index=N*/ comments whose '=' breaks matching
        if "/*" in line:
            line = re.sub(r"/\*.*?\*/", "", line)
        mi = _INSTR_RE.match(line)
        if mi:
            name, type_str, op = mi.group(1), mi.group(2), mi.group(3)
            cur.instrs.append(Instr(name, type_str, op, line.strip()))
            cur.symtab[name] = type_str
    return comps


def _operand_names(line: str, op: str) -> list[str]:
    # take the text inside the first (...) after the op name
    idx = line.find(op + "(")
    if idx < 0:
        return []
    depth, start = 0, idx + len(op) + 1
    out, cur_tok = [], []
    for ch in line[start:]:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur_tok).strip())
            cur_tok = []
        else:
            cur_tok.append(ch)
    if cur_tok:
        out.append("".join(cur_tok).strip())
    names = []
    for tok in out:
        tok = tok.strip()
        # operands print either bare ("%name") or typed
        # ("f32[128,256]{1,0} %name") depending on the HLO dumper version —
        # the instruction name is the last %-token either way
        refs = re.findall(r"%([\w\.\-]+)", tok)
        if refs:
            names.append(refs[-1])
    return names


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return total_devices


def _param_effective_bytes(comp: Computation) -> dict[int, int]:
    """For a fusion subcomputation: bytes actually READ per parameter index.

    * parameter consumed ONLY through dynamic-slice -> just the slice bytes
      (scan-over-layers: stacked (L, ...) weights sliced per iteration —
      charging the full stack per layer over-counted HBM ~2500x).
    * parameter consumed ONLY as the destination (operand 0) of
      dynamic-update-slice -> 0 bytes (aliased in-place buffer; only the
      update region moves — the scan ys-stacking pattern).
    """
    param_name_to_idx: dict[str, int] = {}
    for ins in comp.instrs:
        if ins.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.line)
            if m:
                param_name_to_idx[ins.name] = int(m.group(1))
    eff: dict[int, int] = {}
    uses: dict[str, list[tuple[Instr, int]]] = {n: [] for n in param_name_to_idx}
    for ins in comp.instrs:
        if ins.op == "parameter":
            continue
        for pos, oname in enumerate(_operand_names(ins.line, ins.op)):
            if oname in uses:
                uses[oname].append((ins, pos))
    for pname, idx in param_name_to_idx.items():
        full = _shape_bytes(comp.symtab.get(pname, ""))
        us = uses.get(pname, [])
        if us and all(u.op == "dynamic-slice" for u, _ in us):
            eff[idx] = sum(_shape_bytes(u.type_str) for u, _ in us)
        elif us and all(u.op == "dynamic-update-slice" and pos == 0
                        for u, pos in us):
            eff[idx] = 0
        else:
            eff[idx] = full
    return eff


def _fusion_effective_result(comp: Computation, res: int) -> int:
    """Result bytes actually WRITTEN by a fusion: if the body performs
    dynamic-update-slices, only the update regions are written (the output
    aliases the destination buffer)."""
    dus_updates = 0
    has_dus = False
    for ins in comp.instrs:
        if ins.op == "dynamic-update-slice":
            has_dus = True
            ops = _operand_names(ins.line, ins.op)
            if len(ops) >= 2:
                dus_updates += _shape_bytes(comp.symtab.get(ops[1], ""))
    if has_dus:
        return min(res, max(dus_updates, 0))
    return res


_HEAVY_FUSION_OPS = {
    "dot", "convolution", "reduce", "reduce-window", "gather", "scatter",
    "sort", "dynamic-update-slice", "concatenate", "pad",
}


def _fusion_is_elementwise(comp: Computation) -> bool:
    """True if the fusion body has no op that forces materialized reads —
    a TPU compile fuses such chains into their consumers entirely; we charge
    only the result write (the consumer charges the read)."""
    for ins in comp.instrs:
        if ins.op in _HEAVY_FUSION_OPS:
            return False
    return True


def _multiplicities(comps: dict[str, Computation]) -> dict[str, float]:
    """Times each computation executes, propagated from ENTRY."""
    entry = comps.get("__entry__")
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        return mult

    fusion_subs: set[str] = set()

    def visit(comp: Computation, m: float):
        mult[comp.name] += m
        for ins in comp.instrs:
            callees = _CALL_ATTR_RE.findall(ins.line)
            if not callees:
                continue
            trip = 1
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
            for cname in set(callees):
                callee = comps.get(cname)
                if callee is None:
                    continue
                if ins.op == "fusion":
                    fusion_subs.add(cname)
                visit(callee, m * (trip if ins.op == "while" else 1))

    visit(entry, 1.0)
    mult["__fusion_subs__"] = 0.0
    _multiplicities.fusion_subs = fusion_subs  # type: ignore[attr-defined]
    return mult


@dataclasses.dataclass
class HLOStats:
    flops: float
    hbm_bytes: float
    collective_bytes: float  # operand bytes, summed over ops x multiplicity
    per_collective: dict  # op kind -> bytes
    collective_count: int
    uncorrected_flops: float = 0.0


def analyze_hlo(text: str, total_devices: int = 1) -> HLOStats:
    comps = parse_hlo(text)
    mult = _multiplicities(comps)
    fusion_subs: set = getattr(_multiplicities, "fusion_subs", set())

    flops = 0.0
    hbm = 0.0
    coll = 0.0
    coll_count = 0
    per_coll: dict[str, float] = defaultdict(float)
    eff_cache: dict[str, dict[int, int]] = {}

    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_subs
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                out_elems = 1
                _, dims = _shape_dims(ins.type_str)
                for d in dims:
                    out_elems *= d
                kdim = 1
                ops = _operand_names(ins.line, ins.op)
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
                if ops and cm and cm.group(1):
                    lhs_type = comp.symtab.get(ops[0], "")
                    _, ldims = _shape_dims(lhs_type)
                    for ci in cm.group(1).split(","):
                        ci = int(ci)
                        if ci < len(ldims):
                            kdim *= ldims[ci]
                flops += m * 2.0 * out_elems * kdim
            if in_fusion:
                continue  # fused intermediates don't touch HBM
            if ins.op in COLLECTIVE_OPS:
                res_bytes = _shape_bytes(ins.type_str)
                g = _group_size(ins.line, total_devices)
                if ins.op == "all-gather":
                    operand = res_bytes / max(g, 1)
                elif ins.op == "reduce-scatter":
                    operand = res_bytes * g
                else:
                    operand = res_bytes
                coll += m * operand
                per_coll[ins.op] += m * operand
                coll_count += int(m)
            if ins.op in _MATERIALIZING:
                res = _shape_bytes(ins.type_str)
                operands = _operand_names(ins.line, ins.op)
                if ins.op == "fusion":
                    cm_ = _CALL_ATTR_RE.search(ins.line)
                    callee = cm_.group(1) if cm_ else None
                    if callee and callee in comps:
                        if _fusion_is_elementwise(comps[callee]):
                            # XLA:CPU wraps single elementwise ops in one-op
                            # fusions; a TPU compile fuses these chains away
                            # entirely — charge only the boundary write
                            opsum = 0
                        else:
                            if callee not in eff_cache:
                                eff_cache[callee] = _param_effective_bytes(
                                    comps[callee])
                            eff = eff_cache[callee]
                            opsum = sum(
                                min(eff.get(i, 1 << 62),
                                    _shape_bytes(comp.symtab.get(o, "")))
                                for i, o in enumerate(operands))
                            res = _fusion_effective_result(
                                comps[callee], res)
                    else:
                        opsum = sum(_shape_bytes(comp.symtab.get(o, ""))
                                    for o in operands)
                elif ins.op == "dynamic-slice":
                    opsum = res  # reads only the slice
                elif ins.op == "dynamic-update-slice" and len(operands) >= 2:
                    # in-place: reads + writes only the update region
                    upd = _shape_bytes(comp.symtab.get(operands[1], ""))
                    res = upd
                    opsum = upd
                else:
                    opsum = sum(_shape_bytes(comp.symtab.get(o, ""))
                                for o in operands)
                hbm += m * (res + opsum)

    return HLOStats(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=coll,
        per_collective=dict(per_coll),
        collective_count=coll_count,
    )
