"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
pure data parallelism (+ the cross-pod level of every hierarchical
reduction — the RSC-bus level of the iMARS hierarchy).

Defined as a FUNCTION so importing this module never touches jax device
state (required: the dry-run sets XLA_FLAGS before any jax init; tests and
benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType (explicit-sharding API) only exists on newer
    # jax; older releases are Auto-mode-only, so plain make_mesh is right
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
