"""Serving driver: batched greedy generation through prefill + decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduce_config
from repro.configs.registry import get_arch
from repro.models import transformer as tf
from repro.serving.engine import LMServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    bundle = get_arch(args.arch)
    cfg = reduce_config(bundle.model) if args.reduced else bundle.model
    params = tf.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    if cfg.family == "audio":
        toks = rng.integers(0, cfg.vocab_size,
                            (args.batch, cfg.n_codebooks, args.prompt_len))
    else:
        toks = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    if cfg.family == "vlm":
        nv = cfg.vision_tokens
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, nv, cfg.d_model)), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
        batch["vision_pos"] = jnp.asarray(
            np.stack([rng.choice(args.prompt_len, size=nv, replace=False)
                      for _ in range(args.batch)]), jnp.int32)

    engine = LMServingEngine(
        params, cfg, batch=args.batch,
        cache_len=args.prompt_len + args.gen + 4,
        cache_dtype=bundle.parallel.kv_cache_dtype
        if not args.reduced else "bfloat16")
    t0 = time.time()
    out = engine.generate(batch, args.gen)
    dt = time.time() - t0
    tok_s = args.batch * args.gen / dt
    print(f"[serve] {cfg.name}: generated {out.tokens.shape} tokens in "
          f"{dt:.2f}s ({tok_s:.1f} tok/s on this host)")
    print(out.tokens[0])


if __name__ == "__main__":
    main()
