"""Step builders for the dry-run and the drivers: per (arch x shape x mesh),
construct the jitted step function + abstract inputs (ShapeDtypeStruct — no
allocation) + in/out shardings.

This is where the mesh meets the model: kv_repeat is derived from the model
axis, ShardingRules are instantiated per shape kind, and every input gets
its PartitionSpec.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchBundle, ModelConfig, ParallelConfig, \
    SHAPES, ShapeConfig
from repro.distributed import training as tr
from repro.distributed.sharding import (
    ShardingRules,
    param_partition_specs,
    use_rules,
)
from repro.launch.mesh import data_axes_of
from repro.models import transformer as tf
from repro.optim.adamw import AdamWState, QuantState
from repro.serving import engine as serve_engine
from repro.serving.kv_cache import init_cache


# ---------------------------------------------------------------------------
# mesh adaptation
# ---------------------------------------------------------------------------
def adapt_model_to_mesh(cfg: ModelConfig, mesh) -> ModelConfig:
    """Set kv_repeat so rep_kv_heads shards exactly over the model axis
    (only when the resulting grouping still divides n_heads)."""
    model_size = mesh.shape["model"]
    if (cfg.n_kv_heads and cfg.n_kv_heads < model_size
            and model_size % cfg.n_kv_heads == 0):
        r = model_size // cfg.n_kv_heads
        if cfg.n_heads % (cfg.n_kv_heads * r) == 0:
            return cfg.with_(kv_repeat=r)
    return cfg


def heads_shardable(cfg: ModelConfig, mesh) -> bool:
    if not cfg.n_heads:
        return True
    return cfg.rep_kv_heads % mesh.shape["model"] == 0


def make_rules(pcfg: ParallelConfig, mesh, shape: ShapeConfig,
               kind: str, shard_heads: bool = True) -> ShardingRules:
    data_axes = data_axes_of(mesh)
    long_ctx = shape.kind == "decode" and shape.global_batch < _data_size(mesh)
    if kind == "train":
        return ShardingRules(
            data_axes=data_axes, fsdp=pcfg.fsdp, seq_shard=pcfg.seq_shard,
            shard_heads=shard_heads, moe_ff_fsdp=pcfg.moe_shard_ff)
    # serving; unshardable heads -> parallelize prefill over the sequence
    return ShardingRules(
        data_axes=data_axes,
        fsdp=(pcfg.serve_weight_sharding == "2d"),
        seq_shard=(not shard_heads) and shape.kind == "prefill",
        kv_seq_data=long_ctx,
        batch_data=not long_ctx,
        shard_heads=shard_heads,
        moe_ff_fsdp=pcfg.moe_shard_ff,
    )


def _data_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------
def _as_sharding(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(params, opt: AdamWState, rules: ShardingRules):
    pspecs = param_partition_specs(params, rules)
    flat_specs, treedef = jax.tree_util.tree_flatten(pspecs)

    def moment_specs(moment):
        leaves = treedef.flatten_up_to(moment)
        out = []
        for spec, leaf in zip(flat_specs, leaves):
            if isinstance(leaf, QuantState):
                out.append(QuantState(
                    values=spec, scales=P(*(tuple(spec)[:-1] + (None,)))))
            else:
                out.append(spec)
        return treedef.unflatten(out)

    return AdamWState(
        mu=moment_specs(opt.mu), nu=moment_specs(opt.nu), count=P())


def train_state_specs(state: tr.TrainState, rules: ShardingRules):
    pspecs = param_partition_specs(state.params, rules)
    err = None
    if state.err_buf is not None:
        err = pspecs
    return tr.TrainState(
        params=pspecs,
        opt=opt_state_specs(state.params, state.opt, rules),
        step=P(),
        err_buf=err,
    )


def cache_partition_specs(cfg: ModelConfig, rules: ShardingRules):
    """Specs matching serving.kv_cache.init_cache's pytree."""
    batch_ax = rules.data_axes if rules.batch_data else None
    seq_ax = rules.data_axes if rules.kv_seq_data else None
    if seq_ax is None and not rules.shard_heads:
        # unshardable heads: flash-decode layout (cache seq over model)
        seq_ax = rules.model_axis
    kv = lambda: _kv_specs(batch_ax, seq_ax, rules.model_axis,
                           rules.shard_heads)
    if cfg.family in ("dense", "vlm", "audio"):
        return kv()
    if cfg.family == "moe":
        if cfg.moe_layer_step == 1:
            return kv()
        return {"dense": kv(), "moe": kv()}
    ssm_specs = (
        P(None, batch_ax, None, rules.model_axis),  # conv (L,B,K-1,cd)
        P(None, batch_ax, rules.model_axis, None, None),  # ssm (L,B,H,P,N)
    )
    if cfg.family == "ssm":
        return ssm_specs
    if cfg.family == "hybrid":
        rem = cfg.n_layers % cfg.attn_every
        g_ssm = (
            P(None, None, batch_ax, None, rules.model_axis),
            P(None, None, batch_ax, rules.model_axis, None, None),
        )
        rem_state = None
        if rem:
            rem_attn = _kv_specs(batch_ax, seq_ax, rules.model_axis,
                                 rules.shard_heads, stacked=False)
            rem_state = (rem_attn, ssm_specs)
        return (kv(), g_ssm, rem_state)
    raise ValueError(cfg.family)


def _kv_specs(batch_ax, seq_ax, model_axis, shard_heads=True, stacked=True):
    from repro.models.attention import KVCacheView

    lead = (None,) if stacked else ()
    head_ax = model_axis if shard_heads else None
    arr = P(*lead, batch_ax, head_ax, seq_ax, None)
    return KVCacheView(k=arr, v=arr, k_scale=arr, v_scale=arr)


def _prune(specs, cache):
    """Align spec tree with the cache pytree (bf16 caches drop the scale
    leaves; KVCacheView None children vanish from the treedef)."""
    flat_c_paths = jax.tree_util.tree_flatten_with_path(cache)[0]
    treedef = jax.tree_util.tree_structure(cache)
    spec_leaves = []
    for path, _leaf in flat_c_paths:
        node = specs
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                node = node[p.key]
            elif isinstance(p, jax.tree_util.SequenceKey):
                node = node[p.idx]
            elif isinstance(p, jax.tree_util.GetAttrKey):
                node = getattr(node, p.name)
            else:
                node = node[p.idx]
        spec_leaves.append(node)
    return treedef.unflatten(spec_leaves)


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------
def train_batch_abstract(cfg: ModelConfig, pcfg: ParallelConfig,
                         shape: ShapeConfig, mesh):
    accum = pcfg.accum_for(shape.name)
    gb, S = shape.global_batch, shape.seq_len
    assert gb % accum == 0
    mb = gb // accum
    dsz = _data_size(mesh)
    assert mb % dsz == 0, (
        f"{cfg.name}: microbatch {mb} not divisible by data size {dsz}")
    i32 = jnp.int32
    if cfg.family == "audio":
        tok_shape = (accum, mb, cfg.n_codebooks, S)
        spec = P(None, data_axes_of(mesh), None, None)
    else:
        tok_shape = (accum, mb, S)
        spec = P(None, data_axes_of(mesh), None)
    batch = {
        "tokens": jax.ShapeDtypeStruct(tok_shape, i32),
        "labels": jax.ShapeDtypeStruct(tok_shape, i32),
    }
    specs = {"tokens": spec, "labels": spec}
    if cfg.family == "vlm":
        nv = cfg.vision_tokens
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (accum, mb, nv, cfg.d_model), jnp.bfloat16)
        batch["vision_pos"] = jax.ShapeDtypeStruct((accum, mb, nv), i32)
        specs["vision_embeds"] = P(None, data_axes_of(mesh), None, None)
        specs["vision_pos"] = P(None, data_axes_of(mesh), None)
        # M-RoPE positions provided by the frontend stub; accum axis
        # leads so the gradient-accumulation scan slices it
        batch["positions"] = jax.ShapeDtypeStruct((accum, 3, mb, S), i32)
        specs["positions"] = P(None, None, data_axes_of(mesh), None)
    return batch, specs


def serve_batch_abstract(cfg: ModelConfig, shape: ShapeConfig, mesh,
                         rules: ShardingRules, kind: str):
    B = shape.global_batch
    S = shape.seq_len if kind == "prefill" else 1
    batch_ax = rules.data_axes if rules.batch_data else None
    i32 = jnp.int32
    if cfg.family == "audio":
        tok = jax.ShapeDtypeStruct((B, cfg.n_codebooks, S), i32)
        spec = P(batch_ax, None, None)
    else:
        tok = jax.ShapeDtypeStruct((B, S), i32)
        spec = P(batch_ax, None)
    batch = {"tokens": tok}
    specs = {"tokens": spec}
    if cfg.family == "vlm" and kind == "prefill":
        nv = cfg.vision_tokens
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, nv, cfg.d_model), jnp.bfloat16)
        batch["vision_pos"] = jax.ShapeDtypeStruct((B, nv), i32)
        specs["vision_embeds"] = P(batch_ax, None, None)
        specs["vision_pos"] = P(batch_ax, None)
    if cfg.family == "vlm":
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        specs["positions"] = P(None, batch_ax, None)
    return batch, specs


# ---------------------------------------------------------------------------
# builders — each returns (jitted_fn, abstract_args, debug_info)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BuiltStep:
    fn: Any  # jitted
    abstract_args: tuple
    rules: ShardingRules
    cfg: ModelConfig
    note: str = ""


def build_train_step(bundle: ArchBundle, shape: ShapeConfig, mesh) -> BuiltStep:
    cfg = adapt_model_to_mesh(bundle.model, mesh)
    pcfg = bundle.parallel
    rules = make_rules(pcfg, mesh, shape, "train",
                       shard_heads=heads_shardable(cfg, mesh))

    with use_rules(rules):
        state_abs = jax.eval_shape(
            lambda: tr.init_train_state(cfg, pcfg, jax.random.key(0)))
        batch_abs, batch_specs = train_batch_abstract(cfg, pcfg, shape, mesh)
        state_specs = train_state_specs(state_abs, rules)
        grad_shardings = _as_sharding(
            mesh, param_partition_specs(state_abs.params, rules))
        step_fn = tr.make_train_step(cfg, pcfg, shape,
                                     grad_shardings=grad_shardings)

        def wrapped(state, batch):
            with use_rules(rules):
                return step_fn(state, batch)

        jitted = jax.jit(
            wrapped,
            in_shardings=(_as_sharding(mesh, state_specs),
                          _as_sharding(mesh, batch_specs)),
            out_shardings=(_as_sharding(mesh, state_specs), None),
            donate_argnums=(0,),
        )
    return BuiltStep(fn=jitted, abstract_args=(state_abs, batch_abs),
                     rules=rules, cfg=cfg)


def _params_abstract(cfg: ModelConfig):
    return jax.eval_shape(lambda: tf.init_params(cfg, jax.random.key(0)))


def build_prefill_step(bundle: ArchBundle, shape: ShapeConfig, mesh
                       ) -> BuiltStep:
    cfg = adapt_model_to_mesh(bundle.model, mesh)
    pcfg = bundle.parallel
    rules = make_rules(pcfg, mesh, shape, "serve",
                       shard_heads=heads_shardable(cfg, mesh))
    cache_dtype = pcfg.kv_cache_dtype

    with use_rules(rules):
        params_abs = _params_abstract(cfg)
        pspecs = param_partition_specs(params_abs, rules)
        batch_abs, batch_specs = serve_batch_abstract(
            cfg, shape, mesh, rules, "prefill")

        def fn(params, batch):
            with use_rules(rules):
                out = serve_engine.prefill(
                    params, cfg, batch, cache_len=shape.seq_len,
                    cache_dtype=cache_dtype, remat=pcfg.remat)
                return out.logits, out.caches

        cache_abs = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                               cache_dtype))
        cache_specs = _prune(cache_partition_specs(cfg, rules), cache_abs)
        jitted = jax.jit(
            fn,
            in_shardings=(_as_sharding(mesh, pspecs),
                          _as_sharding(mesh, batch_specs)),
            out_shardings=(None, _as_sharding(mesh, cache_specs)),
        )
    return BuiltStep(fn=jitted, abstract_args=(params_abs, batch_abs),
                     rules=rules, cfg=cfg)


def build_decode_step(bundle: ArchBundle, shape: ShapeConfig, mesh
                      ) -> BuiltStep:
    cfg = adapt_model_to_mesh(bundle.model, mesh)
    pcfg = bundle.parallel
    rules = make_rules(pcfg, mesh, shape, "serve",
                       shard_heads=heads_shardable(cfg, mesh))
    cache_dtype = pcfg.kv_cache_dtype

    with use_rules(rules):
        params_abs = _params_abstract(cfg)
        pspecs = param_partition_specs(params_abs, rules)
        batch_abs, batch_specs = serve_batch_abstract(
            cfg, shape, mesh, rules, "decode")
        cache_abs = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                               cache_dtype))
        cache_specs = _prune(cache_partition_specs(cfg, rules), cache_abs)
        idx_abs = jax.ShapeDtypeStruct((), jnp.int32)

        def fn(params, batch, caches, idx):
            with use_rules(rules):
                out = serve_engine.decode_step(params, cfg, batch, caches, idx)
                return out.logits, out.caches

        jitted = jax.jit(
            fn,
            in_shardings=(
                _as_sharding(mesh, pspecs),
                _as_sharding(mesh, batch_specs),
                _as_sharding(mesh, cache_specs),
                NamedSharding(mesh, P()),
            ),
            out_shardings=(None, _as_sharding(mesh, cache_specs)),
            donate_argnums=(2,),
        )
    return BuiltStep(
        fn=jitted,
        abstract_args=(params_abs, batch_abs, cache_abs, idx_abs),
        rules=rules, cfg=cfg)


def build_step(bundle: ArchBundle, shape_name: str, mesh) -> BuiltStep:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_step(bundle, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill_step(bundle, shape, mesh)
    return build_decode_step(bundle, shape, mesh)
