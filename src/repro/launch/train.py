"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
      --steps 50 --ckpt /tmp/ck

On this CPU container run with --reduced (tiny config, 1 device). On a real
TPU pod the same driver runs the full config on the production mesh
(jax.distributed.initialize + make_production_mesh) — the code path is
identical; only mesh construction and config reduction differ.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.reduced import reduce_config
from repro.configs.registry import get_arch
from repro.data.lm_data import PrefetchIterator, synthetic_token_stream
from repro.distributed import training as tr
from repro.distributed.fault_tolerance import FaultPolicy, TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", type=str, default="/tmp/repro_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    args = ap.parse_args()

    bundle = get_arch(args.arch)
    cfg = reduce_config(bundle.model) if args.reduced else bundle.model
    pcfg = bundle.parallel.with_(
        grad_accum={"cli": 2}, logit_chunk=min(64, args.seq),
        opt_state_dtype="float32", fsdp=False, seq_shard=False)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)

    step_fn = jax.jit(
        tr.make_train_step(cfg, pcfg, shape, base_lr=3e-4, warmup=20,
                           total_steps=args.steps),
        donate_argnums=0)

    accum, mb = 2, args.batch // 2

    def batches():
        stream = synthetic_token_stream(
            cfg.vocab_size, args.seq, args.batch, seed=0,
            n_codebooks=cfg.n_codebooks if cfg.family == "audio" else 0)
        for item in stream:
            tok = item["tokens"]
            lab = item["labels"]
            if cfg.family == "audio":
                tok = tok.reshape(accum, mb, cfg.n_codebooks, args.seq)
                lab = lab.reshape(accum, mb, cfg.n_codebooks, args.seq)
            else:
                tok = tok.reshape(accum, mb, args.seq)
                lab = lab.reshape(accum, mb, args.seq)
            batch = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(lab)}
            if cfg.family == "vlm":
                nv = cfg.vision_tokens
                rng = np.random.default_rng(int(item["step"]))
                batch["vision_embeds"] = jnp.asarray(
                    rng.normal(size=(accum, mb, nv, cfg.d_model)),
                    jnp.float32).astype(jnp.dtype(cfg.dtype))
                batch["vision_pos"] = jnp.asarray(
                    np.stack([rng.choice(args.seq, size=(mb, nv),
                                         replace=False)
                              for _ in range(accum)]), jnp.int32)
            yield batch

    data = PrefetchIterator(batches(), depth=2)
    loop = TrainLoop(step_fn, Checkpointer(args.ckpt, keep=2, async_=True),
                     FaultPolicy(checkpoint_every=args.checkpoint_every))
    state, start = loop.resume_or_init(
        lambda: tr.init_train_state(cfg, pcfg, jax.random.key(0)))
    print(f"[train] {cfg.name} reduced={args.reduced} start={start}")
    state, end = loop.run(state, data, args.steps, start_step=start)
    losses = [r.metrics["loss"] for r in loop.records]
    if losses:
        print(f"[train] done: steps {start}->{end}, loss "
              f"{losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
