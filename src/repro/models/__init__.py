"""Model definitions: config-driven LM family + the paper's RecSys models."""
