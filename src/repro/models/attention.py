"""Attention: GQA with mesh-driven KV repetition, qk-norm, RoPE variants,
blocked (flash-style) training/prefill path and cached decode path.

GQA sharding contract (DESIGN.md): kv heads are repeated by cfg.kv_repeat so
the repeated-head axis (rep_kv = n_kv * kv_repeat) divides the model axis;
q is viewed as (B, S, rep_kv, q_per_rep, hd). Every attention einsum then
carries the rep_kv axis through unchanged — under pjit both operands shard
head-aligned and no collective is needed until the output projection.

KV caches may be int8 (row-wise scales over hd) — the paper's ET
quantization applied to the per-session "table" that a KV cache is.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models.layers import (
    apply_rope,
    init_linear,
    init_rms_norm,
    linear,
    param_dtype,
    rms_norm,
    rope_angles,
)


class KVCacheView(NamedTuple):
    """One layer's cache. k/v: (B, rep_kv, S_max, hd) in cache dtype;
    scales present iff int8 (shape (B, rep_kv, S_max, 1) f32)."""

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array | None
    v_scale: jax.Array | None


def init_attention(key, cfg: ModelConfig) -> dict:
    dt = param_dtype(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, dt, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, dt, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, dt, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd, dt)
        p["k_norm"] = init_rms_norm(hd, dt)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    ang = rope_angles(cfg, positions)
    q = apply_rope(q, ang, cfg.rope_fraction)
    k = apply_rope(k, ang, cfg.rope_fraction)
    # mesh-driven kv repetition (see module docstring)
    if cfg.kv_repeat > 1:
        if cfg.opt_kv_layout:
            # §Perf: place the SP boundary before the repeat — a targeted
            # all-gather over seq, instead of GSPMD's "involuntary full
            # rematerialization" when resharding seq->heads through the
            # repeat's concatenate
            k = constrain(k, ("act_batch", None, None, None))
            v = constrain(v, ("act_batch", None, None, None))
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    # heads over model (seq deliberately unsharded here: under sequence
    # parallelism the residual is seq-sharded and XLA inserts the SP
    # all-gather / reduce-scatter pair at these boundaries)
    q = constrain(q, ("act_batch", None, "act_heads", None))
    k = constrain(k, ("act_batch", None, "act_heads", None))
    v = constrain(v, ("act_batch", None, "act_heads", None))
    return q, k, v


def gqa_blocked_attention(
    q5: jax.Array,  # (B, rep_kv, G, Sq, hd)
    k: jax.Array,  # (B, rep_kv, Sk, hd)
    v: jax.Array,  # (B, rep_kv, Sk, hd)
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_k: int = 1024,
) -> jax.Array:
    """Online-softmax GQA attention with a flash-style custom VJP.

    Forward never materializes the score matrix; the backward pass saves
    only (q, k, v, out, lse) — O(S*hd) — and RECOMPUTES scores per kv block
    (§Perf iteration: the naive autodiff of the forward scan saved all
    O(S^2) probability blocks in fp32, which dominated the training-cell
    memory roofline term ~5x)."""
    return _flash_attn(q5, k, v, causal, q_offset, block_k)


def _blocked_kv(x, n_blocks, block_k, pad):
    x = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    B, R = x.shape[0], x.shape[1]
    return jnp.moveaxis(
        x.reshape(B, R, n_blocks, block_k, x.shape[-1]), 2, 0)


def _block_mask(rows, bi, block_k, Sk, causal):
    cols = bi * block_k + jnp.arange(block_k)[None, :]
    mask = cols < Sk
    if causal:
        mask = jnp.logical_and(mask, cols <= rows)
    return mask


def _flash_fwd_impl(q5, k, v, causal, q_offset, block_k):
    """Returns (out (B,R,G,Sq,hd) f32, lse (B,R,G,Sq) f32)."""
    B, R, G, Sq, hd = q5.shape
    Sk = k.shape[2]
    scale = hd**-0.5
    qf = q5.astype(jnp.float32) * scale
    block_k = min(block_k, Sk)
    n_blocks = -(-Sk // block_k)
    pad = n_blocks * block_k - Sk
    kf = _blocked_kv(k, n_blocks, block_k, pad)
    vf = _blocked_kv(v, n_blocks, block_k, pad)
    rows = jnp.arange(Sq)[:, None] + q_offset  # (Sq, 1)

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        kb, vb, bi = blk
        s = jnp.einsum("brgqd,brkd->brgqk", qf, kb)
        mask = _block_mask(rows, bi, block_k, Sk, causal)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.where(
            jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0
        )
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "brgqk,brkd->brgqd", p, vb
        )
        return (m_safe, l_new, acc_new), None

    m0 = jnp.full((B, R, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, R, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, R, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kf, vf, jnp.arange(n_blocks))
    )
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)  # log-sum-exp of scaled scores
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_attn(q5, k, v, causal, q_offset, block_k):
    out, _ = _flash_fwd_impl(q5, k, v, causal, q_offset, block_k)
    return out


def _flash_attn_fwd(q5, k, v, causal, q_offset, block_k):
    out, lse = _flash_fwd_impl(q5, k, v, causal, q_offset, block_k)
    return out, (q5, k, v, out, lse)


def _flash_attn_bwd(causal, q_offset, block_k, res, dout):
    """Flash backward: recompute p per kv block from the saved lse —
    O(S*hd) residuals instead of O(S^2)."""
    q5, k, v, out, lse = res
    B, R, G, Sq, hd = q5.shape
    Sk = k.shape[2]
    scale = hd**-0.5
    qf = q5.astype(jnp.float32) * scale
    doutf = dout.astype(jnp.float32)
    block_k_ = min(block_k, Sk)
    n_blocks = -(-Sk // block_k_)
    pad = n_blocks * block_k_ - Sk
    kf = _blocked_kv(k, n_blocks, block_k_, pad)
    vf = _blocked_kv(v, n_blocks, block_k_, pad)
    rows = jnp.arange(Sq)[:, None] + q_offset
    # D_i = sum_d dout_i * out_i  (rowwise)
    delta = jnp.sum(doutf * out.astype(jnp.float32), axis=-1)  # (B,R,G,Sq)

    def body(dq_acc, blk):
        kb, vb, bi = blk
        s = jnp.einsum("brgqd,brkd->brgqk", qf, kb)
        mask = _block_mask(rows, bi, block_k_, Sk, causal)
        p = jnp.exp(jnp.where(mask[None, None, None], s, -jnp.inf)
                    - lse[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)  # (B,R,G,Sq,bk)
        dv_b = jnp.einsum("brgqk,brgqd->brkd", p, doutf)
        dp = jnp.einsum("brgqd,brkd->brgqk", doutf, vb)
        ds = p * (dp - delta[..., None])  # (B,R,G,Sq,bk)
        dq_blk = jnp.einsum("brgqk,brkd->brgqd", ds, kb) * scale
        dk_b = jnp.einsum("brgqk,brgqd->brkd", ds, qf)
        return dq_acc + dq_blk, (dk_b, dv_b)

    dq0 = jnp.zeros((B, R, G, Sq, hd), jnp.float32)
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(
        body, dq0, (kf, vf, jnp.arange(n_blocks)))
    dk = jnp.moveaxis(dk_blocks, 0, 2).reshape(B, R, n_blocks * block_k_, hd)
    dv = jnp.moveaxis(dv_blocks, 0, 2).reshape(B, R, n_blocks * block_k_, hd)
    dk = dk[:, :, :Sk].astype(k.dtype)
    dv = dv[:, :, :Sk].astype(v.dtype)
    return dq.astype(q5.dtype), dk, dv


_flash_attn.defvjp(_flash_attn_fwd, _flash_attn_bwd)


def _quantize_kv(x: jax.Array):
    """(B, R, S, hd) -> int8 values + (B, R, S, 1) f32 scales (rowwise/hd)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(vals, scale, dtype):
    return (vals.astype(jnp.float32) * scale).astype(dtype)


def attention(
    p: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    cache: KVCacheView | None = None,
    cache_index: jax.Array | None = None,  # scalar: write offset (decode)
    make_cache: bool = False,  # prefill: also return the filled cache
    cache_len: int | None = None,
    cache_dtype: str = "bfloat16",
    attn_impl: str = "blocked",  # "blocked" | "flash" (Pallas on TPU)
):
    """Returns (out (B,S,D), new_cache | None)."""
    B, S, D = x.shape
    hd = cfg.head_dim
    rep_kv = cfg.rep_kv_heads
    G = cfg.n_heads // rep_kv

    q, k, v = _project_qkv(p, x, cfg, positions)

    new_cache = None
    if cache is not None:
        # ---- decode: append at cache_index, attend over the whole cache ---
        kc = jnp.moveaxis(k, 1, 2)  # (B, rep_kv, S=1, hd)
        vc = jnp.moveaxis(v, 1, 2)
        if cache.k_scale is not None:
            kq, ks = _quantize_kv(kc)
            vq, vs = _quantize_kv(vc)
            ck = jax.lax.dynamic_update_slice(
                cache.k, kq, (0, 0, cache_index, 0))
            cv = jax.lax.dynamic_update_slice(
                cache.v, vq, (0, 0, cache_index, 0))
            cks = jax.lax.dynamic_update_slice(
                cache.k_scale, ks, (0, 0, cache_index, 0))
            cvs = jax.lax.dynamic_update_slice(
                cache.v_scale, vs, (0, 0, cache_index, 0))
            new_cache = KVCacheView(ck, cv, cks, cvs)
            k_full = _dequantize_kv(ck, cks, x.dtype)
            v_full = _dequantize_kv(cv, cvs, x.dtype)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache.k, kc.astype(cache.k.dtype), (0, 0, cache_index, 0))
            cv = jax.lax.dynamic_update_slice(
                cache.v, vc.astype(cache.v.dtype), (0, 0, cache_index, 0))
            new_cache = KVCacheView(ck, cv, None, None)
            k_full, v_full = ck, cv
        S_max = k_full.shape[2]
        q5 = jnp.moveaxis(q, 1, 2).reshape(B, rep_kv, G, S, hd)
        s = jnp.einsum(
            "brgqd,brkd->brgqk",
            q5.astype(jnp.float32) * hd**-0.5,
            k_full.astype(jnp.float32),
        )
        valid = jnp.arange(S_max)[None, :] <= cache_index + jnp.arange(S)[:, None]
        s = jnp.where(valid[None, None, None], s, -jnp.inf)
        pattn = jax.nn.softmax(s, axis=-1)
        out5 = jnp.einsum("brgqk,brkd->brgqd", pattn,
                          v_full.astype(jnp.float32))
    else:
        # ---- train / prefill -------------------------------------------
        q5 = jnp.moveaxis(q, 1, 2).reshape(B, rep_kv, G, S, hd)
        kT = jnp.moveaxis(k, 1, 2)  # (B, rep_kv, S, hd)
        vT = jnp.moveaxis(v, 1, 2)
        if attn_impl == "flash":
            # fold (rep_kv, G) into heads, repeat kv; Pallas flash kernel
            qf = q5.reshape(B * rep_kv * G, S, hd)
            kfold = jnp.repeat(kT, G, axis=1).reshape(B * rep_kv * G, S, hd)
            vfold = jnp.repeat(vT, G, axis=1).reshape(B * rep_kv * G, S, hd)
            outf = ops.flash_attention(
                qf.reshape(B, rep_kv * G, S, hd),
                kfold.reshape(B, rep_kv * G, S, hd),
                vfold.reshape(B, rep_kv * G, S, hd),
                causal=True,
            )
            out5 = outf.reshape(B, rep_kv, G, S, hd)
        else:
            out5 = gqa_blocked_attention(q5, kT, vT, causal=True)
        if make_cache:
            S_max = cache_len or S
            pad = S_max - S
            kc = jnp.pad(kT, ((0, 0), (0, 0), (0, pad), (0, 0)))
            vc = jnp.pad(vT, ((0, 0), (0, 0), (0, pad), (0, 0)))
            if cache_dtype == "int8":
                kq, ks = _quantize_kv(kc)
                vq, vs = _quantize_kv(vc)
                new_cache = KVCacheView(kq, vq, ks, vs)
            else:
                new_cache = KVCacheView(
                    kc.astype(jnp.dtype(cache_dtype)),
                    vc.astype(jnp.dtype(cache_dtype)), None, None)

    out = jnp.moveaxis(out5.reshape(B, rep_kv * G, S, hd), 1, 2)
    out = out.reshape(B, S, cfg.n_heads * hd).astype(x.dtype)
    out = linear(p["wo"], out)
    return out, new_cache
