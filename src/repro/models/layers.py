"""Shared layers: norms, linear, RoPE variants (standard / partial / M-RoPE),
MLPs. Params are plain dicts; all modules are pure functions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils import fold_key


def param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_rms_norm(dim: int, dtype) -> jax.Array:
    return jnp.ones((dim,), dtype=dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------
def init_linear(key, din: int, dout: int, dtype, bias: bool = False,
                scale: float | None = None) -> dict:
    scale = (din**-0.5) if scale is None else scale
    p = {"w": (scale * jax.random.normal(key, (din, dout))).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((dout,), dtype=dtype)
    return p


def linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_inv_freq(cfg: ModelConfig) -> jax.Array:
    rot = int(cfg.head_dim * cfg.rope_fraction)
    assert rot % 2 == 0
    return 1.0 / (
        cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)
    )


def rope_angles(cfg: ModelConfig, positions: jax.Array) -> jax.Array:
    """positions: standard (B, S) int32, or M-RoPE (3, B, S).

    Returns angles (B, S, rot/2) f32.
    """
    inv_freq = rope_inv_freq(cfg)  # (rot/2,)
    if cfg.rope_style == "mrope":
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        # (3, B, S, rot/2): one angle set per position component
        ang3 = positions[..., None].astype(jnp.float32) * inv_freq
        sections = cfg.mrope_sections  # e.g. (16, 24, 24), sums to rot/2
        idx = jnp.concatenate(
            [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
        )
        return jnp.take_along_axis(
            jnp.moveaxis(ang3, 0, -1),  # (B, S, rot/2, 3)
            idx[None, None, :, None],
            axis=-1,
        )[..., 0]
    return positions[..., None].astype(jnp.float32) * inv_freq


def apply_rope(x: jax.Array, angles: jax.Array, fraction: float) -> jax.Array:
    """x: (B, S, H, hd); angles: (B, S, rot/2). Rotates the first `rot` dims
    (rot = hd * fraction; chatglm3's 2d/partial rotary uses fraction=0.5)."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    cos = jnp.cos(angles)[:, :, None, :].astype(jnp.float32)
    sin = jnp.sin(angles)[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = x1f * cos - x2f * sin
    r2 = x2f * cos + x1f * sin
    out = jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype)], axis=-1)
    if rot < hd:
        out = jnp.concatenate([out, xp], axis=-1)
    return out


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d_ff = cfg.d_ff if d_ff is None else d_ff
    dt = param_dtype(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "wi": init_linear(ks[0], cfg.d_model, d_ff, dt),
        "wo": init_linear(ks[1], d_ff, cfg.d_model, dt),
    }
    if cfg.act == "swiglu":
        p["wg"] = init_linear(ks[2], cfg.d_model, d_ff, dt)
    return p


def mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    from repro.distributed.sharding import constrain

    h = linear(p["wi"], x)
    if cfg.act == "swiglu":
        h = jax.nn.silu(linear(p["wg"], x)) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, ("act_batch", None, "act_mlp"))
    return linear(p["wo"], h)
