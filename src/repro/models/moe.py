"""Mixture-of-Experts with GShard-style capacity dispatch (EP over `model`).

The paper connection (DESIGN.md §4): the router's top-k candidate selection
over expert "banks" is structurally the iMARS filtering stage, and the
dispatch/combine all-to-alls are the serialized IBC pattern; EP shards the
expert stacks over the model axis exactly like ET banks over CMAs.

Dispatch uses fixed-size groups of tokens (`group_size`) so the one-hot
dispatch/combine tensors stay O(tokens * experts * capacity/group) — the
standard GShard/GLaM einsum formulation that lowers to all-to-alls under
pjit. Dropped tokens (over capacity) pass through the residual unharmed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import init_linear, init_mlp, mlp, param_dtype
from repro.utils import cdiv

MOE_GROUP_SIZE = 1024  # tokens per dispatch group


def init_moe(key, cfg: ModelConfig) -> dict:
    dt = param_dtype(cfg)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = d**-0.5
    p = {
        "router": (scale * jax.random.normal(ks[0], (d, e))).astype(jnp.float32),
        "wi": (scale * jax.random.normal(ks[1], (e, d, f))).astype(dt),
        "wo": (f**-0.5 * jax.random.normal(ks[2], (e, f, d))).astype(dt),
    }
    if cfg.act == "swiglu":
        p["wg"] = (scale * jax.random.normal(ks[3], (e, d, f))).astype(dt)
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
    return p


def moe_layer(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) -> (y, aux_loss). Capacity-based top-k dispatch."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    tokens = B * S
    gsz = min(MOE_GROUP_SIZE, tokens)
    assert tokens % gsz == 0, (tokens, gsz)
    n_groups = tokens // gsz
    cap = max(1, int(gsz * K * cfg.capacity_factor / E))

    xg = x.reshape(n_groups, gsz, D)
    logits = (xg.astype(jnp.float32) @ p["router"])  # (G, S, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, K)  # (G, S, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # sequential slot assignment: slot j claims capacity after slots < j
    counts = jnp.zeros((n_groups, 1, E), jnp.float32)
    dispatch = jnp.zeros((n_groups, gsz, E, cap), jnp.bfloat16)
    combine = jnp.zeros((n_groups, gsz, E, cap), jnp.float32)
    for j in range(K):
        m = jax.nn.one_hot(topi[..., j], E, dtype=jnp.float32)  # (G,S,E)
        pos = jnp.cumsum(m, axis=1) - m + counts  # position within expert
        in_cap = (pos < cap) * m
        counts = counts + m.sum(axis=1, keepdims=True)
        oh_pos = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        d_j = in_cap[..., None] * oh_pos  # (G,S,E,cap)
        dispatch = dispatch + d_j.astype(jnp.bfloat16)
        combine = combine + d_j * topw[..., j][..., None, None]

    dispatch = constrain(dispatch, ("act_batch", None, "act_experts", None))
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch,
                           xg.astype(jnp.bfloat16))
    expert_in = constrain(expert_in, ("act_experts", "act_batch", None, None))

    h = jnp.einsum("egcd,edf->egcf", expert_in, p["wi"].astype(jnp.bfloat16))
    if cfg.act == "swiglu":
        g = jnp.einsum("egcd,edf->egcf", expert_in,
                       p["wg"].astype(jnp.bfloat16))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    out_e = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(jnp.bfloat16))
    out_e = constrain(out_e, ("act_experts", "act_batch", None, None))

    y = jnp.einsum("egcd,gsec->gsd", out_e.astype(jnp.float32), combine)
    y = y.reshape(B, S, D).astype(x.dtype)

    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], x, cfg)

    # GShard load-balancing aux loss
    me = gates.mean(axis=1)  # (G, E) mean gate prob
    ce = jax.nn.one_hot(topi[..., 0], E).mean(axis=1)  # (G, E) dispatch frac
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    return y, aux
