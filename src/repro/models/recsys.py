"""The paper's two RecSys instances: YoutubeDNN (filtering + ranking) and
Facebook DLRM (ranking). Sec. II-A / Fig. 1.

Training uses dense fp32 embedding tables; serving quantizes every table to
int8 (core.quantization) and runs lookups/pooling through the fused kernel
path (core.embedding) plus LSH+Hamming NNS for the filtering stage — exactly
the paper's deployment flow (Sec. III-B).
"""
from __future__ import annotations

from typing import Mapping, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import fold_key

EMBED_DIM = 32  # the paper's ET dimension (32 x int8 = one 256-bit CMA row)


def _mlp_init(key, dims, dtype=jnp.float32):
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        k = jax.random.fold_in(key, i)
        layers.append({
            "w": (a**-0.5 * jax.random.normal(k, (a, b))).astype(dtype),
            "b": jnp.zeros((b,), dtype),
        })
    return layers


def _mlp_apply(layers, x, final_act=False):
    for i, p in enumerate(layers):
        x = x @ p["w"] + p["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# YoutubeDNN
# ---------------------------------------------------------------------------
class YoutubeDNNConfig(NamedTuple):
    n_items: int = 3000
    user_features: Mapping[str, int] = None  # name -> cardinality
    history_len: int = 20
    filter_dims: tuple = (128, 64, 32)  # paper Table I
    rank_dims: tuple = (128, 1)
    embed_dim: int = EMBED_DIM


def default_youtubednn_config() -> YoutubeDNNConfig:
    return YoutubeDNNConfig(
        user_features={
            "user_id": 6040, "gender": 3, "age": 7, "occupation": 21,
            "zip_bucket": 250,
        },
    )


def init_youtubednn(key, cfg: YoutubeDNNConfig) -> dict:
    p = {"tables": {}, "genre_table": None}
    for name, card in sorted(cfg.user_features.items()):
        k = fold_key(key, "table", name)
        p["tables"][name] = 0.05 * jax.random.normal(k, (card, cfg.embed_dim))
    p["item_table"] = 0.05 * jax.random.normal(
        fold_key(key, "items"), (cfg.n_items, cfg.embed_dim))
    # ranking-only UIET (genre) — Table I: 6 ranking UIETs, 5 shared
    p["genre_table"] = 0.05 * jax.random.normal(
        fold_key(key, "genre"), (18, cfg.embed_dim))
    n_feats = len(cfg.user_features) + 1  # + pooled history
    p["filter_mlp"] = _mlp_init(
        fold_key(key, "fmlp"), (n_feats * cfg.embed_dim,) + cfg.filter_dims)
    # ranking input: user emb (32) + item emb (32) + genre (32) + ctx -> 128
    p["rank_mlp"] = _mlp_init(
        fold_key(key, "rmlp"), (4 * cfg.embed_dim,) + cfg.rank_dims)
    return p


def user_tower(p, cfg: YoutubeDNNConfig, batch: dict) -> jax.Array:
    """Filtering stage DNN: returns the user embedding u_i (B, 32)."""
    feats = []
    for name in sorted(cfg.user_features.keys()):
        feats.append(p["tables"][name][batch[name]])  # (B, d)
    hist = batch["history"]  # (B, H) item ids, -1 padded
    valid = (hist >= 0).astype(jnp.float32)
    rows = p["item_table"][jnp.maximum(hist, 0)] * valid[..., None]
    pooled = rows.sum(1) / jnp.maximum(valid.sum(1, keepdims=True), 1.0)
    feats.append(pooled)
    x = jnp.concatenate(feats, axis=-1)
    return _mlp_apply(p["filter_mlp"], x)


def filtering_loss(p, cfg: YoutubeDNNConfig, batch: dict) -> jax.Array:
    """Full softmax over the item vocabulary against the next-watched item."""
    u = user_tower(p, cfg, batch)  # (B, d)
    logits = u @ p["item_table"].T  # (B, n_items)
    return -jnp.mean(
        jax.nn.log_softmax(logits)[jnp.arange(u.shape[0]), batch["label"]]
    )


def rank_tower(p, cfg: YoutubeDNNConfig, batch: dict,
               item_ids: jax.Array) -> jax.Array:
    """Ranking stage: CTR logits for each (user, candidate) pair.

    item_ids: (B, N) candidate ids. Returns (B, N) logits.
    """
    u = user_tower(p, cfg, batch)  # (B, d)
    items = p["item_table"][item_ids]  # (B, N, d)
    genre = p["genre_table"][batch["genre"]]  # (B, d)
    hist = batch["history"]
    valid = (hist >= 0).astype(jnp.float32)
    rows = p["item_table"][jnp.maximum(hist, 0)] * valid[..., None]
    pooled = rows.sum(1) / jnp.maximum(valid.sum(1, keepdims=True), 1.0)
    B, N = item_ids.shape
    ctx = jnp.concatenate([u, genre, pooled], axis=-1)  # (B, 3d)
    x = jnp.concatenate(
        [jnp.broadcast_to(ctx[:, None], (B, N, ctx.shape[-1])), items], -1)
    return _mlp_apply(p["rank_mlp"], x)[..., 0]  # (B, N)


def ranking_loss(p, cfg: YoutubeDNNConfig, batch: dict) -> jax.Array:
    logits = rank_tower(p, cfg, batch, batch["cand_items"])  # (B, N)
    labels = batch["cand_labels"].astype(jnp.float32)  # (B, N) clicks
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# ---------------------------------------------------------------------------
# DLRM (ranking on Criteo)
# ---------------------------------------------------------------------------
class DLRMConfig(NamedTuple):
    n_dense: int = 13
    n_sparse: int = 26
    cardinality: int = 28000  # rows per ET (Table I)
    embed_dim: int = EMBED_DIM
    bottom_dims: tuple = (256, 128, 32)  # paper Table I
    top_dims: tuple = (256, 64, 1)


def init_dlrm(key, cfg: DLRMConfig) -> dict:
    tables = {}
    for i in range(cfg.n_sparse):
        tables[f"cat_{i:02d}"] = 0.05 * jax.random.normal(
            fold_key(key, "dlrm", str(i)), (cfg.cardinality, cfg.embed_dim))
    n_vec = cfg.n_sparse + 1
    n_inter = n_vec * (n_vec - 1) // 2
    return {
        "tables": tables,
        "bottom": _mlp_init(fold_key(key, "bottom"),
                            (cfg.n_dense,) + cfg.bottom_dims),
        "top": _mlp_init(fold_key(key, "top"),
                         (n_inter + cfg.bottom_dims[-1],) + cfg.top_dims),
    }


def dlrm_forward(p, cfg: DLRMConfig, batch: dict) -> jax.Array:
    """batch: dense (B, 13), sparse (B, 26) int32 -> CTR logits (B,)."""
    dense = _mlp_apply(p["bottom"], batch["dense"], final_act=True)  # (B, 32)
    sparse = batch["sparse"]
    embs = [p["tables"][f"cat_{i:02d}"][sparse[:, i]]
            for i in range(cfg.n_sparse)]
    vecs = jnp.stack([dense] + embs, axis=1)  # (B, 27, 32)
    inter = jnp.einsum("bid,bjd->bij", vecs, vecs)  # pairwise dots
    iu, ju = jnp.triu_indices(vecs.shape[1], k=1)
    flat = inter[:, iu, ju]  # (B, 351)
    x = jnp.concatenate([flat, dense], axis=-1)
    return _mlp_apply(p["top"], x)[..., 0]


def dlrm_loss(p, cfg: DLRMConfig, batch: dict) -> jax.Array:
    logits = dlrm_forward(p, cfg, batch)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
