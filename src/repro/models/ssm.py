"""Mamba2 (state-space duality / SSD) block: chunked training scan and O(1)
recurrent decode. Follows the ssd_minimal discrete formulation of
arXiv:2405.21060 (Dao & Gu 2024); validated against a naive sequential
recurrence oracle in tests/test_models_ssm.py.

Sharding: d_inner (and hence heads) shard over the model axis ("tp" on
in/out projections); the recurrent state (B, H, P, N) shards over batch and
heads; the chunked scan is sequential over chunks (jax.lax.scan) so HLO size
is O(1) in sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import param_dtype, rms_norm
from repro.utils import cdiv


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig) -> dict:
    dt = param_dtype(cfg)
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    cd = conv_dim(cfg)
    d_in = 2 * di + 2 * g * n + h
    ks = jax.random.split(key, 4)
    return {
        "in_proj": (d**-0.5 * jax.random.normal(ks[0], (d, d_in))).astype(dt),
        "conv_w": (0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, cd))).astype(dt),
        "conv_b": jnp.zeros((cd,), dt),
        "dt_bias": jnp.log(
            jnp.exp(jnp.linspace(1e-3, 0.1, h)) - 1.0
        ).astype(jnp.float32),  # softplus^-1 of dt range
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((di,), dt),
        "out_proj": (di**-0.5 * jax.random.normal(ks[3], (di, d))).astype(dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B, S, C), w (K, C) -> (B, S, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # stack K shifted views — K is tiny (4), this is the cheap formulation
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    return out + b[None, None, :]


def _segsum(z: jax.Array) -> jax.Array:
    """z: (..., Q) -> (..., Q, Q) with out[i, j] = sum_{j < s <= i} z[s],
    -inf above the diagonal (the SSD 1-semiseparable decay matrix)."""
    Q = z.shape[-1]
    cs = jnp.cumsum(z, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) f32 (post-softplus)
    A: jax.Array,  # (H,) f32, negative
    B_: jax.Array,  # (B, L, G, N)
    C_: jax.Array,  # (B, L, G, N)
    chunk: int,
    init_state: jax.Array | None = None,  # (B, H, P, N)
):
    """Returns (y (B, L, H, P) f32, final_state (B, H, P, N) f32)."""
    Bsz, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    hpg = H // G
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk

    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    Bh = jnp.repeat(B_.astype(jnp.float32), hpg, axis=2).reshape(
        Bsz, nc, chunk, H, N)
    Ch = jnp.repeat(C_.astype(jnp.float32), hpg, axis=2).reshape(
        Bsz, nc, chunk, H, N)

    dA = dtf * A[None, None, None, :]  # (B, nc, Q, H)
    dA_t = jnp.moveaxis(dA, -1, -2)  # (B, nc, H, Q)
    dA_cs = jnp.cumsum(dA_t, axis=-1)  # (B, nc, H, Q)
    xdt = xf * dtf[..., None]  # (B, nc, Q, H, P)

    # --- intra-chunk (quadratic within chunk) ---
    Lmat = jnp.exp(_segsum(dA_t))  # (B, nc, H, Q, Q)
    CB = jnp.einsum("bcqhn,bcshn->bchqs", Ch, Bh)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", CB * Lmat, xdt)

    # --- chunk states ---
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)  # (B, nc, H, Q)
    states = jnp.einsum(
        "bcqhn,bchq,bcqhp->bchpn", Bh, decay_states, xdt
    )  # (B, nc, H, P, N)

    # --- inter-chunk recurrence (sequential scan over chunks) ---
    chunk_decay = jnp.exp(dA_cs[..., -1])  # (B, nc, H)
    s0 = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def body(carry, inp):
        st_c, dec_c = inp  # (B,H,P,N), (B,H)
        prev = carry
        new = prev * dec_c[:, :, None, None] + st_c
        return new, prev  # emit the state *entering* this chunk

    (final_state, state_in) = jax.lax.scan(
        body,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    state_in = jnp.moveaxis(state_in, 0, 1)  # (B, nc, H, P, N)

    # --- inter-chunk contribution ---
    state_decay_in = jnp.exp(dA_cs)  # (B, nc, H, Q)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bchq->bcqhp", Ch, state_in, state_decay_in
    )
    y = (y_diag + y_off).reshape(Bsz, Lp, H, P)[:, :L]
    return y, final_state


def mamba2_block(
    p: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    *,
    conv_state: jax.Array | None = None,  # (B, K-1, conv_dim) decode carry
    ssm_state: jax.Array | None = None,  # (B, H, P, N) decode carry
    decode: bool = False,
):
    """Returns (y (B,S,D), (new_conv_state, new_ssm_state) | None)."""
    B, S, D = x.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    K = cfg.ssm_conv
    cd = conv_dim(cfg)

    zxbcdt = x @ p["in_proj"]  # (B, S, 2*di + 2*g*n + h)
    z, xBC, dt_raw = jnp.split(zxbcdt, [di, di + cd], axis=-1)

    new_conv_state = None
    if decode:
        assert conv_state is not None and S == 1
        window = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
        conv_out = (
            jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
        )[:, None, :]
        new_conv_state = window[:, 1:].astype(jnp.float32)
    else:
        conv_out = _causal_conv(xBC, p["conv_w"], p["conv_b"])
        # conv carry for a subsequent decode = last K-1 raw xBC inputs
        tail = xBC[:, -(K - 1):, :] if S >= K - 1 else jnp.pad(
            xBC, ((0, 0), (K - 1 - S, 0), (0, 0)))
        new_conv_state = tail.astype(jnp.float32)
    xBC = jax.nn.silu(conv_out)
    xc, B_, C_ = jnp.split(xBC, [di, di + g * n], axis=-1)
    xh = xc.reshape(B, S, h, P)
    B_ = B_.reshape(B, S, g, n)
    C_ = C_.reshape(B, S, g, n)
    xh = constrain(xh, ("act_batch", None, "act_heads", None))

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])  # (H,)

    new_ssm_state = None
    if decode:
        assert ssm_state is not None
        hpg = h // g
        Bh = jnp.repeat(B_[:, 0], hpg, axis=1)  # (B, H, N)
        Ch = jnp.repeat(C_[:, 0], hpg, axis=1)
        dA = jnp.exp(dt[:, 0] * A[None, :])  # (B, H)
        xdt = xh[:, 0].astype(jnp.float32) * dt[:, 0][..., None]  # (B,H,P)
        new_ssm_state = (
            ssm_state.astype(jnp.float32) * dA[:, :, None, None]
            + jnp.einsum("bhp,bhn->bhpn", xdt, Bh.astype(jnp.float32))
        )
        y = jnp.einsum("bhpn,bhn->bhp", new_ssm_state, Ch.astype(jnp.float32))
        y = y[:, None]  # (B, 1, H, P)
    else:
        y, final = ssd_chunked(xh, dt, A, B_, C_, cfg.ssm_chunk)
        new_ssm_state = final

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    # gated RMSNorm (mamba2's norm(y * silu(z)))
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, (new_conv_state, new_ssm_state)


def init_decode_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """Per-layer (conv_state, ssm_state) zeros for decode."""
    return (
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
        jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype
        ),
    )
