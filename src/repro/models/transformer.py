"""Config-driven decoder LM covering all 10 assigned architectures:

  dense   — llama3-405b, qwen3-8b (qk-norm), qwen2.5-3b (qkv-bias, tied),
            chatglm3-6b (partial/2d rotary)
  moe     — llama4-maverick (128e top-1, alternating layers, shared expert),
            phi3.5-moe (16e top-2)
  ssm     — mamba2-1.3b (SSD)
  hybrid  — zamba2-1.2b (mamba2 backbone + shared attention block)
  audio   — musicgen-large (K codebook ETs summed at the input — the iMARS
            multi-table pooled lookup on the LM hot path)
  vlm     — qwen2-vl-72b (M-RoPE; patch embeddings provided by the stub
            frontend per the assignment)

Layers are scanned (stacked params) so HLO size is O(1) in depth; remat is
applied to the scan body; KV caches ride the scan as per-layer slices.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCacheView, attention, init_attention
from repro.models.layers import (
    init_mlp,
    init_rms_norm,
    mlp,
    param_dtype,
    rms_norm,
)
from repro.models.moe import init_moe, moe_layer
from repro.utils import fold_key


class ModelOutput(NamedTuple):
    hidden: jax.Array | None  # (B, S, D) final hidden (train mode)
    logits: jax.Array | None  # (B, S_out, V) or (B, S_out, K, V)
    aux_loss: jax.Array
    caches: Any  # stacked per-layer cache pytree (serve modes)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_block(key, cfg: ModelConfig, kind: str) -> dict:
    dt = param_dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "norm1": init_rms_norm(cfg.d_model, dt),
        "norm2": init_rms_norm(cfg.d_model, dt),
    }
    if kind in ("dense", "moe"):
        p["attn"] = init_attention(k1, cfg)
        if kind == "moe":
            p["moe"] = init_moe(k2, cfg)
        else:
            p["mlp"] = init_mlp(k2, cfg)
    elif kind == "mamba":
        p = {"norm1": p["norm1"], "ssm": init_mamba2_wrap(k1, cfg)}
    return p


def init_mamba2_wrap(key, cfg):
    return ssm_mod.init_mamba2(key, cfg)


def _stacked(key, cfg, n, kind):
    keys = jax.random.split(key, max(n, 1))
    return jax.vmap(lambda k: _init_block(k, cfg, kind))(keys)


def init_params(cfg: ModelConfig, key) -> dict:
    dt = param_dtype(cfg)
    kE, kL, kH, kS = jax.random.split(fold_key(key, cfg.name), 4)
    params: dict = {}
    V, D = cfg.padded_vocab, cfg.d_model
    if cfg.family == "audio":
        params["embed"] = (
            0.02 * jax.random.normal(kE, (cfg.n_codebooks, V, D))
        ).astype(dt)
    else:
        params["embed"] = (0.02 * jax.random.normal(kE, (V, D))).astype(dt)

    if cfg.family in ("dense", "vlm", "audio"):
        params["layers"] = _stacked(kL, cfg, cfg.n_layers, "dense")
    elif cfg.family == "moe":
        if cfg.moe_layer_step == 1:
            params["layers"] = _stacked(kL, cfg, cfg.n_layers, "moe")
        else:  # alternating dense/moe pairs (llama4)
            assert cfg.moe_layer_step == 2 and cfg.n_layers % 2 == 0
            k1, k2 = jax.random.split(kL)
            params["layers"] = {
                "dense": _stacked(k1, cfg, cfg.n_layers // 2, "dense"),
                "moe": _stacked(k2, cfg, cfg.n_layers // 2, "moe"),
            }
    elif cfg.family == "ssm":
        params["layers"] = _stacked(kL, cfg, cfg.n_layers, "mamba")
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers % cfg.attn_every
        k1, k2, k3 = jax.random.split(kL, 3)
        params["mamba_layers"] = _stacked(
            k1, cfg, groups * cfg.attn_every, "mamba")
        if rem:
            params["extra_mamba"] = _stacked(k2, cfg, rem, "mamba")
        params["shared_attn"] = _init_block(kH, cfg, "dense")
    else:
        raise ValueError(cfg.family)

    params["final_norm"] = init_rms_norm(D, dt)
    if not cfg.tie_embeddings:
        if cfg.family == "audio":
            params["lm_head"] = (
                D**-0.5 * jax.random.normal(kS, (cfg.n_codebooks, D, V))
            ).astype(dt)
        else:
            params["lm_head"] = (
                D**-0.5 * jax.random.normal(kS, (D, V))
            ).astype(dt)
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _attn_mlp_block(p, x, cfg, positions, *, cache=None, cache_index=None,
                    make_cache=False, cache_len=None, cache_dtype="bfloat16",
                    attn_impl="blocked", use_moe=False):
    x = constrain(x, ("act_batch", "act_seq", None))
    h, new_cache = attention(
        p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, positions,
        cache=cache, cache_index=cache_index, make_cache=make_cache,
        cache_len=cache_len, cache_dtype=cache_dtype, attn_impl=attn_impl,
    )
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        h, aux = moe_layer(p["moe"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
    else:
        h = mlp(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
    x = x + h
    x = constrain(x, ("act_batch", "act_seq", None))
    return x, aux, new_cache


def _mamba_block(p, x, cfg, *, conv_state=None, ssm_state=None, decode=False):
    x = constrain(x, ("act_batch", "act_seq", None))
    h, states = ssm_mod.mamba2_block(
        p["ssm"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg,
        conv_state=conv_state, ssm_state=ssm_state, decode=decode,
    )
    return x + h, states


# ---------------------------------------------------------------------------
# embedding in / out
# ---------------------------------------------------------------------------
def embed_tokens(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    tokens = batch["tokens"]
    if cfg.family == "audio":
        # (B, K, S) codebook grid -> sum of K codebook embeddings
        # (the iMARS multi-table pooled lookup, dense-training flavor)
        return _audio_embed(params, cfg, tokens)
    x = params["embed"][tokens]  # (B, S, D)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(x.dtype)  # (B, n_vis, D)
        pos = batch["vision_pos"]  # (B, n_vis) int32 slot indices

        def put(xb, pb, vb):
            return xb.at[pb].set(vb)

        x = jax.vmap(put)(x, pos, vis)
    return x


def _audio_embed(params, cfg, tokens):
    # tokens (B, K, S); embed (K, V, D): gather per codebook then sum
    def one(book, toks):  # (V, D), (B, S)
        return book[toks]

    per = jax.vmap(one, in_axes=(0, 1), out_axes=0)(
        params["embed"], tokens
    )  # (K, B, S, D)
    return per.sum(0)


def unembed(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """h (B, S, D) -> logits (B, S, padded_V) (or (..., K, V) for audio).

    Vocab-padding tail (ids >= vocab_size) is masked to -inf so sampling /
    argmax can never emit a padded id.
    """
    if cfg.family == "audio":
        logits = jnp.einsum("bsd,kdv->bskv", h, params["lm_head"])
    else:
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = h @ w
        logits = constrain(logits, ("act_batch", None, "act_vocab"))
    if cfg.padded_vocab != cfg.vocab_size:
        ids = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(ids < cfg.vocab_size, logits, -1e30)
    return logits


def default_positions(cfg: ModelConfig, batch: dict, B: int, S: int,
                      offset=0):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset  # (1, S)
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.rope_style == "mrope":
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def forward(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    *,
    mode: str = "train",  # train | prefill | decode
    caches: Any = None,  # stacked per-layer cache pytree (decode)
    cache_index: jax.Array | None = None,
    cache_len: int | None = None,
    cache_dtype: str = "bfloat16",
    remat: str = "none",
    attn_impl: str = "blocked",
    logits_mode: str = "auto",  # auto | none | last | all
) -> ModelOutput:
    tokens = batch["tokens"]
    B = tokens.shape[0]
    S = tokens.shape[-1]
    x = embed_tokens(params, cfg, batch)
    offset = 0 if mode != "decode" else cache_index
    positions = default_positions(cfg, batch, B, S, offset=offset)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        x, aux, caches = _transformer_stack(
            params, cfg, x, positions, mode, caches, cache_index,
            cache_len, cache_dtype, remat, attn_impl)
    elif cfg.family == "ssm":
        x, aux, caches = _ssm_stack(params, cfg, x, mode, caches, remat)
    elif cfg.family == "hybrid":
        x, aux, caches = _hybrid_stack(
            params, cfg, x, positions, mode, caches, cache_index,
            cache_len, cache_dtype, remat, attn_impl)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)

    if logits_mode == "auto":
        logits_mode = {"train": "none", "prefill": "last", "decode": "all"}[mode]
    logits = None
    if logits_mode == "last":
        logits = unembed(params, cfg, x[:, -1:])
    elif logits_mode == "all":
        logits = unembed(params, cfg, x)
    return ModelOutput(hidden=x, logits=logits, aux_loss=aux, caches=caches)


def _maybe_remat(fn, remat):
    return jax.checkpoint(fn) if remat == "block" else fn


def _transformer_stack(params, cfg, x, positions, mode, caches, cache_index,
                       cache_len, cache_dtype, remat, attn_impl):
    alternating = cfg.family == "moe" and cfg.moe_layer_step == 2
    kind_moe = cfg.family == "moe" and cfg.moe_layer_step == 1

    def body(carry, xs):
        x, aux = carry
        layer_p, cache_slice = xs
        if alternating:
            x, a1, nc_d = _attn_mlp_block(
                layer_p["dense"], x, cfg, positions,
                cache=cache_slice["dense"] if mode == "decode" else None,
                cache_index=cache_index,
                make_cache=(mode == "prefill"), cache_len=cache_len,
                cache_dtype=cache_dtype, attn_impl=attn_impl, use_moe=False)
            x, a2, nc_m = _attn_mlp_block(
                layer_p["moe"], x, cfg, positions,
                cache=cache_slice["moe"] if mode == "decode" else None,
                cache_index=cache_index,
                make_cache=(mode == "prefill"), cache_len=cache_len,
                cache_dtype=cache_dtype, attn_impl=attn_impl, use_moe=True)
            new_cache = {"dense": nc_d, "moe": nc_m}
            aux = aux + a1 + a2
        else:
            x, a, new_cache = _attn_mlp_block(
                layer_p, x, cfg, positions,
                cache=cache_slice if mode == "decode" else None,
                cache_index=cache_index,
                make_cache=(mode == "prefill"), cache_len=cache_len,
                cache_dtype=cache_dtype, attn_impl=attn_impl,
                use_moe=kind_moe)
            aux = aux + a
        return (x, aux), new_cache

    body = _maybe_remat(body, remat)
    layers = params["layers"]
    if alternating:
        n_scan = cfg.n_layers // 2
        layer_tree = {"dense": layers["dense"], "moe": layers["moe"]}
    else:
        n_scan = cfg.n_layers
        layer_tree = layers
    cache_xs = caches if mode == "decode" else _none_like(n_scan)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (layer_tree, cache_xs)
    )
    if mode == "train":
        new_caches = None
    return x, aux, new_caches


def _none_like(shape):
    # scan requires a pytree with consistent leading dim; use a dummy array
    if isinstance(shape, int):
        shape = (shape,)
    return jnp.zeros(shape, jnp.int32)


def _ssm_stack(params, cfg, x, mode, caches, remat):
    decode = mode == "decode"

    def body(carry, xs):
        x = carry
        layer_p, state = xs
        if decode:
            conv_s, ssm_s = state
            x, new_state = _mamba_block(
                layer_p, x, cfg, conv_state=conv_s, ssm_state=ssm_s,
                decode=True)
        else:
            x, new_state = _mamba_block(layer_p, x, cfg)
        return x, new_state

    body = _maybe_remat(body, remat)
    cache_xs = caches if decode else _none_like(cfg.n_layers)
    x, new_states = jax.lax.scan(body, x, (params["layers"], cache_xs))
    if mode == "train":
        new_states = None
    return x, jnp.zeros((), jnp.float32), new_states


def _hybrid_stack(params, cfg, x, positions, mode, caches, cache_index,
                  cache_len, cache_dtype, remat, attn_impl):
    """Zamba2: shared attention block before every group of `attn_every`
    mamba layers (+ once before the remainder group)."""
    groups = cfg.n_layers // cfg.attn_every
    rem = cfg.n_layers % cfg.attn_every
    decode = mode == "decode"
    shared = params["shared_attn"]
    aux0 = jnp.zeros((), jnp.float32)

    mamba_p = params["mamba_layers"]
    # reshape stacked (groups*attn_every, ...) -> (groups, attn_every, ...)
    mamba_g = jax.tree_util.tree_map(
        lambda a: a.reshape((groups, cfg.attn_every) + a.shape[1:]), mamba_p)

    def group_body(carry, xs):
        x = carry
        g_params, attn_cache, m_states = xs
        x, _, new_attn_cache = _attn_mlp_block(
            shared, x, cfg, positions,
            cache=attn_cache if decode else None, cache_index=cache_index,
            make_cache=(mode == "prefill"), cache_len=cache_len,
            cache_dtype=cache_dtype, attn_impl=attn_impl)

        def inner(carry2, xs2):
            x2 = carry2
            lp, st = xs2
            if decode:
                conv_s, ssm_s = st
                x2, new_st = _mamba_block(
                    lp, x2, cfg, conv_state=conv_s, ssm_state=ssm_s,
                    decode=True)
            else:
                x2, new_st = _mamba_block(lp, x2, cfg)
            return x2, new_st

        x, new_m_states = jax.lax.scan(inner, x, (g_params, m_states))
        return x, (new_attn_cache, new_m_states)

    group_body = _maybe_remat(group_body, remat)
    if decode:
        attn_caches, m_states, rem_state = caches
    else:
        attn_caches = _none_like(groups)
        m_states = _none_like((groups, cfg.attn_every))
        rem_state = None
    x, (new_attn_caches, new_m_states) = jax.lax.scan(
        group_body, x, (mamba_g, attn_caches, m_states))

    new_rem = None
    if rem:
        rem_attn_cache, rem_m = (rem_state if decode else (None, None))
        x, _, new_rem_attn = _attn_mlp_block(
            shared, x, cfg, positions,
            cache=rem_attn_cache, cache_index=cache_index,
            make_cache=(mode == "prefill"), cache_len=cache_len,
            cache_dtype=cache_dtype, attn_impl=attn_impl)

        def inner2(carry2, xs2):
            x2 = carry2
            lp, st = xs2
            if decode:
                conv_s, ssm_s = st
                x2, new_st = _mamba_block(lp, x2, cfg, conv_state=conv_s,
                                          ssm_state=ssm_s, decode=True)
            else:
                x2, new_st = _mamba_block(lp, x2, cfg)
            return x2, new_st

        rem_xs = rem_m if decode else _none_like(rem)
        x, new_rem_m = jax.lax.scan(inner2, x, (params["extra_mamba"], rem_xs))
        new_rem = (new_rem_attn, new_rem_m)

    if mode == "train":
        return x, aux0, None
    return x, aux0, (new_attn_caches, new_m_states, new_rem)
