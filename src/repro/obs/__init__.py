"""Observability: the unified telemetry layer for the serving stack.

One `MetricsRegistry` per server (or one shared across a serving stack)
is the single home for every counter the subsystems used to keep ad-hoc
— hot-cache hits, pruned-scan blocks touched, delta-overlay occupancy,
tier residency, compaction pauses, shed/error accounting, fold staleness
— plus per-request **stage spans** threaded through the ticket lifecycle
(submit -> admit -> bucket -> dispatch -> scan -> rank -> resolve) in all
three `make_server` modes. Exporters: `MetricsRegistry.snapshot()` (flat
dict, embedded in BENCH_*.json), `to_prometheus()` (text exposition),
`EventLog` JSONL, and the `tools/obs_report.py` breakdown CLI. The whole
layer is overhead-gated: benchmarks/obs_overhead.py asserts instrumented
serving holds >= 0.95x uninstrumented qps. See docs/OBSERVABILITY.md.
"""
from repro.obs.registry import (
    EventLog,
    MetricsRegistry,
    bucket_upper_bounds,
)
from repro.obs.tracing import (
    STAGES,
    TicketTrace,
    dump_trace,
    stage_durations,
    trace_record,
    well_ordered,
)

__all__ = [
    "STAGES",
    "EventLog",
    "MetricsRegistry",
    "TicketTrace",
    "bucket_upper_bounds",
    "dump_trace",
    "stage_durations",
    "trace_record",
    "well_ordered",
]
