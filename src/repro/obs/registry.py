"""The metrics registry: one home for every serving-stack counter.

iMARS's headline numbers are per-stage latency/energy breakdowns; RecNMP
and MicroRec justify their designs with measured locality and per-stage
profiles. Before this module the reproduction could not produce either:
every subsystem kept an ad-hoc counter dict (hot-cache hits on the
batcher, compaction pauses on the catalog, staleness lists on the
trainer) with no shared schema and no export path. `MetricsRegistry` is
the single sink they all report into, designed so the *hot serving path
pays almost nothing* (gated in benchmarks/obs_overhead.py: instrumented
serving must hold >= 0.95x uninstrumented qps):

  * **counters** (`count`) and **histograms** (`observe`) write to
    per-thread shards — a plain dict bump / one numpy bucket increment,
    no lock on the hot path — merged only when a `snapshot()` is taken;
  * **gauges** (`gauge`) and **info** entries (`info`, non-numeric) are
    last-write-wins under a short lock — they are set from *collector*
    callbacks (`register_collector`), which run at snapshot time, so
    subsystems keep their cheap plain-int attributes and only translate
    them to registry keys when somebody actually looks;
  * **histograms** are log2-bucketed (bucket i counts observations
    ``v <= HIST_BASE * 2**i``), so a 48-cell int64 array spans 1 us to
    ~3 days of latency with constant memory and O(1) updates;
  * **events** (`event`) append structured records (compaction, epoch
    publication, fold) to a bounded in-memory log exportable as JSONL.

Naming convention (docs/OBSERVABILITY.md): dotted lowercase
``subsystem.metric[_unit]`` — e.g. ``serving.served``,
``cache.hits``, ``catalog.compact_pause_s``, ``online.staleness_ms``.

Exporters: `snapshot()` (flat dict: merged counters + gauges + info +
per-histogram summary stats), `to_prometheus()` (text exposition), and
`EventLog.to_jsonl()` / `write_jsonl()` for the event stream.
"""
from __future__ import annotations

import json
import math
import threading
import time
from collections import deque
from typing import Callable

import numpy as np

# log2 histogram buckets: bucket i counts v <= HIST_BASE * 2**i; the last
# bucket absorbs overflow. 48 buckets from 1 us cover ~2.8e8 s.
HIST_BASE = 1e-6
HIST_BUCKETS = 48

# bounded event log: newest-wins would reorder history, so the log keeps
# the most recent EVENT_CAP records and counts what it dropped
EVENT_CAP = 10_000


def _bucket_index(value: float) -> int:
    """Histogram bucket for one observation (0 for v <= HIST_BASE)."""
    if value <= HIST_BASE:
        return 0
    return min(HIST_BUCKETS - 1,
               max(0, math.ceil(math.log2(value / HIST_BASE))))


def bucket_upper_bounds() -> list[float]:
    """The ``le`` upper bound of every histogram bucket, ascending."""
    return [HIST_BASE * 2.0 ** i for i in range(HIST_BUCKETS)]


class _Hist:
    """One thread's shard of one histogram (unsynchronized by design)."""

    __slots__ = ("counts", "total", "n", "max")

    def __init__(self):
        self.counts = np.zeros(HIST_BUCKETS, np.int64)
        self.total = 0.0
        self.n = 0
        self.max = 0.0

    def observe(self, value: float) -> None:
        self.counts[_bucket_index(value)] += 1
        self.total += value
        self.n += 1
        if value > self.max:
            self.max = value


class _Shard:
    """Per-thread metric shard: counters + histograms, no locking.

    Only the owning thread writes a shard; `snapshot()` reads every shard
    (tearing between a counter bump and its histogram twin is acceptable
    for telemetry — each individual value is always internally sane).
    """

    __slots__ = ("counters", "hists")

    def __init__(self):
        self.counters: dict[str, float] = {}
        self.hists: dict[str, _Hist] = {}


class EventLog:
    """Bounded structured event log (compactions, epoch swaps, folds).

    `append` is O(1) and thread-safe; the log keeps the most recent
    `EVENT_CAP` records (`n_dropped` counts evictions). Each record is a
    JSON-serializable dict carrying ``seq`` (monotonic), ``unix_time``,
    ``kind``, and the caller's fields — exported via `to_jsonl()` /
    `write_jsonl()` for offline tooling.
    """

    def __init__(self, cap: int = EVENT_CAP):
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=cap)
        self._seq = 0
        self.n_dropped = 0

    def append(self, kind: str, **fields) -> dict:
        rec = {"seq": 0, "unix_time": time.time(), "kind": str(kind),
               **fields}
        with self._lock:
            rec["seq"] = self._seq
            self._seq += 1
            if len(self._events) == self._events.maxlen:
                self.n_dropped += 1
            self._events.append(rec)
        return rec

    def records(self) -> list[dict]:
        """The retained events, oldest first (a copy)."""
        with self._lock:
            return list(self._events)

    def to_jsonl(self) -> str:
        return "".join(json.dumps(r, sort_keys=True) + "\n"
                       for r in self.records())

    def write_jsonl(self, path) -> int:
        """Write the retained events to `path`; returns the record count."""
        recs = self.records()
        with open(path, "w") as f:
            for r in recs:
                f.write(json.dumps(r, sort_keys=True) + "\n")
        return len(recs)


class MetricsRegistry:
    """Thread-safe metrics sink with per-thread shards (module docstring).

    The write API (`count`, `observe`, `gauge`, `info`, `event`) is safe
    from any thread; `snapshot()` merges every shard into one flat dict.
    Collectors registered via `register_collector` run at the top of each
    snapshot so lazy subsystems can publish gauges just-in-time.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._local = threading.local()
        self._shards: list[_Shard] = []
        self._gauges: dict[str, float] = {}
        self._info: dict[str, object] = {}
        self._collectors: list[Callable[["MetricsRegistry"], None]] = []
        self.events = EventLog()

    # -- hot-path writes (per-thread shards, no lock) -------------------
    def _shard(self) -> _Shard:
        shard = getattr(self._local, "shard", None)
        if shard is None:
            shard = _Shard()
            self._local.shard = shard
            with self._lock:
                self._shards.append(shard)
        return shard

    def count(self, name: str, n: float = 1) -> None:
        """Add `n` to the counter `name` (monotonic, merged at snapshot)."""
        counters = self._shard().counters
        counters[name] = counters.get(name, 0) + n

    def observe(self, name: str, value: float) -> None:
        """Record one observation into the log2-bucketed histogram."""
        hists = self._shard().hists
        h = hists.get(name)
        if h is None:
            h = hists[name] = _Hist()
        h.observe(float(value))

    # -- snapshot-time writes (locked, last-write-wins) -----------------
    def gauge(self, name: str, value) -> None:
        """Set the gauge `name` (int values stay int in the snapshot)."""
        with self._lock:
            self._gauges[name] = value if isinstance(value, int) \
                else float(value)

    def info(self, name: str, value) -> None:
        """Attach a non-numeric entry (mode strings, per-tenant dicts);
        info entries ride `snapshot()` but are skipped by Prometheus."""
        with self._lock:
            self._info[name] = value

    def event(self, kind: str, **fields) -> dict:
        """Append one structured record to the event log (see EventLog)."""
        return self.events.append(kind, **fields)

    def register_collector(self, fn: Callable) -> None:
        """Register `fn(registry)` to run at the top of every snapshot —
        the bridge from a subsystem's plain-int counters to gauges."""
        with self._lock:
            self._collectors.append(fn)

    # -- merged reads ---------------------------------------------------
    def _merged(self) -> tuple[dict, dict]:
        """(counters, histograms) summed across every thread shard."""
        with self._lock:
            shards = list(self._shards)
        counters: dict[str, float] = {}
        hists: dict[str, _Hist] = {}
        for shard in shards:
            for k, v in shard.counters.items():
                counters[k] = counters.get(k, 0) + v
            for k, h in shard.hists.items():
                m = hists.get(k)
                if m is None:
                    m = hists[k] = _Hist()
                m.counts = m.counts + h.counts
                m.total += h.total
                m.n += h.n
                m.max = max(m.max, h.max)
        return counters, hists

    @staticmethod
    def _quantile(h: _Hist, q: float) -> float:
        """Upper bucket bound at quantile `q` (conservative estimate)."""
        if h.n == 0:
            return 0.0
        target = q * h.n
        cum = np.cumsum(h.counts)
        idx = int(np.searchsorted(cum, target))
        return HIST_BASE * 2.0 ** min(idx, HIST_BUCKETS - 1)

    def snapshot(self) -> dict:
        """Run collectors, then merge everything into one flat dict.

        Counters and gauges land under their own names; each histogram
        `h` expands to ``h.count`` / ``h.sum`` / ``h.mean`` / ``h.p50`` /
        ``h.p99`` / ``h.max``; info entries ride verbatim. The dict is
        JSON-serializable — benchmarks embed it as the ``telemetry`` key
        of BENCH_*.json (validated by `bench_io.check_telemetry_schema`).
        """
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            fn(self)
        counters, hists = self._merged()
        out: dict = {}
        out.update(counters)
        with self._lock:
            out.update(self._gauges)
            out.update(self._info)
        for name, h in sorted(hists.items()):
            out[f"{name}.count"] = int(h.n)
            out[f"{name}.sum"] = float(h.total)
            out[f"{name}.mean"] = float(h.total / h.n) if h.n else 0.0
            out[f"{name}.p50"] = self._quantile(h, 0.50)
            out[f"{name}.p99"] = self._quantile(h, 0.99)
            out[f"{name}.max"] = float(h.max)
        out["events.count"] = len(self.events.records())
        out["events.dropped"] = self.events.n_dropped
        return out

    def to_prometheus(self, prefix: str = "repro") -> str:
        """Prometheus-style text exposition of the numeric state.

        Counters export as ``counter``, gauges as ``gauge``, histograms
        as cumulative ``_bucket{le=...}`` series + ``_sum`` / ``_count``.
        Info entries are skipped (Prometheus values must be numeric).
        """
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            fn(self)
        counters, hists = self._merged()
        with self._lock:
            gauges = dict(self._gauges)

        def metric(name: str) -> str:
            safe = "".join(c if c.isalnum() or c == "_" else "_"
                           for c in name)
            return f"{prefix}_{safe}"

        lines = []
        for name in sorted(counters):
            m = metric(name)
            lines += [f"# TYPE {m} counter", f"{m} {counters[name]:g}"]
        for name in sorted(gauges):
            v = gauges[name]
            m = metric(name)
            lines += [f"# TYPE {m} gauge",
                      f"{m} {v:g}" if isinstance(v, (int, float))
                      else f"{m} 0"]
        bounds = bucket_upper_bounds()
        for name in sorted(hists):
            h, m = hists[name], metric(name)
            lines.append(f"# TYPE {m} histogram")
            cum = 0
            for i, le in enumerate(bounds):
                cum += int(h.counts[i])
                lines.append(f'{m}_bucket{{le="{le:g}"}} {cum}')
            lines.append(f'{m}_bucket{{le="+Inf"}} {int(h.n)}')
            lines.append(f"{m}_sum {h.total:g}")
            lines.append(f"{m}_count {int(h.n)}")
        return "\n".join(lines) + "\n"
