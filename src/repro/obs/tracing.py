"""Per-request stage spans: the ticket lifecycle as host timestamps.

iMARS Fig. 3 is a *pipeline*: lookups feed the filtering NNS which feeds
the ranking crossbars, and the paper's claims are per-stage latency
breakdowns. The serving tier mirrors that pipeline in software, so every
ticket — in all three `make_server` modes, including shed and error
outcomes — carries a **span chain**: ``((stage, t), ...)`` with
`time.perf_counter()` timestamps at each lifecycle boundary, ordered by
`STAGES`:

    submit    the caller handed the query in
    admit     the admission decision (== submit for the single-tenant
              front-ends; shed tickets stop here and jump to resolve)
    bucket    the query left its queue and was assigned a batch bucket
    dispatch  the jitted stage pipeline was dispatched to the device
    scan      the filtering NNS scan completed (sync mode observes the
              real device boundary via an intermediate block; pipelined
              mode retires scan+rank together at the ring sync, so scan
              carries the whole device wait and rank is ~0 there)
    rank      the ranked items were materialized on the host
    resolve   the ticket's result was recorded / redeemable

A chain is *contiguous*: stage i starts where stage i-1 ended, so the sum
of stage durations equals ``done_s - submit_s`` exactly — the property
`benchmarks/obs_overhead.py` gates (stage sum within 10% of measured
ticket latency) and `tools/obs_report.py` renders as a breakdown table.

A chain may be a **subsequence** of `STAGES` (shed: submit/admit/resolve;
error: submit/admit/resolve) but is always non-empty when tracing is on,
starts at ``submit``, ends at ``resolve``, and is non-decreasing in time
(`well_ordered` checks all of it; tested in tests/test_obs.py).
"""
from __future__ import annotations

import json
from typing import NamedTuple

# canonical stage order; every span chain's names are a subsequence
STAGES = ("submit", "admit", "bucket", "dispatch", "scan", "rank",
          "resolve")
_STAGE_RANK = {s: i for i, s in enumerate(STAGES)}


class TicketTrace(NamedTuple):
    """One completed ticket's lifecycle, for the load harness + reports.

    ``stages`` is the span chain described in the module docstring —
    ``()`` when the owning server was built with ``trace=False``. The
    first five fields predate the telemetry layer and keep their exact
    meaning (`load_gen.summarize_trace` consumes only those).
    """

    ticket: int
    tenant: int
    submit_s: float  # time.perf_counter() at admission
    done_s: float  # time.perf_counter() at resolution (== submit_s if shed)
    status: str  # "ok" | "shed" | "error"
    stages: tuple = ()  # ((stage, perf_counter_s), ...), see STAGES

    @property
    def latency_s(self) -> float:
        return self.done_s - self.submit_s


def stage_durations(stages) -> dict:
    """Per-stage wall time of one span chain: {later_stage: seconds}.

    Stage ``s`` is charged the gap since the previous boundary, so the
    values sum to last-minus-first exactly (the chain is contiguous).
    """
    out = {}
    for (_, t0), (name, t1) in zip(stages, stages[1:]):
        out[name] = out.get(name, 0.0) + (t1 - t0)
    return out


def well_ordered(stages) -> bool:
    """True when `stages` is a valid span chain: names form a non-empty
    subsequence of `STAGES` starting at ``submit`` and ending at
    ``resolve``, with non-decreasing timestamps."""
    if not stages:
        return False
    names = [s for s, _ in stages]
    times = [t for _, t in stages]
    if names[0] != "submit" or names[-1] != "resolve":
        return False
    ranks = [_STAGE_RANK.get(n, -1) for n in names]
    if -1 in ranks or any(b <= a for a, b in zip(ranks, ranks[1:])):
        return False
    return all(b >= a for a, b in zip(times, times[1:]))


def trace_record(rec: TicketTrace) -> dict:
    """One `TicketTrace` as the JSON shape `tools/obs_report.py` reads."""
    return {"ticket": int(rec.ticket), "tenant": int(rec.tenant),
            "submit_s": float(rec.submit_s), "done_s": float(rec.done_s),
            "status": rec.status,
            "stages": [[s, float(t)] for s, t in rec.stages]}


def dump_trace(trace, path) -> int:
    """Write a `take_trace()` result as JSONL; returns the record count.

    The file is the input format of ``python tools/obs_report.py`` (one
    JSON object per line, `trace_record` shape).
    """
    n = 0
    with open(path, "w") as f:
        for rec in trace:
            f.write(json.dumps(trace_record(rec), sort_keys=True) + "\n")
            n += 1
    return n
