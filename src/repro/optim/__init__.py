"""Optimizers: AdamW with fp32/bf16/int8 states, schedules, clipping,
gradient compression."""
