"""AdamW with configurable state precision.

State dtypes:
  float32  — standard.
  bfloat16 — halves optimizer memory; fine with fp32 update math.
  int8     — the iMARS quantization idea applied to optimizer memory
             (bitsandbytes-style): per-row symmetric int8 over the last
             axis. `nu` (second moment, non-negative, huge dynamic range) is
             stored as sqrt(nu) before quantization, which compresses its
             range into int8's — see tests/test_optim.py for the convergence
             check vs fp32 states.

Quantized leaves keep the PARAM'S RANK (values: int8 same shape, scales:
last-dim-collapsed), so optimizer state shards with exactly the param's
PartitionSpec (crucial for FSDP: 405B int8 Adam states = 0.75 bytes/param
instead of 8).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass

INT8_MAX = 127.0


@pytree_dataclass
class QuantState:
    """Same-rank int8 container: values (..., d) int8, scales (..., 1) f32."""

    values: jax.Array
    scales: jax.Array


@pytree_dataclass
class AdamWState:
    mu: Any
    nu: Any
    count: jax.Array


def _q(x: jax.Array) -> QuantState:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / INT8_MAX
    v = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return QuantState(values=v, scales=scale.astype(jnp.float32))


def _dq(q: QuantState) -> jax.Array:
    return q.values.astype(jnp.float32) * q.scales


def _encode(x: jax.Array, dtype: str, sqrt_transform: bool = False):
    if dtype == "int8":
        return _q(jnp.sqrt(x) if sqrt_transform else x)
    return x.astype(jnp.dtype(dtype))


def _decode(x, dtype: str, sqrt_transform: bool = False) -> jax.Array:
    if dtype == "int8":
        d = _dq(x)
        return jnp.square(d) if sqrt_transform else d
    return x.astype(jnp.float32)


def init_adamw_state(params: Any, state_dtype: str = "float32") -> AdamWState:
    def zero(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _encode(z, state_dtype)

    return AdamWState(
        mu=jax.tree_util.tree_map(zero, params),
        nu=jax.tree_util.tree_map(lambda p: _encode(
            jnp.zeros(p.shape, jnp.float32), state_dtype, True), params),
        count=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    state_dtype: str = "float32",
):
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1**c
    bc2 = 1.0 - b2**c

    def upd(g, m_s, v_s, p):
        g = g.astype(jnp.float32)
        m = b1 * _decode(m_s, state_dtype) + (1 - b1) * g
        v = b2 * _decode(v_s, state_dtype, True) + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
            jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, _encode(m, state_dtype), _encode(v, state_dtype, True)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_params, AdamWState(mu=new_mu, nu=new_nu, count=count)


# ---------------------------------------------------------------------------
# schedules & clipping
# ---------------------------------------------------------------------------
def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, jnp.maximum(cos, 0.1 * base_lr))

    return lr


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm
