"""Gradient compression: int8 with error feedback for the cross-pod
reduction (the slow RSC-bus level of the hierarchy).

Two forms:
  * `compressed_psum` — explicit shard_map collective: quantize the local
    gradient shard to int8 (per-row scales), psum int32 values and f32
    scales-weighted contributions across the given axis, dequantize. Use in
    manual-collective training variants.
  * `compress_decompress` — the numerics of the above under jit/GSPMD
    (where the allreduce is implicit in the backward pass): quantize +
    dequantize with a persistent error-feedback buffer so the compression
    bias does not accumulate. The dry-run measures its collective-bytes
    effect via the int8 dtype of the reduced tensors in the manual variant;
    under pure GSPMD we report the numerics-only simulation honestly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def _rowwise_q(x):
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8), scale


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """int8-compressed allreduce (call inside shard_map).

    Each participant contributes int8 rows + f32 row scales; the reduction
    sums dequantized contributions (int32 accumulate per participant pair is
    done by the ICI in practice; semantically identical here).
    """
    q, scale = _rowwise_q(x.astype(jnp.float32))
    # psum of the dequantized contribution — bytes on the wire are the int8
    # values + tiny scales (the manual-collective training path sends these)
    return jax.lax.psum(q.astype(jnp.float32) * scale, axis)


def compress_decompress(grads: Any, error_buf: Any):
    """Error-feedback int8 round-trip: g_hat = Q(g + e); e' = g + e - g_hat."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if gf.ndim == 0:
            return g, e  # scalars pass through
        q, scale = _rowwise_q(gf)
        g_hat = q.astype(jnp.float32) * scale
        return g_hat.astype(g.dtype), gf - g_hat

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_buf)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error_buffer(grads_template: Any):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)
