"""Serving: KV caches (bf16 / int8 — the paper's ET quantization applied to
the per-session cache), prefill/decode steps, and the batched RecSys
subsystem (micro-batching queue + hot-row cache + jitted serve step, plus
the pipelined `AsyncServer` that overlaps host-side batching with the
in-flight NNS scan via the staged lookup/scan/rank steps, the threaded
multi-tenant `ConcurrentFrontend` with bounded per-tenant queues and load
shedding, and the `LiveCatalog` versioned embedding store: bounded delta
shard + tombstones + epoch compaction over a read-only base, serving
bit-identically to a from-scratch rebuild while the catalog churns, and
the `TieredCatalog` frequency-tiered out-of-core store: memmapped base
shard + int8 RAM pool + f32 hot cache, migrating rows between tiers at
epoch compaction from measured lookup frequencies, plus the
train-while-serve pair: `OnlineTrainer` folding filtering-model gradient
steps into the live catalog concurrently with serving, and the
`ShadowHarness` freshness oracle asserting live quality tracks a cold
rebuild of the current parameters).

Every front-end implements the one `Server` protocol (submit -> ticket,
result(ticket), flush, close, stats) and is constructed through
`make_server(engine, mode="sync" | "pipelined" | "concurrent", **knobs)`
— see serving/server.py and docs/SERVING.md."""
from repro.serving.async_server import AsyncServer
from repro.serving.batcher import MicroBatcher, ServedQuery, default_buckets
from repro.serving.frontend import ConcurrentFrontend, TicketTrace
from repro.serving.load_gen import LoadGen, LoadSummary, summarize_trace
from repro.serving.server import (
    STATUS_ERROR,
    STATUS_OK,
    STATUS_SHED,
    QueueFullError,
    SchemaMismatchError,
    Server,
    ServerClosedError,
    ServerConfigError,
    ServingError,
    make_server,
    stats_view,
)
from repro.serving.catalog import (
    DeltaFullError,
    DeltaShard,
    LiveCatalog,
    compact_engine,
    empty_delta,
    engine_apply_updates,
    engine_refresh_model,
    materialize,
    rebuild_reference,
)
from repro.serving.online import OnlineTrainer
from repro.serving.shadow import (
    ShadowHarness,
    ShadowRecord,
    rebuild_from_params,
)
from repro.serving.hot_cache import (
    CacheStats,
    HotRowCache,
    build_hot_cache,
    cached_embedding_bag,
    cached_lookup,
    invalidate_rows,
    pin_rows,
    top_ids_by_freq,
)
from repro.serving.tiered import (
    BaseShard,
    BaseShardWriter,
    TieredCatalog,
    open_base_shard,
    write_base_shard,
)
from repro.serving.recsys_engine import (
    RecSysEngine,
    ServeResult,
    filter_step,
    hit_rate,
    lookup_step,
    rank_stage_step,
    rank_step,
    scan_step,
    serve_step,
)

__all__ = [
    "STATUS_ERROR",
    "STATUS_OK",
    "STATUS_SHED",
    "AsyncServer",
    "BaseShard",
    "BaseShardWriter",
    "CacheStats",
    "ConcurrentFrontend",
    "DeltaFullError",
    "DeltaShard",
    "HotRowCache",
    "LiveCatalog",
    "LoadGen",
    "LoadSummary",
    "MicroBatcher",
    "OnlineTrainer",
    "QueueFullError",
    "RecSysEngine",
    "SchemaMismatchError",
    "ServeResult",
    "ServedQuery",
    "Server",
    "ShadowHarness",
    "ShadowRecord",
    "ServerClosedError",
    "ServerConfigError",
    "ServingError",
    "TicketTrace",
    "TieredCatalog",
    "build_hot_cache",
    "cached_embedding_bag",
    "cached_lookup",
    "compact_engine",
    "default_buckets",
    "empty_delta",
    "engine_apply_updates",
    "engine_refresh_model",
    "filter_step",
    "hit_rate",
    "invalidate_rows",
    "lookup_step",
    "make_server",
    "materialize",
    "open_base_shard",
    "pin_rows",
    "rank_stage_step",
    "rank_step",
    "rebuild_from_params",
    "rebuild_reference",
    "scan_step",
    "serve_step",
    "stats_view",
    "summarize_trace",
    "top_ids_by_freq",
    "write_base_shard",
]
