"""Serving: KV caches (bf16 / int8 — the paper's ET quantization applied to
the per-session cache), prefill/decode steps, and the batched RecSys
subsystem (micro-batching queue + hot-row cache + jitted serve step, plus
the pipelined `AsyncServer` that overlaps host-side batching with the
in-flight NNS scan via the staged lookup/scan/rank steps)."""
from repro.serving.async_server import AsyncServer
from repro.serving.batcher import MicroBatcher, ServedQuery, default_buckets
from repro.serving.hot_cache import (
    CacheStats,
    HotRowCache,
    build_hot_cache,
    cached_embedding_bag,
    cached_lookup,
)
from repro.serving.recsys_engine import (
    RecSysEngine,
    ServeResult,
    filter_step,
    hit_rate,
    lookup_step,
    rank_stage_step,
    rank_step,
    scan_step,
    serve_step,
)

__all__ = [
    "AsyncServer",
    "CacheStats",
    "HotRowCache",
    "MicroBatcher",
    "RecSysEngine",
    "ServeResult",
    "ServedQuery",
    "build_hot_cache",
    "cached_embedding_bag",
    "cached_lookup",
    "default_buckets",
    "filter_step",
    "hit_rate",
    "lookup_step",
    "rank_stage_step",
    "rank_step",
    "scan_step",
    "serve_step",
]
