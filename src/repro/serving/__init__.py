"""Serving: KV caches (bf16 / int8 — the paper's ET quantization applied to
the per-session cache), prefill/decode steps, and the batched RecSys
subsystem (micro-batching queue + hot-row cache + jitted serve step)."""
from repro.serving.batcher import MicroBatcher, ServedQuery, default_buckets
from repro.serving.hot_cache import (
    CacheStats,
    HotRowCache,
    build_hot_cache,
    cached_embedding_bag,
    cached_lookup,
)
from repro.serving.recsys_engine import (
    RecSysEngine,
    ServeResult,
    filter_step,
    hit_rate,
    rank_step,
    serve_step,
)

__all__ = [
    "CacheStats",
    "HotRowCache",
    "MicroBatcher",
    "RecSysEngine",
    "ServeResult",
    "ServedQuery",
    "build_hot_cache",
    "cached_embedding_bag",
    "cached_lookup",
    "default_buckets",
    "filter_step",
    "hit_rate",
    "rank_step",
    "serve_step",
]
