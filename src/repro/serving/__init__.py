"""Serving: KV caches (bf16 / int8 — the paper's ET quantization applied to
the per-session cache), prefill/decode steps, batched engines."""
