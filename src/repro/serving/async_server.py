"""Pipelined (double-buffered) serving over the staged iMARS pipeline.

iMARS's end-to-end win comes from keeping the filtering and ranking stages
busy *simultaneously* (paper Fig. 3: the CMA banks scan while the crossbars
rank the previous query's candidates). The synchronous `MicroBatcher` loses
that overlap in software: each bucket is stacked on the host, served, and
converted back to numpy before the next bucket is even assembled, so the
host sits idle while the device scans and the device sits idle while the
host stacks — the MicroRec/RecNMP observation that deployed RecSys latency
hides in lookup/compute *serialization*, not in any single kernel.

`AsyncServer` recovers the overlap with JAX async dispatch — no threads:

  * each bucket is dispatched through the **staged** serve pipeline
    (`lookup_step` -> `scan_step` -> `rank_stage_step`, the fused
    `serve_step` split at its stage boundaries) and the resulting device
    futures are pushed onto a small ring of in-flight buckets;
  * nothing blocks until the ring holds `depth` buckets: while bucket i's
    NNS scan runs on the device, the host is already stacking/padding
    bucket i+1 and dispatching its lookup stage — double-buffering for
    `depth=2`, deeper rings for burstier devices;
  * results are materialized (the only host sync) when a bucket is retired
    off the ring, so the numpy conversion + per-ticket fan-out of bucket i
    also overlaps bucket i+1's device compute;
  * the hot-cache accumulator is threaded through the donated stage steps
    exactly like the synchronous path, so measured hit rates stay honest.

**Query-mesh routing.** When the engine was sharded with a query axis
(`RecSysEngine.shard(mesh, ..., query_axis=...)`), up to `coalesce` full
buckets are concatenated into one routed super-batch per dispatch: the
query-parallel `shard_map` splits its rows contiguously over the query
axis, so concurrent buckets land on **disjoint query blocks** and scan the
catalog in parallel instead of queueing behind each other. `coalesce`
defaults to the query-axis size (1 — no coalescing — for unrouted
engines) and can be forced for testing.

Bit-for-bit contract (tested in tests/test_async_serving.py): pipelined
serving returns exactly the items, scores, and cache counters the
synchronous `MicroBatcher` returns for the same query stream — the ring,
the stage split, and the routing are pure execution knobs.

**Epoch-safe engine swap.** `swap_engine` (inherited from `MicroBatcher`,
driven by `serving/catalog.py`'s `LiveCatalog._publish`) composes with the
ring MVCC-style: a swap never touches in-flight entries — each ring entry
holds device futures of the engine value it was dispatched against, so
those buckets finish on the *old* epoch while every later dispatch serves
the new one. A bucket is always entirely one epoch (asserted over whole
streams in tests/test_catalog.py); counters and the donated hot-cache
accumulator carry across the swap.
"""
from __future__ import annotations

import time
from collections import deque
from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from repro.obs import MetricsRegistry
from repro.serving.batcher import MicroBatcher
from repro.serving.server import ServerConfigError
from repro.serving.recsys_engine import (
    RecSysEngine,
    lookup_step,
    rank_stage_step,
    scan_step,
)


class _InFlight(NamedTuple):
    """One dispatched (possibly coalesced) bucket riding the ring."""

    parts: tuple  # ((chunk, bucket), ...) — chunk = [(ticket, query), ...]
    items: object  # (sum(buckets), top_k) device future
    scores: object  # (sum(buckets), top_k) device future
    blocks: object = None  # (sum(buckets),) blocks-touched future | None
    t_bucket: float = 0.0  # host time the buckets were taken off the queue
    t_dispatch: float = 0.0  # host time the staged pipeline was dispatched


class AsyncServer(MicroBatcher):
    """Pipelined micro-batching server over a `RecSysEngine`.

    Drop-in for `MicroBatcher` (same submit/result/serve_many API, same
    bucketing, same counters) with a ring of up to `depth` in-flight
    buckets dispatched through the staged serve pipeline.

    Args:
      engine: the serving engine (local or sharded).
      max_batch / buckets: bucketing, as `MicroBatcher`.
      depth: in-flight ring size; 1 degenerates to synchronous serving,
        2 (default) double-buffers host work against device compute.
      coalesce: number of full buckets fused into one routed super-batch
        per dispatch. Default: the engine's query-mesh axis size when
        sharded with `query_axis=...`, else 1. Values > 1 route concurrent
        buckets onto disjoint query blocks of the mesh.

    Invariant: results bit-match the synchronous `MicroBatcher` for any
    depth / coalesce / bucket mix (tested).
    """

    mode = "pipelined"

    def __init__(self, engine: RecSysEngine, *, max_batch: int = 256,
                 buckets: Sequence[int] | None = None, depth: int = 2,
                 coalesce: int | None = None, trace: bool = True,
                 registry: MetricsRegistry | None = None):
        super().__init__(engine, max_batch=max_batch, buckets=buckets,
                         trace=trace, registry=registry)
        if depth < 1:
            raise ServerConfigError(f"ring depth must be >= 1, got {depth}")
        if coalesce is None:
            routed = (engine.nns_mesh is not None
                      and engine.nns_query_axis is not None)
            coalesce = (engine.nns_mesh.shape[engine.nns_query_axis]
                        if routed else 1)
        if coalesce < 1:
            raise ServerConfigError(f"coalesce must be >= 1, got {coalesce}")
        self.depth = depth
        self.coalesce = coalesce
        self._ring: deque[_InFlight] = deque()

    # ------------------------------------------------------------------
    @property
    def in_flight(self) -> int:
        """Dispatched-but-unretired buckets currently riding the ring."""
        return len(self._ring)

    def flush(self) -> None:
        """Drain the queue, keeping up to `depth` buckets in flight.

        Dispatches are non-blocking (JAX async dispatch); the only host
        syncs are the retirements, each overlapped with the following
        buckets' host prep and device compute. Returns with every pending
        ticket's result materialized, like the synchronous flush.
        """
        while self._pending:
            self._ring.append(self._dispatch(self._take_parts()))
            while len(self._ring) >= self.depth:
                self._retire()
        while self._ring:
            self._retire()

    # ------------------------------------------------------------------
    def _take_parts(self) -> list[tuple[list, int]]:
        """Pop 1..coalesce chunks off the queue as (chunk, bucket) parts.

        Only *full* `max_batch` chunks coalesce (so the set of compiled
        super-batch shapes stays tiny); a short tail always ships alone in
        its own pow2 bucket.
        """
        parts = []
        while self._pending and len(parts) < self.coalesce:
            chunk = self._pending[: self.max_batch]
            if parts and len(chunk) < self.max_batch:
                break  # tail chunk: dispatch separately
            self._pending = self._pending[self.max_batch:]
            bucket = next(b for b in self.buckets if b >= len(chunk))
            parts.append((chunk, bucket))
        return parts

    def _dispatch(self, parts: list[tuple[list, int]]) -> _InFlight:
        """Stack `parts` into one batch and dispatch the staged pipeline."""
        t_bucket = time.perf_counter() if self.trace else 0.0
        stacked = [self._stack_np([q for _, q in chunk], bucket)
                   for chunk, bucket in parts]
        host = (stacked[0] if len(stacked) == 1 else
                {k: np.concatenate([s[k] for s in stacked])
                 for k in stacked[0]})
        batch = {k: jnp.asarray(v) for k, v in host.items()}
        u, pooled, self._stats = lookup_step(self.engine, batch, self._stats)
        nns = scan_step(self.engine, u)
        items, top, self._stats = rank_stage_step(
            self.engine, batch, nns.indices, u, pooled, self._stats)
        for chunk, bucket in parts:
            self.n_served += len(chunk)
            self.n_padded += bucket - len(chunk)
            self.n_batches += 1
        return _InFlight(parts=tuple(parts), items=items, scores=top.scores,
                         blocks=getattr(nns, "blocks_touched", None),
                         t_bucket=t_bucket,
                         t_dispatch=(time.perf_counter() if self.trace
                                     else 0.0))

    def _retire(self) -> None:
        """Materialize the oldest in-flight bucket and fan out its results.

        Span semantics for the ring (docs/OBSERVABILITY.md): the device
        futures retire *together* at the one host sync, so the ``scan``
        boundary lands on the retirement and ``rank`` is ~0 — the whole
        in-flight device wait shows up as dispatch -> scan. Observing the
        real scan/rank edge would require an extra intermediate block,
        which is exactly the serialization the ring exists to remove.
        """
        inf = self._ring.popleft()
        items = np.asarray(inf.items)  # the one host sync per bucket
        scores = np.asarray(inf.scores)
        if self.trace:
            t_sync = time.perf_counter()
            self.registry.observe("serving.stage.dispatch_s",
                                  inf.t_dispatch - inf.t_bucket)
            self.registry.observe("serving.stage.scan_s",
                                  t_sync - inf.t_dispatch)
            if inf.blocks is not None:
                bt = np.asarray(inf.blocks)
                self.registry.count("nns.blocks_touched", int(bt.sum()))
                self.registry.count("nns.block_scan_queries", int(bt.size))
            tail = (("bucket", inf.t_bucket),
                    ("dispatch", inf.t_dispatch),
                    ("scan", t_sync), ("rank", t_sync))
            for chunk, _ in inf.parts:
                for ticket, _ in chunk:
                    self._spans.setdefault(ticket, []).extend(tail)
        row = 0
        for chunk, bucket in inf.parts:
            self._observe(chunk, items[row: row + bucket])
            for j, (ticket, _) in enumerate(chunk):
                self._resolve(ticket, items[row + j], scores[row + j])
            row += bucket

    # ------------------------------------------------------------------
    def _collect(self, reg: MetricsRegistry) -> None:
        """`MicroBatcher._collect` + the ring knobs and occupancy."""
        super()._collect(reg)
        reg.gauge("serving.ring_depth", self.depth)
        reg.gauge("serving.coalesce", self.coalesce)
        reg.gauge("serving.in_flight", self.in_flight)
