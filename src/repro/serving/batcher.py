"""Micro-batching request queue in front of the jitted iMARS serve step.

Paper mapping (Fig. 3): each submitted query is one user hitting the
recommendation fabric. The batcher plays the role of the query scheduler in
front of the pipeline — it accumulates queries, pads/buckets them to a small
set of fixed batch shapes, and feeds each bucket through **one** jitted
`serve_step` whose stages are exactly the paper's computation flow:

    queue  ->  (1a/1b*) UIET/ItET lookups + pooling   (hot-row cache + int8
                        embedding_pool — CMA RAM mode, Sec. III-A1)
           ->  (1b/1c)  filtering DNN -> user embedding u_i (crossbar MVMs)
           ->  (1d)     fixed-radius Hamming NNS over ItET LSH signatures
                        (TCAM threshold match, optionally bank-sharded over
                        a device mesh)
           ->  (2a-2d)  ranking DNN: CTR per candidate
           ->  (2e)     CTR-buffer threshold top-k -> final items

Bucketing keeps the set of compiled shapes tiny (powers of two up to
`max_batch`): a bucket compiles once and is reused forever after, so the
steady-state cost of a query is pure device compute. Padding rows carry
*invalid* ids (-1 everywhere) — they read zero rows, never touch the
hot-row cache counters, and are dropped before results are handed back, so
padding can never change a served result or a measured hit rate (tested;
this used to replicate the last pending query, which made the padded tail
of a bucket — e.g. a queue smaller than the smallest bucket — re-serve real
ids and lean on the `valid` mask alone to keep the counters honest).

The hot-cache hit accumulator is donated to the jitted step (`serve_step`'s
third argument), so the counters update in place across batches without a
host round-trip per flush.

Telemetry (docs/OBSERVABILITY.md): with ``trace=True`` (the default)
every ticket carries a stage-span chain (submit -> admit -> bucket ->
dispatch -> scan -> rank -> resolve, `repro.obs.tracing.STAGES`) on its
`ServedQuery.stages` and on the `TicketTrace` records `take_trace()`
hands back; the per-server `MetricsRegistry` accumulates ticket-latency
and per-stage histograms plus pruned-scan block counts, and `stats()` is
a compatibility view over `snapshot()` (`server.stats_view`). The whole
layer is overhead-gated in benchmarks/obs_overhead.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import numpy as np

from repro.obs import MetricsRegistry, TicketTrace
from repro.serving.hot_cache import CacheStats
from repro.serving.recsys_engine import RecSysEngine, n_summary_blocks, \
    serve_step
from repro.serving.server import (
    STATUS_OK,
    SchemaMismatchError,
    ServerClosedError,
    ServerConfigError,
    stats_view,
)

# tickets traced beyond this are dropped (counted in `serving.trace_dropped`)
# rather than growing the trace list without bound between take_trace calls
TRACE_CAP = 100_000


def default_buckets(max_batch: int) -> tuple[int, ...]:
    """Powers of two up to max_batch (always includes max_batch)."""
    b, out = 1, []
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


@dataclasses.dataclass
class ServedQuery:
    """One redeemed ticket: the recommendation (or its admission outcome).

    ``status`` is ``"ok"`` for an engine-served result; the concurrent
    front-end resolves rejected/failed tickets as ``"shed"`` / ``"error"``
    with sentinel payloads (items all -1, scores all 0) instead of raising
    through `result()` — see serving/server.py.
    """

    items: np.ndarray  # (top_k,) recommended item ids, -1 padded
    scores: np.ndarray  # (top_k,) CTR scores
    status: str = STATUS_OK  # "ok" | "shed" | "error"
    tenant: int = 0  # submitting tenant (0 for single-tenant front-ends)
    stages: tuple = ()  # stage-span chain (obs.tracing.STAGES); () untraced

    @property
    def ok(self) -> bool:
        """True when the engine actually served this ticket."""
        return self.status == STATUS_OK


class MicroBatcher:
    """Synchronous micro-batching queue over a `RecSysEngine`.

    submit() enqueues single-user queries (dicts of scalars + the history
    vector); flush() drains the queue through bucket-shaped jitted serve
    steps; results() hands back per-ticket recommendations in submission
    order. `serve_many` is the one-call convenience wrapper.
    """

    mode = "sync"

    def __init__(self, engine: RecSysEngine, *, max_batch: int = 256,
                 buckets: Sequence[int] | None = None, trace: bool = True,
                 registry: MetricsRegistry | None = None):
        self.engine = engine
        self.max_batch = max_batch
        self.buckets = tuple(sorted(buckets or default_buckets(max_batch)))
        if self.buckets[-1] != max_batch:
            raise ServerConfigError(
                f"largest bucket {self.buckets[-1]} must equal "
                f"max_batch={max_batch} (buckets={self.buckets})")
        self._feature_names = tuple(sorted(engine.cfg.user_features.keys()))
        self._pending: list[tuple[int, dict]] = []
        self._results: dict[int, ServedQuery] = {}
        self._next_ticket = 0
        self._closed = False
        # donated accumulator: hot-cache hits/lookups across every batch
        self._stats = CacheStats.zero()
        # optional lookup-frequency hook (LiveCatalog.attach wires it to
        # LiveCatalog.observe): called per served chunk with one flat int
        # array of the item ids the batch looked up — history rows and the
        # served candidates. Pure host-side telemetry; never affects
        # serving results.
        self.observer = None
        self._tenant_of: dict[int, int] = {}  # ticket -> submitting tenant
        self._per_tenant: dict[int, dict] = {}
        self.n_served = 0
        self.n_padded = 0
        self.n_batches = 0
        # telemetry: stage spans per open ticket + completed-ticket trace
        self.trace = bool(trace)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.registry.register_collector(self._collect)
        self._spans: dict[int, list] = {}
        self._trace: list[TicketTrace] = []
        self.n_trace_dropped = 0

    # ------------------------------------------------------------------
    def swap_engine(self, engine: RecSysEngine) -> None:
        """Atomically swap to a new engine epoch/update view.

        The live-catalog publication point (`catalog.LiveCatalog.attach`):
        every bucket dispatched *after* the swap serves from `engine`;
        buckets already dispatched (the `AsyncServer` in-flight ring) hold
        device buffers of the old engine value and finish on that epoch —
        a bucket is always entirely one epoch, never mixed. The hot-cache
        hit accumulator and the served/padded counters carry over.
        """
        if tuple(sorted(engine.cfg.user_features.keys())) \
                != self._feature_names:
            raise SchemaMismatchError(
                "swap_engine: user-feature schema changed; "
                "start a new server instead")
        self.engine = engine

    # ------------------------------------------------------------------
    def submit(self, query: dict, *, tenant: int = 0) -> int:
        """Enqueue one user query; returns a ticket for `result()`.

        `tenant` tags the ticket for per-tenant accounting (`stats()`);
        single-tenant front-ends serve every tenant from the one queue.
        """
        if self._closed:
            raise ServerClosedError("submit() on a closed server")
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, query))
        if tenant != 0:
            self._tenant_of[ticket] = tenant
        t = self._per_tenant.setdefault(tenant, {"submitted": 0, "served": 0,
                                                 "shed": 0, "errors": 0})
        t["submitted"] += 1
        if self.trace:
            # the synchronous front-ends admit unconditionally: the admit
            # boundary coincides with submit (no queue to shed from)
            now = time.perf_counter()
            self._spans[ticket] = [("submit", now), ("admit", now)]
        return ticket

    def result(self, ticket: int, *,
               timeout: float | None = None) -> ServedQuery:
        """Recommendations for `ticket` (flushes the queue if still pending).

        Pops the result — each ticket can be redeemed exactly once.
        `timeout` is accepted for protocol uniformity; the synchronous
        front-ends resolve every ticket inside `flush()` and never wait.
        """
        if ticket not in self._results:
            self.flush()
        return self._results.pop(ticket)

    def serve_many(self, queries: Sequence[dict], *,
                   tenant: int = 0) -> list[ServedQuery]:
        """Submit, flush, and collect: one ServedQuery per input query,
        in submission order."""
        tickets = [self.submit(q, tenant=tenant) for q in queries]
        self.flush()
        return [self.result(t) for t in tickets]

    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Drain the queue through bucket-shaped jitted serve steps.

        With tracing on, the synchronous path observes the *real* device
        stage boundaries: an intermediate block on the NNS result marks
        the scan->rank edge (free here — this path blocks on the ranked
        items immediately after anyway), and pruned scans feed their
        blocks-touched counts into the registry.
        """
        while self._pending:
            chunk = self._pending[: self.max_batch]
            self._pending = self._pending[self.max_batch:]
            bucket = next(b for b in self.buckets if b >= len(chunk))
            t_bucket = time.perf_counter() if self.trace else 0.0
            batch = self._stack([q for _, q in chunk], bucket)
            items, top, nns, self._stats = serve_step(
                self.engine, batch, self._stats)
            if self.trace:
                t_dispatch = time.perf_counter()
                jax.block_until_ready(nns.indices)
                t_scan = time.perf_counter()
            items = np.asarray(items)
            scores = np.asarray(top.scores)
            if self.trace:
                t_rank = time.perf_counter()
                self._meter_scan(nns)
                self.registry.observe("serving.stage.dispatch_s",
                                      t_dispatch - t_bucket)
                self.registry.observe("serving.stage.scan_s",
                                      t_scan - t_dispatch)
                self.registry.observe("serving.stage.rank_s",
                                      t_rank - t_scan)
                tail = (("bucket", t_bucket), ("dispatch", t_dispatch),
                        ("scan", t_scan), ("rank", t_rank))
                for ticket, _ in chunk:
                    self._spans.setdefault(ticket, []).extend(tail)
            self._observe(chunk, items)
            for row, (ticket, _) in enumerate(chunk):
                self._resolve(ticket, items[row], scores[row])
            self.n_served += len(chunk)
            self.n_padded += bucket - len(chunk)
            self.n_batches += 1

    def _observe(self, chunk, items) -> None:
        """Feed the frequency observer one served chunk's item lookups:
        the real (non-padding) queries' history ids plus the items served
        back to them. Invalid (-1) ids are filtered by the observer."""
        if self.observer is None or not len(chunk):
            return
        hist = np.concatenate(
            [np.asarray(q["history"], np.int64).reshape(-1)
             for _, q in chunk])
        served = np.asarray(items[: len(chunk)], np.int64).reshape(-1)
        self.observer(np.concatenate([hist, served]))

    def _resolve(self, ticket: int, items, scores) -> None:
        """Record one served ticket (+ its tenant accounting + spans)."""
        tenant = self._tenant_of.pop(ticket, 0)
        stages = self._close_span(ticket, tenant, STATUS_OK)
        self._results[ticket] = ServedQuery(items=items, scores=scores,
                                            tenant=tenant, stages=stages)
        self._per_tenant[tenant]["served"] += 1

    def _close_span(self, ticket: int, tenant: int, status: str) -> tuple:
        """Stamp the resolve boundary, record the `TicketTrace`, and feed
        the latency histograms; returns the finished span chain."""
        if not self.trace:
            return ()
        span = self._spans.pop(ticket, None)
        if not span:
            return ()
        t_res = time.perf_counter()
        span.append(("resolve", t_res))
        stages = tuple(span)
        t_sub = span[0][1]
        self._record_trace(
            TicketTrace(ticket, tenant, t_sub, t_res, status, stages))
        self.registry.observe("serving.ticket_latency_s", t_res - t_sub)
        return stages

    def _record_trace(self, rec: TicketTrace) -> None:
        if len(self._trace) >= TRACE_CAP:
            self.n_trace_dropped += 1
            return
        self._trace.append(rec)

    def take_trace(self) -> list[TicketTrace]:
        """Return and clear the completed-ticket trace (load harness /
        `tools/obs_report.py`); every record carries its span chain when
        the server was built with ``trace=True``."""
        out, self._trace = self._trace, []
        return out

    def _meter_scan(self, nns) -> None:
        """Accumulate pruned-scan effectiveness counters (blocks touched
        per query vs the catalog's summary blocks -> scan_frac). Called
        after the ranked items are materialized, so reading the tiny
        per-query counts never stalls the pipeline."""
        bt = getattr(nns, "blocks_touched", None)
        if bt is not None:
            bt = np.asarray(bt)
            self.registry.count("nns.blocks_touched", int(bt.sum()))
            self.registry.count("nns.block_scan_queries", int(bt.size))

    def _stack_np(self, queries: list[dict], bucket: int) -> dict:
        """Stack per-user queries into one padded (bucket, ...) host batch.

        Padding rows are INVALID queries: every id is -1, so they read zero
        rows and can never count as hot-cache lookups — even without the
        `valid` row mask (which still marks real queries so their results
        are the ones handed back). Returns numpy arrays so callers (the
        pipelined `AsyncServer`) can concatenate several buckets into one
        routed super-batch before the single device transfer.
        """
        n = len(queries)
        history_len = len(np.asarray(queries[0]["history"]))
        batch = {
            name: np.full(bucket, -1, np.int32) for name in
            (*self._feature_names, "genre")
        }
        batch["history"] = np.full((bucket, history_len), -1, np.int32)
        for name in (*self._feature_names, "genre"):
            batch[name][:n] = [q[name] for q in queries]
        batch["history"][:n] = np.stack(
            [np.asarray(q["history"], np.int32) for q in queries])
        batch["valid"] = np.arange(bucket) < n
        return batch

    def _stack(self, queries: list[dict], bucket: int) -> dict:
        """`_stack_np` placed on device: one padded (bucket, ...) batch."""
        return {k: jax.numpy.asarray(v)
                for k, v in self._stack_np(queries, bucket).items()}

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Flush everything pending, then stop admitting queries.

        Idempotent; `submit()` afterwards raises `ServerClosedError`.
        Unredeemed tickets stay redeemable through `result()`.
        """
        if not self._closed:
            self.flush()
            self._closed = True

    def _collect(self, reg: MetricsRegistry) -> None:
        """Snapshot-time collector: publish the plain-int serving counters
        as registry gauges/info (the hot path never touches the registry
        for these — see docs/OBSERVABILITY.md's overhead contract)."""
        cache = self._stats.as_dict()
        reg.info("serving.mode", self.mode)
        reg.info("serving.closed", self._closed)
        reg.gauge("serving.submitted", self._next_ticket)
        reg.gauge("serving.served", self.n_served)
        reg.gauge("serving.shed", 0)
        reg.gauge("serving.errors", 0)
        reg.gauge("serving.pending", len(self._pending))
        reg.gauge("serving.padded", self.n_padded)
        reg.gauge("serving.batches", self.n_batches)
        reg.gauge("serving.trace_dropped", self.n_trace_dropped)
        reg.gauge("cache.hits", cache["hits"])
        reg.gauge("cache.lookups", cache["lookups"])
        reg.gauge("nns.summary_blocks", n_summary_blocks(self.engine))
        reg.info("serving.per_tenant",
                 {t: dict(v) for t, v in self._per_tenant.items()})

    def snapshot(self) -> dict:
        """The full telemetry snapshot (`MetricsRegistry.snapshot`):
        merged counters + collector gauges + histogram summaries."""
        return self.registry.snapshot()

    def stats(self) -> dict:
        """The unified `Server` stats schema (see docs/SERVING.md) — a
        compatibility view over `snapshot()` (`server.stats_view`)."""
        return stats_view(self.snapshot())
