"""Live catalog: versioned embedding store with delta shard + epoch compaction.

iMARS assumes the ItET sits frozen in the CMA fabric, but a production
catalog churns while traffic is live: items are added, retired, and
re-embedded. Rebuilding the engine per update would recompile the serving
pipeline and stall every in-flight query; mutating the base table in place
would corrupt concurrent serves. This module gives the serving stack an
MVCC-style mutable view that never blocks and never changes a served bit:

  * the **base epoch** (the engine's `item_table_q` / `item_sigs`) stays
    read-only — the streaming-NNS superblock layout is never touched;
  * updates land in a bounded **delta shard** (`DeltaShard`): dense int8
    rows + LSH signatures + global item ids, kept *sorted by id* so both
    the O(log D) membership probe (`searchsorted`, the hot-cache idiom) and
    the bounded candidate truncation stay exact;
  * base rows that were deleted or overwritten are **tombstoned** via the
    engine's `item_mask`, threaded through every NNS plan (dense,
    streaming kernel, bank-sharded, query-parallel) like `n_valid`;
  * the filtering stage scans base + delta and fuses the two bounded
    buffers with one `merge_candidate_buffers` reuse
    (`core.nns.delta_aware_nns`) — results bit-match a from-scratch
    rebuild with the final table;
  * `compact()` folds the delta into a new base **epoch**: one host-side
    scatter, a fresh (empty) delta, and an atomic engine swap between
    buckets — in-flight `AsyncServer` ring entries finish on the old
    epoch, hot-cache counters carry over, and only touched rows were ever
    invalidated from the hot set.

Catalog content is canonically **quantized**: `upsert` quantizes f32 rows
once at ingestion (the CMA stores int8 + scale), and every equality
contract — delta serving, compaction, reference rebuild — is defined over
the quantized rows and their signatures. This keeps bit-match achievable:
row-wise int8 quantization and SRP signatures are per-row operations, so a
row's image is identical whether it entered at build time, through the
delta, or through a compaction scatter.

MicroRec/RecFlash context: both show embedding *placement and remapping*
dominating RecSys latency as much as the lookup kernel. The delta shard is
the remap-friendly answer here — updates never reshuffle the base layout,
and compaction is the one (amortized, off-bucket) moment rows move.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lsh import lsh_signature
from repro.core.nns import (
    EMPTY_ID,
    SUMMARY_BLOCK_ROWS,
    build_block_summary,
    update_block_summary,
)
from repro.core.quantization import (
    QuantizedTensor,
    dequantize_rowwise,
    quantize_rowwise,
)
from repro.serving.hot_cache import (
    cached_rows,
    invalidate_rows,
    pin_rows,
    pool_rows,
    top_ids_by_freq,
)
from repro.utils import pytree_dataclass


class DeltaFullError(RuntimeError):
    """The bounded delta shard cannot hold the requested updates."""


@pytree_dataclass(meta_fields=("capacity",))
class DeltaShard:
    """Bounded mutable overlay on a read-only base item table.

    Live slots form an ascending-by-id prefix; free slots carry `EMPTY_ID`
    (which sorts after every real id) so `ids` is always globally sorted —
    the searchsorted membership probe and the (distance, slot) ==
    (distance, id) truncation argument in `core.nns.delta_scan` both hang
    off this invariant. `values`/`scales` are the quantized replacement
    rows (same row-wise int8 format as the base table), `sigs` their
    packed LSH signatures.
    """

    ids: jax.Array  # (D,) int32 ascending, EMPTY_ID = free slot
    values: jax.Array  # (D, d) int8
    scales: jax.Array  # (D, 1) f32
    sigs: jax.Array  # (D, words) uint32
    capacity: int = 0


def empty_delta(capacity: int, embed_dim: int, words: int) -> DeltaShard:
    """An all-free delta shard of `capacity` slots."""
    capacity = int(capacity)
    return DeltaShard(
        ids=jnp.full((capacity,), EMPTY_ID, jnp.int32),
        values=jnp.zeros((capacity, embed_dim), jnp.int8),
        scales=jnp.zeros((capacity, 1), jnp.float32),
        sigs=jnp.zeros((capacity, words), jnp.uint32),
        capacity=capacity)


def delta_n_live(delta: DeltaShard) -> int:
    """Host-side count of occupied delta slots."""
    return int(np.sum(np.asarray(delta.ids) != EMPTY_ID))


# ---------------------------------------------------------------------------
# jit-side delta row resolution (feature pooling + candidate ranking)
# ---------------------------------------------------------------------------
def delta_rows(delta: DeltaShard, ids: jax.Array):
    """ids (...,) -> (hit mask (...,), dequantized rows (..., d) f32).

    Binary-search membership over the sorted live prefix (the hot-cache
    `_probe` idiom). Rows dequantize with the exact formula of the cold
    int8 path, so a delta hit bit-matches the rebuilt base row.
    """
    pos = jnp.searchsorted(delta.ids, ids)
    pos = jnp.clip(pos, 0, delta.capacity - 1)
    hit = (delta.ids[pos] == ids) & (ids >= 0)
    rows = delta.values[pos].astype(jnp.float32) * delta.scales[pos]
    return hit, rows


def delta_cached_rows(delta: DeltaShard | None, cache, table, ids):
    """Delta-aware drop-in for `hot_cache.cached_rows`.

    Resolution order: delta shard (the only source holding a touched row's
    current value — touched ids were invalidated from the hot cache the
    moment they changed) > hot cache > cold int8 path. CacheStats semantics
    are unchanged: lookups count valid ids, hits count hot-set probes — a
    delta hit is not a cache hit, exactly as in a rebuilt engine whose
    cache pins the same surviving hot set.

    Ids beyond the current base table that miss the delta read ZERO rows
    (not the clamped-gather last row): the engine and its compacted /
    reference rebuilds have different base sizes, and an id that is
    out-of-catalog on one side materializes as the canonical zero row on
    the other — zeroing is the one resolution both sides agree on bit for
    bit (e.g. a retired new-id still present in a user's history).
    """
    rows, stats = cached_rows(cache, table, ids)
    if delta is None or delta.capacity == 0:
        return rows, stats
    in_range = (ids < table.values.shape[0])[..., None]
    hit, drows = delta_rows(delta, ids)
    return jnp.where(hit[..., None], drows,
                     jnp.where(in_range, rows, 0.0)), stats


def delta_cached_embedding_bag(delta, cache, table, ids, weights=None,
                               mode: str = "sum"):
    """Delta-aware drop-in for `hot_cache.cached_embedding_bag`.

    The exact `pool_rows` reduction the frozen bag uses, over rows
    resolved through the delta overlay — identical ops on identical
    inputs, so pooling bit-matches a rebuilt engine's paths.
    """
    rows, stats = delta_cached_rows(delta, cache, table, ids)  # (B, L, d)
    return pool_rows(rows, ids, weights, mode), stats


# ---------------------------------------------------------------------------
# host-side epoch transitions (apply / compact / materialize / rebuild)
# ---------------------------------------------------------------------------
def ensure_live(engine, delta_capacity: int = 1024):
    """Give `engine` an (empty) delta shard + alive mask if it has none.

    The treedef changes once here (None -> arrays), so jitted serve steps
    compile once for the live layout and never again across updates or
    epochs (as long as the base table does not grow). Engines built
    outside `RecSysEngine.build` (no block summary yet) also get one here,
    so every live catalog can prune its base scans.
    """
    if engine.delta is not None:
        return engine
    n, d = engine.item_table_q.shape
    words = engine.item_sigs.shape[1]
    summary = engine.block_summary
    if summary is None:
        summary = build_block_summary(
            np.asarray(engine.item_sigs), n_valid=n)
    return dataclasses.replace(
        engine,
        delta=empty_delta(delta_capacity, d, words),
        block_summary=summary,
        item_mask=jnp.ones((engine.item_sigs.shape[0],), jnp.bool_)
        .at[n:].set(False))  # shard-padding rows stay dead


def quantize_updates(engine, rows: jax.Array):
    """f32 rows (m, d) -> (int8 values, scales, packed sigs) — the exact
    build-time transform (`RecSysEngine.build`), applied per row."""
    q = quantize_rowwise(jnp.asarray(rows, jnp.float32))
    sigs = lsh_signature(dequantize_rowwise(q), engine.lsh_proj)
    return (np.asarray(q.values), np.asarray(q.scales), np.asarray(sigs))


def engine_apply_updates(engine, upsert_ids=None, upsert_rows=None,
                         delete_ids=None):
    """Fold a batch of updates into the engine's delta shard (host-side).

    upsert_ids/upsert_rows: (m,) int ids + (m, d) f32 embeddings — new ids
    extend the catalog, existing ids re-embed (base row tombstoned, row
    rides the delta until the next compaction). delete_ids: (k,) ids to
    retire (tombstoned everywhere; delete-then-re-add round-trips through
    the delta). Later entries win within one batch. Touched ids are evicted
    from the hot-row cache. Raises `DeltaFullError` when the surviving
    update set does not fit the bounded shard — `LiveCatalog` turns that
    into a forced compaction.

    Returns a new engine (the old epoch view stays valid — MVCC).
    """
    if engine.delta is None:
        raise ValueError("engine has no delta shard; wrap it in "
                         "LiveCatalog or call ensure_live() first")
    delta = engine.delta
    n_base = int(engine.item_table_q.shape[0])

    live: dict[int, tuple] = {}
    ids_np = np.asarray(delta.ids)
    vals_np, scales_np, sigs_np = (np.asarray(delta.values),
                                   np.asarray(delta.scales),
                                   np.asarray(delta.sigs))
    for slot in np.nonzero(ids_np != EMPTY_ID)[0]:
        live[int(ids_np[slot])] = (vals_np[slot], scales_np[slot],
                                   sigs_np[slot])

    mask = np.asarray(engine.item_mask).copy()
    touched: list[int] = []
    if delete_ids is not None:
        for gid in np.asarray(delete_ids, np.int64).reshape(-1):
            gid = int(gid)
            live.pop(gid, None)
            if gid < n_base:
                mask[gid] = False
            touched.append(gid)
    if upsert_ids is not None:
        ids_arr = np.asarray(upsert_ids, np.int64).reshape(-1)
        if np.any(ids_arr < 0) or np.any(ids_arr >= EMPTY_ID):
            raise ValueError(f"item ids must be in [0, {EMPTY_ID})")
        uvals, uscales, usigs = quantize_updates(engine, upsert_rows)
        if len(ids_arr) != len(uvals):
            raise ValueError(f"{len(ids_arr)} ids vs {len(uvals)} rows")
        for i, gid in enumerate(ids_arr):
            gid = int(gid)
            live[gid] = (uvals[i], uscales[i], usigs[i])
            if gid < n_base:
                mask[gid] = False  # base row stale; delta row is the truth
            touched.append(gid)

    if len(live) > delta.capacity:
        raise DeltaFullError(
            f"{len(live)} pending rows > delta capacity {delta.capacity}")

    # keep the block summary sound AND tight: every touched base row's
    # block is recomputed exactly against the new tombstone mask (an
    # incremental OR/AND could only loosen; a stale summary that still
    # counts a tombstoned row is sound but must not survive compaction
    # comparisons — see update_block_summary)
    summary = engine.block_summary
    base_touched = [g for g in touched if g < n_base]
    if summary is not None and base_touched:
        summary = update_block_summary(
            summary, np.asarray(engine.item_sigs), mask, base_touched)

    new = empty_delta(delta.capacity, vals_np.shape[1], sigs_np.shape[1])
    ids_out = np.full(delta.capacity, EMPTY_ID, np.int32)
    vals_out = np.asarray(new.values).copy()
    scales_out = np.asarray(new.scales).copy()
    sigs_out = np.asarray(new.sigs).copy()
    for slot, gid in enumerate(sorted(live)):  # ascending-id prefix
        v, s, g = live[gid]
        ids_out[slot], vals_out[slot] = gid, v
        scales_out[slot], sigs_out[slot] = s, g
    return dataclasses.replace(
        engine,
        delta=DeltaShard(ids=jnp.asarray(ids_out),
                         values=jnp.asarray(vals_out),
                         scales=jnp.asarray(scales_out),
                         sigs=jnp.asarray(sigs_out),
                         capacity=delta.capacity),
        item_mask=jnp.asarray(mask),
        block_summary=summary,
        item_hot=invalidate_rows(engine.item_hot, np.asarray(touched)))


def engine_refresh_model(engine, params):
    """New engine serving the *current* model parameters (host-side).

    The online-learning counterpart of `quantize_updates` for everything
    that is NOT the item table: the filtering/ranking MLPs and the genre
    table swap in directly, the user-feature ETs re-quantize with the
    exact build-time transform, and every pinned UIET hot row is re-pinned
    from its new quantized table (a stale pinned row would change served
    bits vs a cold rebuild — the hot tier must stay bit-transparent).

    Item rows are deliberately untouched: they flow through the delta
    shard (`quantize_updates` via `LiveCatalog.upsert`), which is what
    keeps the base epoch read-only and the MVCC swap atomic. The engine's
    treedef and every leaf shape are preserved, so jitted serve steps
    never retrace across a refresh.
    """
    tables_q = {k: quantize_rowwise(v) for k, v in params["tables"].items()}
    uiet_hot = {}
    for name, cache in engine.uiet_hot.items():
        if cache is not None and cache.capacity:
            ids = np.asarray(cache.hot_ids)
            uiet_hot[name] = pin_rows(tables_q[name], ids[ids != EMPTY_ID],
                                      cache.capacity)
        else:
            uiet_hot[name] = cache
    return dataclasses.replace(
        engine, params=params, tables_q=tables_q,
        genre_table_q=quantize_rowwise(params["genre_table"]),
        uiet_hot=uiet_hot)


def materialize(engine):
    """Fold base + delta into one flat table (the \"final table\").

    Returns (QuantizedTensor (n_total, d), sigs (n_total, words) uint32,
    alive (n_total,) bool numpy) — n_total covers every id ever upserted.
    Rows never touched keep their exact base bytes; delta rows scatter in
    verbatim; id-space gaps (never-written ids below a larger upserted id)
    get the canonical zero-row quantization and stay dead. This is both
    the compaction scatter and the reference-rebuild input, so the two are
    bitwise the same table by construction.
    """
    n_base, d = engine.item_table_q.shape
    words = engine.item_sigs.shape[1]
    ids_np = np.asarray(engine.delta.ids) if engine.delta is not None else \
        np.zeros((0,), np.int32)
    live = np.nonzero(ids_np != EMPTY_ID)[0]
    gids = ids_np[live].astype(np.int64)
    n_total = int(max(n_base, (gids.max() + 1) if len(gids) else 0))

    zero_q = quantize_rowwise(jnp.zeros((1, d), jnp.float32))
    zero_sig = lsh_signature(dequantize_rowwise(zero_q), engine.lsh_proj)
    values = np.broadcast_to(np.asarray(zero_q.values),
                             (n_total, d)).copy()
    scales = np.broadcast_to(np.asarray(zero_q.scales),
                             (n_total, 1)).copy()
    sigs = np.broadcast_to(np.asarray(zero_sig), (n_total, words)).copy()
    values[:n_base] = np.asarray(engine.item_table_q.values)
    scales[:n_base] = np.asarray(engine.item_table_q.scales)
    sigs[:n_base] = np.asarray(engine.item_sigs)[:n_base]

    alive = np.zeros((n_total,), bool)
    if engine.item_mask is not None:
        alive[:n_base] = np.asarray(engine.item_mask)[:n_base]
    else:
        alive[:n_base] = True
    if len(live):
        values[gids] = np.asarray(engine.delta.values)[live]
        scales[gids] = np.asarray(engine.delta.scales)[live]
        sigs[gids] = np.asarray(engine.delta.sigs)[live]
        alive[gids] = True
    table = QuantizedTensor(values=jnp.asarray(values),
                            scales=jnp.asarray(scales))
    return table, jnp.asarray(sigs), alive


def compact_engine(engine):
    """Fold the delta into a fresh base epoch; returns the new engine.

    One host-side scatter (`materialize`) + an empty delta: the old engine
    object — and every device buffer an in-flight bucket was dispatched
    against — stays fully valid, so callers swap epochs atomically between
    buckets. The hot cache carries over untouched: every surviving pinned
    row's backing bytes are identical in the new base (touched rows were
    already evicted at update time). A sharded engine is re-sharded onto
    its mesh after the fold.
    """
    if engine.delta is None:
        raise ValueError("engine has no delta shard to compact")
    table, sigs, alive = materialize(engine)
    d, words = table.shape[1], sigs.shape[1]
    br = (engine.block_summary.block_rows if engine.block_summary is not None
          else SUMMARY_BLOCK_ROWS)
    out = dataclasses.replace(
        engine,
        item_table_q=table, item_sigs=sigs,
        item_mask=jnp.asarray(alive),
        # fresh epoch, fresh summary: cold-built over the materialized
        # table + alive mask (the rebuild_reference summary by definition)
        block_summary=build_block_summary(np.asarray(sigs), br,
                                          db_mask=alive),
        delta=empty_delta(engine.delta.capacity, d, words),
        nns_mesh=None, nns_axis=None, nns_query_axis=None)
    if engine.nns_mesh is not None and (engine.nns_axis is not None
                                        or engine.nns_query_axis is not None):
        out = out.shard(engine.nns_mesh, engine.nns_axis,
                        query_axis=engine.nns_query_axis)
    return out


def rebuild_reference(engine):
    """A from-scratch frozen engine over the live engine's final table.

    The bit-match oracle: base/sigs/mask come from `materialize` (never
    from the incremental delta path), the delta is empty, and the hot
    cache pins exactly the live cache's surviving hot set — so `serve`
    on the reference must equal `serve` on the live engine bit for bit,
    counters included. Always unsharded (execution plans are separately
    proven result-invariant).
    """
    table, sigs, alive = materialize(engine)
    d, words = table.shape[1], sigs.shape[1]
    cap = engine.item_hot.capacity
    if cap:
        hot = np.asarray(engine.item_hot.hot_ids)
        item_hot = pin_rows(table, hot[hot != EMPTY_ID], cap)
    else:
        item_hot = engine.item_hot
    capacity = engine.delta.capacity if engine.delta is not None else 0
    br = (engine.block_summary.block_rows if engine.block_summary is not None
          else SUMMARY_BLOCK_ROWS)
    return dataclasses.replace(
        engine,
        item_table_q=table, item_sigs=sigs, item_mask=jnp.asarray(alive),
        block_summary=build_block_summary(np.asarray(sigs), br,
                                          db_mask=alive),
        item_hot=item_hot, delta=empty_delta(capacity, d, words),
        nns_mesh=None, nns_axis=None, nns_query_axis=None)


def repin_hot_from_freqs(engine, freqs):
    """Refill the item hot cache from measured lookup frequencies.

    Pins the `capacity` most-looked-up alive base rows (ties broken by
    ascending id — `top_ids_by_freq`, the one tier-selection order).
    Pending delta ids are never pinned: the delta-resolution contract
    requires delta ∩ hot = ∅, and their bytes live in the shard, not the
    base table. Called after `compact()` (delta empty, every surviving row
    in the new base) this restores hit rates that churn eviction decayed —
    the previously open hot-cache-repinning item. Serving results are
    unchanged by construction (the cache is bit-transparent); only the
    hit counters move.
    """
    cache = engine.item_hot
    if cache is None or not cache.capacity:
        return engine
    n = int(engine.item_table_q.shape[0])
    f = np.zeros((n,), np.int64)
    m = min(len(freqs), n)
    f[:m] = np.asarray(freqs)[:m]
    alive = (np.ones((n,), bool) if engine.item_mask is None
             else np.asarray(engine.item_mask)[:n].copy())
    if engine.delta is not None:
        dids = np.asarray(engine.delta.ids)
        dids = dids[dids != EMPTY_ID]
        alive[dids[dids < n]] = False
    ids = top_ids_by_freq(f, cache.capacity, eligible=alive)
    return dataclasses.replace(
        engine, item_hot=pin_rows(engine.item_table_q, ids, cache.capacity))


# ---------------------------------------------------------------------------
# the subsystem front door
# ---------------------------------------------------------------------------
class LiveCatalog:
    """Versioned item catalog over a serving engine.

    Wraps a `RecSysEngine` with the mutable-catalog lifecycle: bounded
    delta ingestion (`upsert` / `delete`), epoch compaction (`compact`,
    auto-forced when the delta fills), atomic engine publication to
    attached servers (`attach` — in-flight `AsyncServer` buckets finish on
    the epoch they were dispatched against), and epoch-numbered
    snapshot/restore through the fault-tolerant checkpointer.

    The engine exposed by `.engine` is always safe to serve: updates and
    compactions build a *new* engine value and swap it in; nothing an
    already-dispatched bucket references is ever mutated.
    """

    def __init__(self, engine, *, delta_capacity: int = 1024,
                 auto_compact: bool = True, registry=None):
        self.engine = ensure_live(engine, delta_capacity)
        self.epoch = 0
        self.auto_compact = auto_compact
        self.n_upserts = 0
        self.n_deletes = 0
        self.n_compactions = 0
        self.last_compact_s = 0.0
        self._servers: list = []
        # measured per-row lookup frequencies (serve-path observations):
        # grown on demand past the base size as new ids are upserted
        self.item_freqs = np.zeros(
            (int(self.engine.item_table_q.shape[0]),), np.int64)
        self.n_observed = 0
        # telemetry sink (repro.obs.MetricsRegistry); when None, `attach`
        # adopts the first attached server's registry so one snapshot
        # covers serving + catalog
        self.registry = None
        if registry is not None:
            self._set_registry(registry)

    def _set_registry(self, registry) -> None:
        if self.registry is None and registry is not None:
            self.registry = registry
            registry.register_collector(self._collect)

    def _collect(self, reg) -> None:
        """Snapshot-time collector: catalog lifecycle counters + the
        delta-overlay occupancy, as `catalog.*` gauges."""
        reg.gauge("catalog.epoch", self.epoch)
        reg.gauge("catalog.upserts", self.n_upserts)
        reg.gauge("catalog.deletes", self.n_deletes)
        reg.gauge("catalog.compactions", self.n_compactions)
        reg.gauge("catalog.delta_pending", self.n_pending)
        reg.gauge("catalog.delta_capacity", self.delta_capacity)
        reg.gauge("catalog.observed_lookups", self.n_observed)
        reg.gauge("catalog.last_compact_s", self.last_compact_s)

    # -- publication ---------------------------------------------------
    def attach(self, server) -> None:
        """Publish every future epoch/update swap to `server`
        (a `MicroBatcher` / `AsyncServer`). Servers exposing an `observer`
        hook also feed this catalog's per-row lookup-frequency counters
        (every valid item id a served batch looked up — history rows and
        served candidates alike), which `compact()` uses to repin the hot
        cache. A catalog built without a registry adopts the first
        attached server's, so its `catalog.*` gauges and compaction
        events ride the server's `snapshot()`."""
        self._servers.append(server)
        if hasattr(server, "observer"):
            server.observer = self.observe
        self._set_registry(getattr(server, "registry", None))
        server.swap_engine(self.engine)

    # -- frequency observation -----------------------------------------
    def observe(self, ids) -> None:
        """Count serve-path item lookups: `ids` is any int array of item
        ids; negative (padding) and sentinel ids are ignored. Purely a
        host-side counter — serving results never depend on it."""
        ids = np.asarray(ids).reshape(-1)
        ids = ids[(ids >= 0) & (ids < EMPTY_ID)]
        if not ids.size:
            return
        hi = int(ids.max()) + 1
        if hi > self.item_freqs.shape[0]:
            grown = np.zeros((hi,), np.int64)
            grown[: self.item_freqs.shape[0]] = self.item_freqs
            self.item_freqs = grown
        np.add.at(self.item_freqs, ids, 1)
        self.n_observed += int(ids.size)

    def _publish(self) -> None:
        if self.registry is not None:
            self.registry.event("publish", epoch=self.epoch,
                                delta_pending=self.n_pending)
        for server in self._servers:
            server.swap_engine(self.engine)

    # -- mutation ------------------------------------------------------
    def apply_updates(self, upsert_ids=None, upsert_rows=None,
                      delete_ids=None) -> None:
        """Apply one update batch; forces a compaction when the delta is
        full (unless `auto_compact=False`, which re-raises
        `DeltaFullError`)."""
        try:
            engine = engine_apply_updates(self.engine, upsert_ids,
                                          upsert_rows, delete_ids)
        except DeltaFullError:
            if not self.auto_compact:
                raise
            self.compact()
            engine = engine_apply_updates(self.engine, upsert_ids,
                                          upsert_rows, delete_ids)
        self.engine = engine
        if upsert_ids is not None:
            self.n_upserts += len(np.asarray(upsert_ids).reshape(-1))
        if delete_ids is not None:
            self.n_deletes += len(np.asarray(delete_ids).reshape(-1))
        self._publish()

    def upsert(self, ids, rows) -> None:
        """Add or re-embed items: (m,) ids + (m, d) f32 embeddings."""
        self.apply_updates(upsert_ids=ids, upsert_rows=rows)

    def delete(self, ids) -> None:
        """Retire items: tombstoned out of retrieval immediately."""
        self.apply_updates(delete_ids=ids)

    def refresh_model(self, params) -> None:
        """Publish the current model parameters (MLPs, UIETs, genre table)
        to every attached server — the dense-parameter half of online
        learning (`engine_refresh_model`); item-embedding updates take the
        `upsert` path instead. Atomic like every other publication: a new
        engine value swaps in between drain chunks."""
        self.engine = engine_refresh_model(self.engine, params)
        self._publish()

    def compact(self) -> float:
        """Fold the delta into a new base epoch; returns the pause in
        seconds (the fold is synchronous host work; serves issued against
        the previous epoch keep running on their own buffers)."""
        t0 = time.perf_counter()
        engine = compact_engine(self.engine)
        if self.n_observed:
            # tier migration rides the epoch fold: measured frequencies
            # refill hot slots that churn eviction emptied (delta is empty
            # here, so every surviving row is pinnable from the new base)
            engine = repin_hot_from_freqs(engine, self.item_freqs)
        jax.block_until_ready((engine.item_table_q.values, engine.item_sigs))
        self.last_compact_s = time.perf_counter() - t0
        self.engine = engine
        self.epoch += 1
        self.n_compactions += 1
        if self.registry is not None:
            self.registry.observe("catalog.compact_pause_s",
                                  self.last_compact_s)
            self.registry.event("compact", epoch=self.epoch,
                                pause_s=self.last_compact_s,
                                n_items=self.n_items)
        self._publish()
        return self.last_compact_s

    # -- introspection -------------------------------------------------
    @property
    def n_pending(self) -> int:
        """Occupied delta slots awaiting compaction."""
        return delta_n_live(self.engine.delta)

    @property
    def delta_capacity(self) -> int:
        return self.engine.delta.capacity

    @property
    def n_items(self) -> int:
        """Alive catalog size (base + delta - tombstones).

        O(n) over the mask, no materialization: the base-alive and
        live-delta id sets are disjoint (overwritten base rows are
        tombstoned), so the counts simply add.
        """
        n_base = int(self.engine.item_table_q.shape[0])
        alive = int(np.asarray(self.engine.item_mask)[:n_base].sum())
        return alive + delta_n_live(self.engine.delta)

    def rebuild_reference(self):
        """Frozen from-scratch engine over the current final table (the
        bit-match oracle for tests and benchmarks)."""
        return rebuild_reference(self.engine)

    # -- persistence ---------------------------------------------------
    def snapshot(self, directory) -> None:
        """Atomic epoch-numbered snapshot of the full engine pytree
        (base epoch + delta shard + tombstones + hot caches), via the
        fault-tolerant checkpointer (`checkpoint/checkpointer.py`)."""
        from repro.checkpoint import checkpointer

        checkpointer.save(directory, self.epoch, self.engine)

    def restore(self, directory) -> None:
        """Restore the latest committed epoch snapshot into this catalog
        (the current engine is the structural template: same table/delta
        shapes). Published to attached servers."""
        from repro.checkpoint import checkpointer

        step = checkpointer.latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed snapshot in {directory}")
        self.engine = checkpointer.restore(directory, step, self.engine)
        self.epoch = step
        self._publish()
