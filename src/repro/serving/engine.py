"""Prefill / decode steps + a batched serving engine.

serve_step contract for the dry-run shapes:
  prefill_32k  — `prefill` lowered with (B, S) token inputs, producing the
                 full KV cache + last-position logits.
  decode_32k / long_500k — `decode_step` lowered with a KV cache of
                 `seq_len` as input and one new token per sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serving.kv_cache import init_cache


def prefill(params, cfg: ModelConfig, batch: dict, *, cache_len: int,
            cache_dtype: str = "bfloat16", remat: str = "none",
            attn_impl: str = "blocked") -> tf.ModelOutput:
    """Process a prompt batch; returns last-token logits + a filled cache."""
    if cfg.family in ("ssm", "hybrid"):
        # recurrent states are produced by the scan itself
        out = tf.forward(params, cfg, batch, mode="prefill",
                         cache_len=cache_len, cache_dtype=cache_dtype,
                         remat=remat, attn_impl=attn_impl,
                         logits_mode="last")
        return out
    return tf.forward(params, cfg, batch, mode="prefill",
                      cache_len=cache_len, cache_dtype=cache_dtype,
                      remat=remat, attn_impl=attn_impl, logits_mode="last")


def decode_step(params, cfg: ModelConfig, batch: dict, caches: Any,
                cache_index: jax.Array, *, attn_impl: str = "blocked"
                ) -> tf.ModelOutput:
    """One token per sequence against an existing cache."""
    return tf.forward(params, cfg, batch, mode="decode", caches=caches,
                      cache_index=cache_index, attn_impl=attn_impl,
                      logits_mode="all")


# ---------------------------------------------------------------------------
# Batched generation engine (continuous-batching-lite)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, n_generated)


class LMServingEngine:
    """Synchronous batched engine: prefill once, greedy-decode n steps.

    Slot-based continuous batching: finished sequences' slots are refilled
    from the pending queue between decode steps (host-side bookkeeping; the
    device step is shape-stable).
    """

    def __init__(self, params, cfg: ModelConfig, *, batch: int,
                 cache_len: int, cache_dtype: str = "bfloat16"):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.cache_len = cache_len
        self.cache_dtype = cache_dtype
        self._decode = jax.jit(
            lambda p, b, c, i: decode_step(p, cfg, b, c, i))

    def generate(self, prompt_batch: dict, n_steps: int) -> GenerationResult:
        cfg = self.cfg
        prompt_len = prompt_batch["tokens"].shape[-1]
        out = prefill(self.params, cfg, prompt_batch,
                      cache_len=self.cache_len, cache_dtype=self.cache_dtype)
        caches = out.caches
        tok = jnp.argmax(out.logits[:, -1], axis=-1)  # greedy
        toks = [np.asarray(tok)]
        index = jnp.int32(prompt_len)
        for _ in range(n_steps - 1):
            if cfg.family == "audio":
                step_tokens = tok.reshape(-1, cfg.n_codebooks, 1)
            else:
                step_tokens = tok[:, None]
            out = self._decode(self.params, {"tokens": step_tokens}, caches,
                               index)
            caches = out.caches
            logits = out.logits[:, -1]
            tok = jnp.argmax(logits, axis=-1)
            if cfg.family == "audio":
                tok = tok.reshape(tok.shape[0], -1)[:, : cfg.n_codebooks]
            toks.append(np.asarray(tok))
            index = index + 1
        return GenerationResult(tokens=np.stack(toks, axis=-1))
