"""Concurrent multi-tenant serving tier: bounded queues + a drain thread.

The `AsyncServer` ring overlaps host batching with device compute, but it
is still a *closed-loop* front-end: one caller, one unbounded queue, and a
flush that admits everything ever submitted. A datacenter-shaped serving
tier (the "scale-in" observation: RecSys deployments lose their
accelerator wins in the serving tier, not the kernels) needs the opposite
discipline under open-loop load:

  * **per-tenant bounded queues** — each tenant (product surface, shard,
    or customer) owns a FIFO of at most ``queue_depth`` waiting queries,
    so one tenant's burst cannot grow another tenant's latency without
    bound;
  * **admission control / load shedding** — a submit against a full
    tenant queue is rejected *immediately* with a ``status="shed"``
    ticket (accounted per tenant in `stats()`), trading goodput for a
    bounded p99 instead of collapsing into unbounded queueing latency;
  * **a single drain thread** — queries are collected round-robin across
    tenant queues into engine-shaped chunks and served through an inner
    `AsyncServer` ring (per-shard dispatch: on a query-mesh engine the
    ring's coalescing lands concurrent buckets on disjoint query blocks).
    One thread owns every JAX call, so device work stays single-writer
    while submits stay lock-cheap and thread-safe;
  * **typed failure containment** — a `ServingError` raised while
    draining (e.g. a schema-mismatched epoch swap) resolves the affected
    tickets as ``status="error"`` and the thread keeps draining; nothing
    in the overload path can kill it.

Bit-for-bit contract (tests/test_server_protocol.py): the admitted stream
serves byte-identically to the synchronous `MicroBatcher` given the same
engine — per-query results are independent of bucket composition, so
threading, interleaving, and shedding move *time and admission*, never
the bits of an admitted result.

Open-loop measurement hooks: every ticket is timestamped at submit and at
resolve; `take_trace()` hands `repro.obs.TicketTrace` records — (ticket,
tenant, submit_s, done_s, status, stages) — to the load harness
(`serving/load_gen.py`), which turns them into per-tenant p50/p99 latency
and shed accounting. With ``trace=True`` every record (including shed and
error tickets) carries a stage-span chain: the outer submit/admit stamps,
the inner ring's bucket/dispatch/scan/rank stamps, and the outer resolve
— so queue wait shows up as the admit -> bucket gap (docs/OBSERVABILITY.md).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Sequence

import numpy as np

from repro.obs import MetricsRegistry, TicketTrace
from repro.serving.async_server import AsyncServer
from repro.serving.batcher import TRACE_CAP, ServedQuery
from repro.serving.recsys_engine import RecSysEngine
from repro.serving.server import (
    STATUS_ERROR,
    STATUS_OK,
    STATUS_SHED,
    QueueFullError,
    ServerClosedError,
    ServerConfigError,
    ServingError,
    stats_view,
)

# the stages of an inner span chain the outer ticket inherits (the inner
# submit/admit/resolve stamps are replaced by the outer ticket's own)
_INNER_STAGES = frozenset(("bucket", "dispatch", "scan", "rank"))


class ConcurrentFrontend:
    """Threaded multi-tenant front-end over an inner `AsyncServer` ring.

    Conforms to the unified `Server` protocol (serving/server.py);
    construct via ``make_server(engine, mode="concurrent", ...)``.

    Args:
      engine: the serving engine (local or sharded).
      tenants: tenant count; tenant ids are ``0..tenants-1``.
      queue_depth: max waiting queries per tenant queue; a submit beyond
        it is shed (``None`` = unbounded, never sheds).
      max_batch / buckets / depth / coalesce: inner `AsyncServer` knobs.
      drain_chunk: max queries the drain thread collects per cycle
        (default ``max_batch * depth * coalesce`` — enough to keep the
        ring full).
      shed: when False, a full queue raises `QueueFullError` at submit
        instead of resolving the ticket as shed (closed-loop callers).
      autostart: start the drain thread at construction (tests pass
        False to stage deterministic overloads, then call `start()`).
      trace / registry: stage-span tracing + the shared telemetry
        registry (repro.obs); the inner ring shares the registry, so one
        `snapshot()` covers the whole front-end.
    """

    mode = "concurrent"

    def __init__(self, engine: RecSysEngine, *, tenants: int = 1,
                 queue_depth: int | None = 256, max_batch: int = 256,
                 buckets: Sequence[int] | None = None, depth: int = 2,
                 coalesce: int | None = None, drain_chunk: int | None = None,
                 shed: bool = True, autostart: bool = True,
                 trace: bool = True,
                 registry: MetricsRegistry | None = None):
        if tenants < 1:
            raise ServerConfigError(f"tenants must be >= 1, got {tenants}")
        if queue_depth is not None and queue_depth < 1:
            raise ServerConfigError(
                f"queue_depth must be >= 1 or None, got {queue_depth}")
        self.trace = bool(trace)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._inner = AsyncServer(engine, max_batch=max_batch,
                                  buckets=buckets, depth=depth,
                                  coalesce=coalesce, trace=trace,
                                  registry=self.registry)
        # registered after the inner collector, so the outer view of the
        # shared gauges (submitted/shed/errors/pending/per_tenant) wins
        self.registry.register_collector(self._collect)
        self.tenants = tuple(range(tenants))
        self.queue_depth = queue_depth
        self.shed = shed
        self.drain_chunk = (drain_chunk if drain_chunk is not None else
                            max_batch * depth * self._inner.coalesce)
        if self.drain_chunk < 1:
            raise ServerConfigError(
                f"drain_chunk must be >= 1, got {self.drain_chunk}")

        self._cv = threading.Condition()
        self._serve_lock = threading.Lock()  # inner server / engine swaps
        self._queues: dict[int, deque] = {t: deque() for t in self.tenants}
        self._per_tenant = {t: {"submitted": 0, "served": 0, "shed": 0,
                                "errors": 0} for t in self.tenants}
        self._results: dict[int, ServedQuery] = {}
        self._outstanding: set[int] = set()
        self._trace: list[TicketTrace] = []
        self.n_trace_dropped = 0
        self._next_ticket = 0
        self._n_inflight = 0  # collected from queues, not yet resolved
        self._rr = 0  # round-robin start tenant for the next collect
        self._closed = False
        self._started = False
        self._last_error: str | None = None
        self._thread = threading.Thread(target=self._drain_loop,
                                        name="serving-drain", daemon=True)
        if autostart:
            self.start()

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def submit(self, query: dict, *, tenant: int = 0) -> int:
        """Admit (or shed) one query into `tenant`'s bounded queue.

        Thread-safe; never blocks on the drain thread. Returns a ticket —
        shed submissions get a ticket too, already resolved with
        ``status="shed"``, so accounting and redemption stay uniform.
        """
        with self._cv:
            if self._closed:
                raise ServerClosedError("submit() on a closed server")
            if tenant not in self._queues:
                raise ServerConfigError(
                    f"unknown tenant {tenant!r}; configured: {self.tenants}")
            ticket = self._next_ticket
            self._next_ticket += 1
            self._outstanding.add(ticket)
            self._per_tenant[tenant]["submitted"] += 1
            now = time.perf_counter()
            q = self._queues[tenant]
            if self.queue_depth is not None and len(q) >= self.queue_depth:
                if not self.shed:
                    self._outstanding.discard(ticket)
                    self._per_tenant[tenant]["submitted"] -= 1
                    raise QueueFullError(
                        f"tenant {tenant} queue at depth {len(q)}")
                self._per_tenant[tenant]["shed"] += 1
                stages = ((("submit", now), ("admit", now),
                           ("resolve", now)) if self.trace else ())
                self._results[ticket] = self._sentinel(
                    tenant, STATUS_SHED, stages)
                self._record_trace(TicketTrace(ticket, tenant, now, now,
                                               STATUS_SHED, stages))
                self._cv.notify_all()
                return ticket
            q.append((ticket, tenant, query, now))
            self._cv.notify_all()  # wake the drain thread
            return ticket

    def _sentinel(self, tenant: int, status: str,
                  stages: tuple = ()) -> ServedQuery:
        k = self._inner.engine.top_k
        return ServedQuery(items=np.full(k, -1, np.int32),
                           scores=np.zeros(k, np.float32),
                           status=status, tenant=tenant, stages=stages)

    def _record_trace(self, rec: TicketTrace) -> None:
        """Append under `_cv` (held by every caller); capped like the
        single-tenant front-ends so an unharvested trace can't grow
        without bound between `take_trace()` calls."""
        if len(self._trace) >= TRACE_CAP:
            self.n_trace_dropped += 1
            return
        self._trace.append(rec)

    # ------------------------------------------------------------------
    # redemption / draining
    # ------------------------------------------------------------------
    def result(self, ticket: int, *,
               timeout: float | None = None) -> ServedQuery:
        """Block until `ticket` resolves; pops it (redeem exactly once)."""
        with self._cv:
            if ticket not in self._outstanding:
                raise KeyError(f"ticket {ticket} unknown or already redeemed")
            if not self._cv.wait_for(lambda: ticket in self._results,
                                     timeout=timeout):
                raise TimeoutError(f"ticket {ticket} unresolved after "
                                   f"{timeout}s")
            self._outstanding.discard(ticket)
            return self._results.pop(ticket)

    def serve_many(self, queries: Sequence[dict], *,
                   tenant: int = 0) -> list[ServedQuery]:
        """Submit, flush, and collect, in submission order (shed tickets
        come back as ``status="shed"`` sentinels, not exceptions)."""
        tickets = [self.submit(q, tenant=tenant) for q in queries]
        self.flush()
        return [self.result(t) for t in tickets]

    def start(self) -> None:
        """Start the drain thread (no-op if already running)."""
        with self._cv:
            if self._started:
                return
            self._started = True
        self._thread.start()

    def flush(self) -> None:
        """Block until every admitted query has resolved its ticket."""
        self.start()
        with self._cv:
            self._cv.wait_for(
                lambda: self._n_queued() == 0 and self._n_inflight == 0)

    def close(self) -> None:
        """Drain everything admitted, then stop; idempotent, no deadlock.

        In-flight and queued tickets are resolved (served, not shed)
        before the drain thread exits; they stay redeemable afterwards.
        `submit()` raises `ServerClosedError` once close() begins.
        """
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self.start()  # a never-started frontend still drains its queues
        self._thread.join(timeout=120)
        if self._thread.is_alive():  # pragma: no cover - defensive
            raise ServingError("drain thread failed to stop within 120s")
        with self._serve_lock:
            self._inner.close()

    def _n_queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _collect_locked(self, limit: int) -> list:
        """Round-robin up to `limit` queued entries across tenant queues.

        Fair interleave: one query per non-empty tenant per cycle, so a
        backlogged tenant cannot starve the others between drains.
        """
        batch: list = []
        n = len(self.tenants)
        while len(batch) < limit:
            took = False
            for k in range(n):
                if len(batch) >= limit:
                    break
                q = self._queues[self.tenants[(self._rr + k) % n]]
                if q:
                    batch.append(q.popleft())
                    took = True
            if not took:
                break
        self._rr = (self._rr + 1) % n
        return batch

    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                self._cv.wait_for(
                    lambda: self._closed or self._n_queued() > 0)
                batch = self._collect_locked(self.drain_chunk)
                if not batch:
                    if self._closed:
                        return
                    continue  # pragma: no cover - spurious wakeup
                self._n_inflight += len(batch)
            served = None
            try:
                with self._serve_lock:
                    tickets = [self._inner.submit(q)
                               for (_, _, q, _) in batch]
                    self._inner.flush()
                    served = [self._inner.result(t) for t in tickets]
                    # the outer ticket is the unit of tracing: its span
                    # chain absorbs the inner stamps below, so drop the
                    # inner ring's duplicate trace records
                    self._inner.take_trace()
            except ServingError as e:
                self._contain(e)  # typed: surface through the tickets
            except Exception as e:  # defensive: the thread must survive
                self._contain(e)
            done = time.perf_counter()
            with self._cv:
                for i, (ticket, tenant, _, t_sub) in enumerate(batch):
                    if served is not None:
                        status = STATUS_OK
                        chain = self._chain(t_sub, done,
                                            served[i].stages)
                        self._results[ticket] = dataclasses.replace(
                            served[i], tenant=tenant, stages=chain)
                        self._per_tenant[tenant]["served"] += 1
                    else:
                        status = STATUS_ERROR
                        chain = self._chain(t_sub, done, ())
                        self._results[ticket] = self._sentinel(
                            tenant, STATUS_ERROR, chain)
                        self._per_tenant[tenant]["errors"] += 1
                    self._record_trace(TicketTrace(
                        ticket, tenant, t_sub, done, status, chain))
                    if self.trace:
                        self.registry.observe("serving.e2e_latency_s",
                                              done - t_sub)
                self._n_inflight -= len(batch)
                self._cv.notify_all()

    def _chain(self, t_sub: float, done: float, inner: tuple) -> tuple:
        """The outer ticket's span chain: outer submit/admit stamps, the
        inner ring's bucket/dispatch/scan/rank stamps (queue wait is the
        admit -> bucket gap), and the outer resolve. Error tickets carry
        the degenerate submit -> admit -> resolve chain."""
        if not self.trace:
            return ()
        mid = tuple((s, t) for s, t in inner if s in _INNER_STAGES)
        return (("submit", t_sub), ("admit", t_sub), *mid,
                ("resolve", done))

    def _contain(self, exc: Exception) -> None:
        """Reset the inner server after a drain failure (tickets resolve
        as ``status="error"``; the thread keeps serving later chunks)."""
        self._last_error = f"{type(exc).__name__}: {exc}"
        with self._serve_lock:
            self._inner._pending = []
            self._inner._ring.clear()
            self._inner._results.clear()
            spans = getattr(self._inner, "_spans", None)
            if spans is not None:  # tests inject span-less fake inners
                spans.clear()

    # ------------------------------------------------------------------
    # engine swaps / stats / trace
    # ------------------------------------------------------------------
    @property
    def engine(self):
        return self._inner.engine

    def swap_engine(self, engine: RecSysEngine) -> None:
        """Epoch swap between drain chunks (LiveCatalog publication point).

        Serializes against the drain thread: the swap lands between inner
        flushes, so a chunk is always entirely one epoch. A schema change
        raises `SchemaMismatchError` to the *caller*; the drain thread is
        untouched.
        """
        with self._serve_lock:
            self._inner.swap_engine(engine)

    def take_trace(self) -> list[TicketTrace]:
        """Return and clear the completed-ticket trace (load harness /
        `tools/obs_report.py`); one record per submitted ticket, each
        carrying its span chain when the server traces."""
        with self._cv:
            out, self._trace = self._trace, []
            return out

    def _collect(self, reg: MetricsRegistry) -> None:
        """Snapshot-time collector for the multi-tenant accounting; runs
        after the inner ring's collector on the shared registry, so the
        outer view of submitted/shed/errors/pending/per_tenant wins.
        `Condition` wraps an RLock, so taking `_cv` here is safe even
        when `snapshot()` is called under it."""
        with self._cv:
            per_tenant = {t: dict(v) for t, v in self._per_tenant.items()}
            reg.info("serving.mode", self.mode)
            reg.info("serving.closed", self._closed)
            reg.gauge("serving.submitted", self._next_ticket)
            reg.gauge("serving.shed",
                      sum(v["shed"] for v in per_tenant.values()))
            reg.gauge("serving.errors",
                      sum(v["errors"] for v in per_tenant.values()))
            reg.gauge("serving.pending",
                      self._n_queued() + self._n_inflight)
            reg.gauge("serving.trace_dropped", self.n_trace_dropped)
            reg.gauge("serving.drain_chunk", self.drain_chunk)
            reg.info("serving.per_tenant", per_tenant)
            reg.info("serving.queue_depth", self.queue_depth)
            reg.info("serving.queued_now",
                     {t: len(q) for t, q in self._queues.items()})
            reg.info("serving.last_error", self._last_error)

    def snapshot(self) -> dict:
        """The full telemetry snapshot: shared registry, so inner-ring
        counters/histograms and multi-tenant accounting in one dict."""
        return self.registry.snapshot()

    def stats(self) -> dict:
        """The unified `Server` stats schema + tenant/queue accounting —
        a compatibility view over `snapshot()` (`server.stats_view`)."""
        return stats_view(self.snapshot())
