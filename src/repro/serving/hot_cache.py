"""Frequency-based hot-row cache for quantized embedding tables.

RecNMP and MicroRec both observe that embedding-table traffic under real
recommendation workloads is heavily skewed: a tiny fraction of rows (popular
items, frequent users) absorbs most lookups. iMARS keeps every ET row in the
CMA fabric; the software image of the same locality win is a small cache of
the hottest rows pinned *dense in f32* next to the compute, while cold rows
take the int8 `embedding_pool` dequant-gather path.

Design contract (tested in tests/test_batched_serving.py):

  * the pinned f32 rows are bit-identical to `dequantize_rowwise` of the
    backing int8 rows, so a cached lookup / pooled bag **bit-matches** the
    uncached path — the cache is purely a bandwidth/latency optimisation and
    can never change serving results;
  * every cached op returns a `CacheStats` (hits, lookups) alongside its
    value, so engines can surface measured hit rates per served batch.

Membership is a binary search over the sorted hot-id set (`searchsorted`
plus an equality probe) — O(log K) per id, branch-free, jit-friendly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import QuantizedTensor, dequantize_rowwise
from repro.utils import pytree_dataclass

# empty-slot sentinel for invalidated hot rows: sorts after every real item
# id, so `hot_ids` stays ascending and the searchsorted probe stays valid
# (same value as core.nns.EMPTY_ID, defined locally to keep layering flat)
INVALID_ID = 2**31 - 1


class CacheStats(NamedTuple):
    hits: jax.Array  # () int32 — ids served from the hot set
    lookups: jax.Array  # () int32 — total valid (non-padding) ids

    @staticmethod
    def zero() -> "CacheStats":
        return CacheStats(hits=jnp.int32(0), lookups=jnp.int32(0))

    def __add__(self, other: "CacheStats") -> "CacheStats":
        return CacheStats(hits=self.hits + other.hits,
                          lookups=self.lookups + other.lookups)

    def hit_rate(self) -> float:
        lk = int(self.lookups)
        return float(self.hits) / lk if lk else 0.0

    def as_dict(self) -> dict:
        """Plain-int view ``{hits, lookups, hit_rate}`` for registries and
        reports (forces the one host sync on the traced scalars)."""
        hits, lk = int(self.hits), int(self.lookups)
        return {"hits": hits, "lookups": lk,
                "hit_rate": hits / lk if lk else 0.0}


@pytree_dataclass(meta_fields=("capacity",))
class HotRowCache:
    """Top-K hot rows of one int8 table, pinned dense in f32.

    `hot_ids` is sorted ascending; `hot_rows[i]` is the exact dequantized
    image of table row `hot_ids[i]`.
    """

    hot_ids: jax.Array  # (K,) int32, sorted
    hot_rows: jax.Array  # (K, d) f32
    capacity: int = 0


def top_ids_by_freq(freqs, k: int, eligible=None) -> np.ndarray:
    """Rank row ids by (frequency desc, id asc) and return the top `k`.

    The secondary ascending-id key makes frequency ties deterministic —
    `np.argpartition` tie order is implementation-defined and drifted
    across numpy versions, which made the pinned hot set (and hence the
    served cache counters) irreproducible. Every tier-selection site
    (hot cache, int8 pool, compaction repinning) goes through this one
    helper so they can never disagree on tie order.

    eligible: optional (n,) bool mask; ineligible rows are excluded even
    if fewer than `k` eligible rows exist (the result may be short).

    Runs in O(chunk) temporary memory — a full-array lexsort allocates
    several n-sized scratch arrays, which at the tiered catalog's 8M+
    row counts is hundreds of MB against a residency budget of tens.
    The chunked threshold select returns the EXACT lexsort answer: the
    k-th-largest frequency `t` is found from per-chunk top-k value
    pools, rows with freq > t (at most k of them) sort by
    (freq desc, id asc), and the remaining slots fill with the smallest
    ids at freq == t — `np.flatnonzero` per ascending chunk IS the
    ascending-id tie order.
    """
    freqs = np.asarray(freqs, np.int64)
    n = freqs.shape[0]
    k = min(int(k), n)
    if k <= 0:
        return np.zeros((0,), np.int32)
    elig = None if eligible is None else np.asarray(eligible, bool)
    chunk = 1 << 20

    def masked(lo, hi):
        c = freqs[lo:hi]
        if elig is None:
            return c
        return np.where(elig[lo:hi], c, np.int64(-1))

    pool = []  # per-chunk top-k values: the global top-k lives in here
    for lo in range(0, n, chunk):
        c = masked(lo, min(lo + chunk, n))
        m = c.shape[0]
        # .copy(): the slice would otherwise pin the whole partitioned
        # chunk (an O(chunk) array per iteration) in `pool`
        pool.append(np.partition(c, m - k)[m - k:].copy() if m > k
                    else np.array(c))
    pool = np.concatenate(pool)
    t = np.partition(pool, pool.shape[0] - k)[pool.shape[0] - k]

    gt, eq, n_eq = [], [], 0
    for lo in range(0, n, chunk):
        c = masked(lo, min(lo + chunk, n))
        gt.append(lo + np.flatnonzero(c > t))
        if n_eq < k:  # chunks ascend in id, so the first k suffice
            ids = lo + np.flatnonzero(c == t)
            eq.append(ids)
            n_eq += ids.shape[0]
    gt = np.concatenate(gt)  # at most k rows are strictly above the k-th
    order = np.lexsort((gt, -freqs[gt]))
    top = np.concatenate([gt[order], np.concatenate(eq)[: k - gt.shape[0]]])
    if elig is not None:
        top = top[elig[top] & (freqs[top] >= 0)]
    return top.astype(np.int32)


def build_hot_cache(table: QuantizedTensor, freqs=None,
                    capacity: int = 256) -> HotRowCache:
    """Pin the `capacity` most frequent rows of `table`.

    freqs: (n_rows,) lookup counts (e.g. `np.bincount` over training
    histories). None pins the lowest row ids — the right default for tables
    whose ids are already popularity-ranked, and a deterministic fallback
    otherwise.
    """
    n = int(table.values.shape[0])
    capacity = min(int(capacity), n)
    if capacity <= 0:
        d = int(table.values.shape[1])
        return HotRowCache(hot_ids=jnp.zeros((0,), jnp.int32),
                           hot_rows=jnp.zeros((0, d), jnp.float32),
                           capacity=0)
    if freqs is None:
        hot = np.arange(capacity, dtype=np.int32)
    else:
        freqs = np.asarray(freqs)
        assert freqs.shape == (n,), (freqs.shape, n)
        hot = np.sort(top_ids_by_freq(freqs, capacity))
    hot_ids = jnp.asarray(hot)
    hot_rows = dequantize_rowwise(
        QuantizedTensor(values=table.values[hot_ids],
                        scales=table.scales[hot_ids]))
    return HotRowCache(hot_ids=hot_ids, hot_rows=hot_rows, capacity=capacity)


def pin_rows(table: QuantizedTensor, ids, capacity: int) -> HotRowCache:
    """Pin exactly `ids` (unique item ids) into a capacity-`capacity` cache.

    Slots beyond ``len(ids)`` are empty (`INVALID_ID` ids, zero rows). The
    live-catalog reference rebuild uses this to reproduce a churned cache's
    exact surviving hot set, so cache counters stay comparable bit-for-bit.
    """
    d = int(table.values.shape[1])
    ids = np.sort(np.asarray(ids, np.int32))
    capacity = max(int(capacity), 0)
    if len(ids) > capacity:
        raise ValueError(
            f"pin_rows: {len(ids)} ids exceed capacity {capacity}")
    if capacity == 0:
        return HotRowCache(hot_ids=jnp.zeros((0,), jnp.int32),
                           hot_rows=jnp.zeros((0, d), jnp.float32),
                           capacity=0)
    hot_ids = np.full(capacity, INVALID_ID, np.int32)
    hot_ids[: len(ids)] = ids
    rows = np.zeros((capacity, d), np.float32)
    if len(ids):
        rows[: len(ids)] = np.asarray(dequantize_rowwise(QuantizedTensor(
            values=table.values[ids], scales=table.scales[ids])))
    return HotRowCache(hot_ids=jnp.asarray(hot_ids),
                       hot_rows=jnp.asarray(rows), capacity=capacity)


def invalidate_rows(cache: HotRowCache | None, ids) -> HotRowCache | None:
    """Evict `ids` from the hot set (live-catalog row invalidation).

    Touched rows' pinned f32 images are stale the moment the backing table
    row changes, so they must leave the hot set — everything else stays
    pinned ("invalidated only for touched rows"). Evicted slots become
    `INVALID_ID` / zero-row tails; `hot_ids` is re-sorted so the
    searchsorted probe contract holds. Host-side (updates are host-driven);
    a no-op returns the cache unchanged.
    """
    if cache is None or cache.capacity == 0:
        return cache
    ids = np.asarray(ids, np.int32).reshape(-1)
    hot = np.asarray(cache.hot_ids).copy()
    dead = np.isin(hot, ids)
    if not dead.any():
        return cache
    hot[dead] = INVALID_ID
    rows = np.asarray(cache.hot_rows).copy()
    rows[dead] = 0.0
    order = np.argsort(hot, kind="stable")
    return HotRowCache(hot_ids=jnp.asarray(hot[order]),
                       hot_rows=jnp.asarray(rows[order]),
                       capacity=cache.capacity)


def _probe(cache: HotRowCache, ids: jax.Array):
    """ids (...,) -> (hit mask (...,), position into hot_rows (...,))."""
    pos = jnp.searchsorted(cache.hot_ids, ids)
    pos = jnp.clip(pos, 0, cache.capacity - 1)
    hit = (cache.hot_ids[pos] == ids) & (ids >= 0)
    return hit, pos


def cached_rows(cache: HotRowCache | None, table: QuantizedTensor,
                ids: jax.Array):
    """Gather rows for `ids` (...,) -> ((..., d) f32, CacheStats).

    Hot ids come from the pinned f32 rows; cold ids take the int8
    dequant-gather path. -1 ids yield zero rows (as `embedding.lookup`).
    """
    valid = ids >= 0
    safe = jnp.maximum(ids, 0)
    cold = table.values[safe].astype(jnp.float32) * table.scales[safe]
    if cache is None or cache.capacity == 0:
        rows = jnp.where(valid[..., None], cold, 0.0)
        return rows, CacheStats(
            hits=jnp.int32(0),
            lookups=jnp.sum(valid).astype(jnp.int32))
    hit, pos = _probe(cache, ids)
    rows = jnp.where(hit[..., None], cache.hot_rows[pos], cold)
    rows = jnp.where(valid[..., None], rows, 0.0)
    return rows, CacheStats(hits=jnp.sum(hit).astype(jnp.int32),
                            lookups=jnp.sum(valid).astype(jnp.int32))


def cached_lookup(cache: HotRowCache | None, table: QuantizedTensor,
                  ids: jax.Array):
    """Drop-in for `core.embedding.lookup` returning (rows, CacheStats)."""
    return cached_rows(cache, table, ids)


def pool_rows(rows: jax.Array, ids: jax.Array,
              weights: jax.Array | None = None,
              mode: str = "sum") -> jax.Array:
    """THE pooling reduction: (B, L, d) rows + (B, L) ids -> (B, d).

    One definition shared by the cached bag below and the delta-aware bag
    in `serving/catalog.py` — the frozen-vs-live bit-match contract
    requires the two poolings to stay op-for-op identical, so they must be
    the same ops.
    """
    valid = (ids >= 0).astype(jnp.float32)
    w = valid if weights is None else weights.astype(jnp.float32) * valid
    pooled = jnp.einsum("bld,bl->bd", rows, w)
    if mode == "mean":
        count = jnp.sum(valid, axis=-1, keepdims=True)
        pooled = pooled / jnp.maximum(count, 1.0)
    return pooled


def cached_embedding_bag(
    cache: HotRowCache | None,
    table: QuantizedTensor,
    ids: jax.Array,  # (B, L) int32, -1 padded
    weights: jax.Array | None = None,
    mode: str = "sum",
):
    """Drop-in for `core.embedding.embedding_bag` -> ((B, d), CacheStats).

    The pooling reduction is the same weighted contraction as the uncached
    kernel reference, over rows sourced from the hot set or the int8 path —
    identical inputs in identical order, so the result bit-matches.
    """
    rows, stats = cached_rows(cache, table, ids)  # (B, L, d)
    return pool_rows(rows, ids, weights, mode), stats
