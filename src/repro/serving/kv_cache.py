"""Cache construction per model family (stacked per-layer pytrees that ride
the layer scan). int8 caches follow the iMARS ET format: int8 values +
per-(position, head) f32 scales over the head_dim row."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCacheView
from repro.utils import tree_size_bytes


def _kv_view(cfg: ModelConfig, n_layers: int, batch: int, cache_len: int,
             dtype: str) -> KVCacheView:
    R, hd = cfg.rep_kv_heads, cfg.head_dim
    shape = (n_layers, batch, R, cache_len, hd)
    if dtype == "int8":
        return KVCacheView(
            k=jnp.zeros(shape, jnp.int8),
            v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros(shape[:-1] + (1,), jnp.float32),
            v_scale=jnp.zeros(shape[:-1] + (1,), jnp.float32),
        )
    dt = jnp.dtype(dtype)
    return KVCacheView(
        k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
        k_scale=None, v_scale=None,
    )


def _ssm_states(cfg: ModelConfig, lead: tuple, batch: int):
    conv = jnp.zeros(
        lead + (batch, cfg.ssm_conv - 1, ssm_mod.conv_dim(cfg)), jnp.float32)
    ssm = jnp.zeros(
        lead + (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
        jnp.float32)
    return (conv, ssm)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype: str = "bfloat16"):
    """Empty cache pytree matching models.transformer.forward(mode=decode)."""
    if cfg.family in ("dense", "vlm", "audio"):
        return _kv_view(cfg, cfg.n_layers, batch, cache_len, dtype)
    if cfg.family == "moe":
        if cfg.moe_layer_step == 1:
            return _kv_view(cfg, cfg.n_layers, batch, cache_len, dtype)
        half = cfg.n_layers // 2
        return {
            "dense": _kv_view(cfg, half, batch, cache_len, dtype),
            "moe": _kv_view(cfg, half, batch, cache_len, dtype),
        }
    if cfg.family == "ssm":
        return _ssm_states(cfg, (cfg.n_layers,), batch)
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers % cfg.attn_every
        attn = _kv_view(cfg, groups, batch, cache_len, dtype)
        m = _ssm_states(cfg, (groups, cfg.attn_every), batch)
        rem_state = None
        if rem:
            rem_attn = _kv_view(cfg, 1, batch, cache_len, dtype)
            rem_attn = jax.tree_util.tree_map(
                lambda a: a[0] if a is not None else None, rem_attn)
            rem_state = (rem_attn, _ssm_states(cfg, (rem,), batch))
        return (attn, m, rem_state)
    raise ValueError(cfg.family)


def cache_bytes(cache) -> int:
    return tree_size_bytes(cache)
