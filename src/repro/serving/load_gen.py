"""Open-loop load generation: Poisson arrivals, Zipf popularity, bursts.

Closed-loop benchmarks (serve a wave, wait, serve the next) can only
measure *peak* throughput: the client politely stops offering load while
the server works, so queueing latency never appears. Production traffic
is open-loop — users arrive whether or not the tier is keeping up — and
the number that says "millions of users" is the latency-vs-offered-load
curve, not peak qps. This module generates that load:

  * **Poisson arrivals** per tenant at a configured offered rate
    (exponential inter-arrivals — the datacenter arrival model);
  * **Zipf query popularity** — query ids drawn ``p(rank) ∝ rank^-a``
    from a finite pool, the heavily skewed production embedding traffic
    RecNMP documents (and what makes the hot-row cache earn its keep);
  * **bursty phases** — a deterministic on/off rate modulation
    ``(period_s, duty_frac, multiplier)`` realized by thinning a peak-rate
    Poisson stream, so bursts are still a (inhomogeneous) Poisson process;
  * **real-time replay** into any `Server` — arrivals are submitted at
    their scheduled wall-clock offsets even when the server is behind
    (that is the open loop); latency is measured by the front-end's own
    submit/resolve timestamps (`ConcurrentFrontend.take_trace`), not by
    the caller's redemption time.

Everything is seeded and the schedule is generated up front, so the same
(seed, rate, duration) always offers the same queries at the same
offsets — the CI smoke lane depends on that determinism.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.serving.server import STATUS_OK, STATUS_SHED, ServerConfigError


@dataclasses.dataclass(frozen=True)
class LoadSummary:
    """Per-tenant and aggregate outcome of one open-loop run."""

    duration_s: float
    offered_qps: float  # scheduled arrivals / duration
    achieved_qps: float  # status=ok completions / duration
    shed_frac: float  # shed / submitted
    error_frac: float  # errors / submitted
    p50_ms: float  # admitted (ok) latency percentiles, submit -> resolve
    p99_ms: float
    per_tenant: dict  # tenant -> {offered_qps, achieved_qps, shed_frac,
    #                              p50_ms, p99_ms, n_ok, n_shed, n_errors}


def summarize_trace(trace: Sequence, duration_s: float) -> LoadSummary:
    """Fold `TicketTrace` records into a `LoadSummary`.

    Latency percentiles cover **admitted** (status="ok") tickets only —
    shed tickets resolve instantly by design and would fake a great p99;
    their cost is accounted as `shed_frac` instead.
    """
    tenants = sorted({r.tenant for r in trace})
    per_tenant = {}
    for t in tenants:
        rs = [r for r in trace if r.tenant == t]
        lat = np.array([r.latency_s for r in rs if r.status == STATUS_OK])
        n_ok = int(len(lat))
        n_shed = sum(r.status == STATUS_SHED for r in rs)
        p50, p99 = (np.percentile(lat, [50, 99]) * 1e3 if n_ok else
                    (float("nan"), float("nan")))
        per_tenant[t] = {
            "offered_qps": len(rs) / duration_s,
            "achieved_qps": n_ok / duration_s,
            "shed_frac": n_shed / len(rs) if rs else 0.0,
            "p50_ms": float(p50), "p99_ms": float(p99),
            "n_ok": n_ok, "n_shed": int(n_shed),
            "n_errors": int(len(rs) - n_ok - n_shed),
        }
    lat = np.array([r.latency_s for r in trace if r.status == STATUS_OK])
    n = len(trace)
    n_ok, n_shed = len(lat), sum(r.status == STATUS_SHED for r in trace)
    p50, p99 = (np.percentile(lat, [50, 99]) * 1e3 if n_ok else
                (float("nan"), float("nan")))
    return LoadSummary(
        duration_s=duration_s,
        offered_qps=n / duration_s,
        achieved_qps=n_ok / duration_s,
        shed_frac=n_shed / n if n else 0.0,
        error_frac=(n - n_ok - n_shed) / n if n else 0.0,
        p50_ms=float(p50), p99_ms=float(p99),
        per_tenant=per_tenant)


class LoadGen:
    """Deterministic open-loop arrival schedule + real-time replayer.

    Args:
      rate_qps: total offered rate, split evenly across tenants.
      duration_s: schedule horizon.
      tenants: tenant count (ids ``0..tenants-1``, matching
        `ConcurrentFrontend`).
      pool_size: number of distinct queries to draw from (the caller
        provides the actual query dicts at replay time).
      zipf_a: Zipf popularity exponent over the pool (0 = uniform).
      burst: optional ``(period_s, duty_frac, multiplier)`` — for the
        first ``duty_frac`` of every ``period_s`` window the offered rate
        is ``multiplier`` x the base rate (thinned peak-rate Poisson, so
        the average offered rate rises accordingly).
      seed: RNG seed; the schedule is a pure function of the arguments.
    """

    def __init__(self, *, rate_qps: float, duration_s: float,
                 tenants: int = 1, pool_size: int,
                 zipf_a: float = 1.1,
                 burst: tuple[float, float, float] | None = None,
                 seed: int = 0):
        if rate_qps <= 0 or duration_s <= 0:
            raise ServerConfigError("rate_qps and duration_s must be > 0")
        if tenants < 1 or pool_size < 1:
            raise ServerConfigError("tenants and pool_size must be >= 1")
        if burst is not None:
            period, duty, mult = burst
            if not (period > 0 and 0 < duty <= 1 and mult >= 1):
                raise ServerConfigError(
                    f"burst must be (period>0, 0<duty<=1, mult>=1): {burst}")
        self.rate_qps = float(rate_qps)
        self.duration_s = float(duration_s)
        self.tenants = int(tenants)
        self.pool_size = int(pool_size)
        self.zipf_a = float(zipf_a)
        self.burst = burst
        self.seed = int(seed)

    # ------------------------------------------------------------------
    def _zipf_p(self) -> np.ndarray:
        ranks = np.arange(1, self.pool_size + 1, dtype=np.float64)
        p = ranks ** -self.zipf_a
        return p / p.sum()

    def schedule(self) -> list[tuple[float, int, int]]:
        """Sorted ``(t_offset_s, tenant, pool_index)`` arrivals.

        Per-tenant independent Poisson streams at ``rate_qps / tenants``;
        bursts realized by thinning a peak-rate stream so the process
        stays Poisson within each phase.
        """
        rng = np.random.default_rng(self.seed)
        per_rate = self.rate_qps / self.tenants
        peak = per_rate * (self.burst[2] if self.burst else 1.0)
        p_pool = self._zipf_p()
        out = []
        for tenant in range(self.tenants):
            # draw enough exponential gaps to cover the horizon at peak
            n_max = max(16, int(peak * self.duration_s * 1.5) + 64)
            t = np.cumsum(rng.exponential(1.0 / peak, size=n_max))
            while t[-1] < self.duration_s:  # pragma: no cover - rare topup
                t = np.concatenate(
                    [t, t[-1] + np.cumsum(
                        rng.exponential(1.0 / peak, size=n_max))])
            t = t[t < self.duration_s]
            if self.burst is not None:
                period, duty, mult = self.burst
                in_burst = (t % period) < duty * period
                # thin off-burst arrivals down from the peak rate
                keep = in_burst | (rng.random(len(t)) < 1.0 / mult)
                t = t[keep]
            q = rng.choice(self.pool_size, size=len(t), p=p_pool)
            out.extend(zip(t.tolist(), [tenant] * len(t), q.tolist()))
        out.sort()
        return out

    # ------------------------------------------------------------------
    def replay(self, server, pool: Sequence[dict]
               ) -> list[tuple[int, int, int]]:
        """Submit the schedule against `server` in real time.

        Arrivals are submitted at their scheduled offsets; when the
        submitting thread falls behind wall-clock (scheduler jitter, a
        slow submit), the overdue arrivals are submitted immediately —
        open-loop load never waits for the server. Returns
        ``(ticket, tenant, pool_index)`` in schedule order (so callers
        can bit-match admitted results against synchronous serving);
        call ``server.flush()`` + ``server.take_trace()`` afterwards to
        measure.
        """
        if len(pool) < self.pool_size:
            raise ServerConfigError(
                f"pool has {len(pool)} queries, schedule draws from "
                f"{self.pool_size}")
        sched = self.schedule()
        out = []
        t0 = time.perf_counter()
        for t_arr, tenant, qi in sched:
            lag = t_arr - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            out.append((server.submit(pool[qi], tenant=tenant), tenant, qi))
        return out
