"""Train-while-serve: filtering-model gradient steps feeding the live catalog.

Every accuracy number so far was measured against a frozen table; RecSys
tables churn with user behavior (the reason iMARS wants embeddings in the
CMA fabric at all), so the serving stack needs a trainer that keeps the
catalog fresh *while traffic is live*. `OnlineTrainer` closes that loop:

  * **gradient steps** run the exact offline training computation
    (`distributed.training.make_recsys_train_step`: full-softmax
    `filtering_loss` + AdamW) on interaction batches;
  * **embedding folds** diff the trainer's item table against the last
    published snapshot and push only the changed rows through
    `LiveCatalog.upsert` — i.e. the quantize-at-ingestion path
    (`catalog.quantize_updates`), so a folded row is bit-identical to the
    same row in a cold `RecSysEngine.build` of the current parameters;
  * **dense refreshes** (`refresh_dense`) publish the MLPs / UIETs /
    genre table through `catalog.engine_refresh_model` — same treedef,
    same shapes, no retrace;
  * every publication lands through `LiveCatalog._publish` ->
    `server.swap_engine`, which on the concurrent front-end takes the
    drain thread's `_serve_lock` — **updates serialize with serving
    exactly the way epoch swaps do**: a drain chunk is always entirely
    one engine value, and nothing an in-flight bucket references is ever
    mutated.

Staleness contract (measured, not assumed): each `step()` *lands* one
update batch in trainer state at time t_step; the batch becomes *visible*
to serving when a later `fold()` publishes it at t_fold. Per-batch
staleness is ``t_fold - t_step``; `updates_landed` / `updates_visible`
count the two sides, and `staleness_ms` records every folded batch's
value so harnesses can plot staleness against update rate
(`benchmarks/online_freshness.py`).

The trainer is single-writer by design: call `step`/`fold`/
`refresh_dense` from ONE thread (the training thread). Serving threads
only ever read engine values that publications swapped in atomically.
The correctness oracle lives in `serving/shadow.py`.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.distributed import training
from repro.serving.catalog import LiveCatalog


class OnlineTrainer:
    """Filtering-model online learner over a `LiveCatalog`.

    Args:
      catalog: the live catalog whose attached servers receive every fold
        and refresh (`LiveCatalog.attach` wires the publication path).
      cfg: the `YoutubeDNNConfig` the catalog's engine was built with.
      params: the current model parameters (the engine's build params —
        online learning continues the deployed model, it does not restart
        from scratch).
      lr / weight_decay: AdamW knobs, defaulting to the offline recipe of
        `benchmarks/accuracy_hr.py` so on/offline trajectories match.
      fold_every: publish embedding updates every N steps (1 = every
        step; 0 = only on explicit `fold()` calls). Larger cadences trade
        staleness for fold overhead — the axis the freshness benchmark
        sweeps.
      compact_every: fold the delta into a new base epoch every N folds
        (0 = never; the delta still auto-compacts when full). Keeps the
        epoch machinery exercised *under* live training.
    """

    def __init__(self, catalog: LiveCatalog, cfg, params, *,
                 lr: float = 3e-3, weight_decay: float = 0.0,
                 fold_every: int = 1, compact_every: int = 0):
        self.catalog = catalog
        self.cfg = cfg
        self.fold_every = int(fold_every)
        self.compact_every = int(compact_every)
        self.state = training.init_recsys_train_state(params)
        self._train_step = training.make_recsys_train_step(
            cfg, lr=lr, weight_decay=weight_decay)
        # the last *published* item table (host f32): folds diff against
        # it so only rows whose embedding actually moved ride the delta
        self._last_folded = np.array(params["item_table"], np.float32)
        self.steps_done = 0
        self.n_folds = 0
        self.rows_folded = 0
        self.updates_visible = 0  # steps whose updates serving can see
        self.staleness_ms: list[float] = []  # one entry per folded step
        self._pending_t: list[float] = []  # t_step of not-yet-folded steps
        self.last_loss = float("nan")
        # telemetry: share the catalog's registry (adopted from the
        # attached server) so fold staleness rides the one snapshot();
        # resolved lazily because attach order varies
        self._registry = None
        self._probe_registry()

    @property
    def registry(self):
        return self._probe_registry()

    def _probe_registry(self):
        """The catalog's registry, once it has one; registers the
        `online.*` collector the first time it appears."""
        if self._registry is None:
            reg = getattr(self.catalog, "registry", None)
            if reg is not None:
                self._registry = reg
                reg.register_collector(self._collect)
        return self._registry

    def _collect(self, reg) -> None:
        """Snapshot-time collector: `online.*` freshness gauges."""
        reg.gauge("online.steps", self.steps_done)
        reg.gauge("online.folds", self.n_folds)
        reg.gauge("online.rows_folded", self.rows_folded)
        reg.gauge("online.updates_visible", self.updates_visible)
        reg.gauge("online.updates_pending", self.updates_pending)

    # -- introspection -------------------------------------------------
    @property
    def params(self):
        """The trainer's current parameters (the cold-rebuild input)."""
        return self.state.params

    @property
    def updates_landed(self) -> int:
        """Update batches applied to trainer state (== steps taken)."""
        return self.steps_done

    @property
    def updates_pending(self) -> int:
        """Landed update batches not yet visible to serving."""
        return self.steps_done - self.updates_visible

    # -- the training loop ---------------------------------------------
    def step(self, batch: dict) -> float:
        """One gradient step on an interaction batch; folds on cadence.

        Returns the batch loss. The step *lands* an update batch (its
        embedding changes exist only in trainer state until the next
        fold makes them serveable).
        """
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        self.state, loss = self._train_step(self.state, b)
        self.last_loss = float(loss)
        self.steps_done += 1
        self._pending_t.append(time.perf_counter())
        if self.fold_every and self.steps_done % self.fold_every == 0:
            self.fold()
        return self.last_loss

    def fold(self) -> int:
        """Publish item-embedding changes since the last fold.

        Diffs the trainer's item table against the last published
        snapshot and upserts exactly the changed rows (quantized at
        ingestion — `LiveCatalog.upsert`). Publication swaps the new
        engine value into every attached server under its serve lock, so
        the fold is atomic w.r.t. the drain thread. Returns the number of
        rows folded; a fold with no pending change is a no-op (no upsert,
        no publication).
        """
        table = np.asarray(self.state.params["item_table"], np.float32)
        changed = np.nonzero((table != self._last_folded).any(axis=1))[0]
        if changed.size:
            self.catalog.upsert(changed.astype(np.int64), table[changed])
            self._last_folded[changed] = table[changed]
            self.rows_folded += int(changed.size)
        now = time.perf_counter()
        self.staleness_ms.extend((now - t) * 1e3 for t in self._pending_t)
        if self.registry is not None:
            for t in self._pending_t:
                self.registry.observe("online.staleness_ms",
                                      (now - t) * 1e3)
            self.registry.event("fold", rows=int(changed.size),
                                steps_folded=len(self._pending_t))
        self.updates_visible += len(self._pending_t)
        self._pending_t.clear()
        self.n_folds += 1
        if self.compact_every and self.n_folds % self.compact_every == 0:
            self.catalog.compact()
        return int(changed.size)

    def refresh_dense(self) -> None:
        """Publish the current dense parameters (MLPs, UIETs, genre
        table) to serving — `LiveCatalog.refresh_model`. After
        ``fold(); refresh_dense()`` the live engine serves bit-for-bit
        what a cold rebuild of `self.params` would serve (the
        `serving.shadow` oracle asserts exactly this)."""
        self.catalog.refresh_model(self.state.params)

    def stats(self) -> dict:
        """Host-side freshness counters (never affect served results)."""
        lat = self.staleness_ms
        return {
            "steps": self.steps_done,
            "folds": self.n_folds,
            "rows_folded": self.rows_folded,
            "updates_landed": self.updates_landed,
            "updates_visible": self.updates_visible,
            "updates_pending": self.updates_pending,
            "staleness_ms_mean": float(np.mean(lat)) if lat else 0.0,
            "staleness_ms_p95": float(np.percentile(lat, 95)) if lat
            else 0.0,
            "last_loss": self.last_loss,
        }
