"""The end-to-end iMARS serving pipeline (paper Fig. 3 computation flow).

Deployment flow (Sec. III-B/C): take a *trained* YoutubeDNN, quantize every
ET to int8 (1a: tables into CMA banks), build 256-bit LSH signatures for the
ItET rows, then per query:

  (1a/1b*) sparse lookups + pooling through the fused int8 kernel path
  (1b/1c)  filtering DNN -> user embedding u_i
  (1d)     fixed-radius Hamming NNS over the ItET signatures -> candidates
  (2a-2d)  ranking: candidate embeddings + ranking UIETs -> CTR per item
  (2e)     CTR-buffer threshold top-k -> final items

Serving architecture (this module + serving/batcher.py + serving/hot_cache.py):

  * `RecSysEngine` is a **registered pytree** — all tables/params/signatures
    are leaves, all scalar knobs (cfg, radius, k, mesh) are static metadata —
    so the whole engine passes through `jax.jit` as a plain argument and
    `serve_step` / `filter_step` / `rank_step` are jit-compiled pure
    functions over it.
  * UIET/ItET lookups go through a `HotRowCache` (RecNMP/MicroRec-style
    top-K hot rows pinned dense f32; cold rows via the int8 `embedding_pool`
    path); measured hit rates ride along in every serve result.
  * The filtering NNS optionally shards `item_sigs` row-wise over a mesh
    axis (`RecSysEngine.shard`): each device scans its bank and bounded
    per-shard candidates are all-gathered + re-selected, the paper's
    priority-encoder + RSC communication pattern.

The engine also composes the hardware cost model per query so every served
batch reports (latency_us, energy_uj) the iMARS fabric would have spent —
the software pipeline and the analytic model stay in lockstep.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core.embedding import embedding_bag
from repro.core.lsh import lsh_signature, make_lsh_projections
from repro.core.nns import (
    NNSResult,
    build_block_summary,
    delta_scan,
    fixed_radius_nns,
    merge_delta_candidates,
    query_parallel_delta_scan,
    query_parallel_nns,
    sharded_fixed_radius_nns,
)
from repro.core.quantization import QuantizedTensor, quantize_rowwise
from repro.core.topk import TopKResult, threshold_topk
from repro.models import recsys as rs
from repro.serving.catalog import (
    delta_cached_embedding_bag,
    delta_cached_rows,
)
from repro.serving.hot_cache import (
    CacheStats,
    HotRowCache,
    build_hot_cache,
    cached_embedding_bag,
)
from repro.utils import FrozenMapping, pytree_dataclass


class ServeResult(NamedTuple):
    items: jax.Array  # (B, top_k) final item ids, -1 padded
    topk: TopKResult  # per-candidate CTR top-k
    nns: NNSResult  # filtering-stage candidates
    cost: cm.OpCost  # hardware cost model for this query shape
    stats: CacheStats  # hot-cache hits/lookups for this batch


@pytree_dataclass(meta_fields=(
    "cfg", "radius", "n_candidates", "top_k", "nns_mesh", "nns_axis",
    "scan_block", "nns_query_axis", "prune"))
class RecSysEngine:
    """The deployed iMARS pipeline as a jit-able pytree.

    Array fields (quantized tables, LSH signatures, MLP params, hot-row
    caches) are pytree leaves; scalar knobs are static jit metadata:

      * ``radius`` / ``n_candidates`` / ``top_k`` — filtering-NNS radius,
        bounded candidate-set size, and final recommendation count;
      * ``scan_block`` — filtering-stage NNS execution plan: ``None`` routes
        dense vs streaming automatically by catalog size, ``0`` forces the
        dense (q, n) path, a positive value forces the streaming scan with
        that chunk size. A pure execution knob: every plan serves
        bit-identical results (tested);
      * ``nns_mesh`` / ``nns_axis`` / ``nns_query_axis`` — set by
        :meth:`shard`; route the NNS onto a device mesh (bank-sharded DB,
        query-parallel blocks, or both). Also execution-only: sharded
        serving bit-matches local serving.

    Build with :meth:`build` (quantizes a trained YoutubeDNN), distribute
    with :meth:`shard`, serve with :meth:`serve` / `MicroBatcher` /
    `AsyncServer`.
    """

    tables_q: dict  # name -> QuantizedTensor (int8 UIETs)
    item_table_q: QuantizedTensor  # int8 ItET
    genre_table_q: QuantizedTensor
    item_sigs: jax.Array  # (n_items, 8) packed 256-bit LSH signatures
    params: dict  # trained MLP weights (crossbar stack)
    lsh_proj: jax.Array
    item_hot: HotRowCache  # hot ItET rows (history pooling + ranking)
    uiet_hot: dict  # name -> HotRowCache for the user-feature ETs
    # live-catalog state (serving/catalog.py): a bounded DeltaShard overlay
    # of pending item updates + the base-row tombstone mask; None for a
    # frozen engine (zero serving overhead). Both are pytree leaves, so
    # epoch/update swaps never retrace the jitted serve steps.
    delta: object = None  # catalog.DeltaShard | None
    item_mask: jax.Array | None = None  # (n,) bool — alive base rows
    # per-block occupancy summary of item_sigs (core.nns.BlockSummary) —
    # a pytree leaf like delta/item_mask, kept fresh by serving/catalog.py
    # on upsert/delete/compact; None disables pruning entirely
    block_summary: object = None  # core.nns.BlockSummary | None
    cfg: rs.YoutubeDNNConfig = None
    radius: int = 96
    n_candidates: int = 50
    top_k: int = 10
    nns_mesh: jax.sharding.Mesh | None = None
    nns_axis: str | None = None
    scan_block: int | None = None  # filtering NNS: None=auto, 0=dense, >0=chunk
    nns_query_axis: str | None = None  # mesh axis scanning query blocks in parallel
    # block pruning: None=auto (prune whenever a summary exists and the
    # plan streams), False=force off, True=explicitly on (same as auto —
    # the scan still needs a summary and a streaming plan to prune)
    prune: bool | None = None

    @staticmethod
    def build(params: dict, cfg: rs.YoutubeDNNConfig, *, lsh_bits: int = 256,
              radius: int = 96, n_candidates: int = 50, top_k: int = 10,
              hot_rows: int = 0, item_freqs=None, uiet_freqs: dict | None = None,
              scan_block: int | None = None, prune: bool | None = None,
              key=None) -> "RecSysEngine":
        """Quantize a trained YoutubeDNN into a serving engine.

        hot_rows: capacity of the per-table hot-row caches (0 disables).
        item_freqs / uiet_freqs: lookup-frequency histograms (e.g. bincounts
        over training histories) selecting which rows get pinned.
        scan_block: filtering-stage NNS execution plan — None routes dense vs
        streaming automatically by catalog size, 0 forces the dense (q, n)
        path, a positive value forces the streaming scan with that chunk.
        prune: block-summary pruning of the streaming scan — None=auto
        (prune whenever the plan streams), False=off. Bit-identical either
        way; pruned scans also report per-query `blocks_touched`.
        """
        key = jax.random.key(7) if key is None else key
        # cfg is static jit metadata -> its feature map must be hashable
        if not isinstance(cfg.user_features, FrozenMapping):
            cfg = cfg._replace(user_features=FrozenMapping(cfg.user_features))
        tables_q = {k: quantize_rowwise(v) for k, v in params["tables"].items()}
        item_q = quantize_rowwise(params["item_table"])
        genre_q = quantize_rowwise(params["genre_table"])
        proj = make_lsh_projections(key, cfg.embed_dim, lsh_bits)
        # signatures of the int8-dequantized rows (what the CMA stores)
        from repro.core.quantization import dequantize_rowwise

        sigs = lsh_signature(dequantize_rowwise(item_q), proj)
        uiet_freqs = uiet_freqs or {}
        item_hot = build_hot_cache(item_q, item_freqs, hot_rows)
        uiet_hot = {name: build_hot_cache(tables_q[name],
                                          uiet_freqs.get(name), hot_rows)
                    for name in tables_q}
        return RecSysEngine(
            cfg=cfg, tables_q=tables_q, item_table_q=item_q,
            genre_table_q=genre_q, item_sigs=sigs, params=params,
            lsh_proj=proj, item_hot=item_hot, uiet_hot=uiet_hot,
            block_summary=build_block_summary(sigs),
            radius=radius, n_candidates=n_candidates, top_k=top_k,
            scan_block=scan_block, prune=prune)

    def shard(self, mesh: jax.sharding.Mesh, axis: str | None = None, *,
              query_axis: str | None = None) -> "RecSysEngine":
        """Distribute the filtering-stage NNS over `mesh`.

        `axis` row-shards the signature DB (pads `item_sigs` to a multiple
        of the axis size — pad rows are excluded from matching via
        `n_valid` — and places it with a NamedSharding); `query_axis` scans
        query blocks in parallel over a second mesh axis with each block
        seeing its bank (or, with `axis=None`, the whole replicated
        catalog). Both compose: `shard(mesh, "banks", query_axis="qp")`
        partitions (query block x bank).
        """
        from jax.sharding import NamedSharding, PartitionSpec as P

        if axis is None and query_axis is None:
            raise ValueError("shard() needs a db axis, a query_axis, or both")
        sigs, mask = self.item_sigs, self.item_mask
        summary = self.block_summary
        if axis is not None:
            n_shards = mesh.shape[axis]
            n = sigs.shape[0]
            pad = (-n) % n_shards
            sigs = jnp.pad(sigs, ((0, pad), (0, 0)))
            if mask is not None:  # tombstones ride the banks (pad rows dead)
                mask = jnp.pad(mask[: n], (0, pad))
            if summary is not None:
                # the summary must cover the PADDED layout so each bank owns
                # whole summary blocks; pad rows are ineligible via n_valid.
                # Misaligned shard sizes drop the summary (unpruned banks —
                # a pure execution fallback, results unchanged).
                br = summary.block_rows
                per_shard = sigs.shape[0] // n_shards
                if per_shard % br == 0:
                    summary = build_block_summary(
                        np.asarray(sigs), br, db_mask=mask, n_valid=n)
                else:
                    summary = None
            sigs = jax.device_put(sigs, NamedSharding(mesh, P(axis, None)))
            if mask is not None:
                mask = jax.device_put(mask, NamedSharding(mesh, P(axis)))
        kw = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        kw.update(item_sigs=sigs, item_mask=mask, block_summary=summary,
                  nns_mesh=mesh, nns_axis=axis, nns_query_axis=query_axis)
        return RecSysEngine(**kw)

    # ------------------------------------------------------------------
    # live-catalog plumbing (serving/catalog.py holds the mechanics)
    # ------------------------------------------------------------------
    def live(self, delta_capacity: int = 1024) -> "RecSysEngine":
        """A live-catalog view of this engine: empty bounded delta shard +
        all-alive tombstone mask (one-time treedef change; see
        `catalog.ensure_live`). Usually reached via `catalog.LiveCatalog`.
        """
        from repro.serving.catalog import ensure_live

        return ensure_live(self, delta_capacity)

    def apply_updates(self, upsert_ids=None, upsert_rows=None,
                      delete_ids=None) -> "RecSysEngine":
        """New engine with the update batch folded into the delta shard
        (upserts re-embed/extend, deletes tombstone; touched rows leave the
        hot cache). The old engine value stays valid — callers swap
        atomically between buckets. See `catalog.engine_apply_updates`."""
        from repro.serving.catalog import engine_apply_updates

        return engine_apply_updates(self, upsert_ids, upsert_rows,
                                    delete_ids)

    def compact(self) -> "RecSysEngine":
        """New-epoch engine with the delta folded into a fresh read-only
        base (sharded engines re-shard onto their mesh). The old epoch
        stays serveable. See `catalog.compact_engine`."""
        from repro.serving.catalog import compact_engine

        return compact_engine(self)

    # ------------------------------------------------------------------
    # thin object API over the jitted pure functions below
    # ------------------------------------------------------------------
    def user_embedding(self, batch: dict) -> jax.Array:
        """(1a)-(1c): quantized lookups/pooling + filtering DNN."""
        u, _, _ = _features(self, batch)
        return u

    def filter_stage(self, batch: dict) -> NNSResult:
        """(1d): fixed-radius Hamming NNS -> candidate item ids."""
        nns, _ = filter_step(self, batch)
        return nns

    def rank_stage(self, batch: dict, cand: jax.Array) -> TopKResult:
        """(2a)-(2e): CTR per candidate + threshold top-k."""
        top, _ = rank_step(self, batch, cand)
        return top

    def serve(self, batch: dict) -> ServeResult:
        """Serve one padded batch through the full query pipeline.

        Args:
          batch: dict with one (B,) int32 array per user feature named in
            ``cfg.user_features``, a (B, L) int32 ``history``, a (B,) int32
            ``genre``, and optionally a (B,) bool ``valid`` row mask
            (rows with ``valid=False`` — or with all ids -1 — are padding:
            they read zero rows, never touch the hot-cache counters, and
            their outputs are discarded by callers).
        Returns:
          ServeResult with (B, top_k) final item ids (-1 padded), the
          per-candidate CTR top-k, the filtering-stage NNS candidates, the
          per-query hardware cost model, and the hot-cache CacheStats for
          this batch. One fused jitted step (`serve_step`); bit-identical
          to running `lookup_step` -> `scan_step` -> `rank_stage_step`.
        """
        items, top, nns, stats = serve_step(self, batch, CacheStats.zero())
        return ServeResult(items=items, topk=top, nns=nns,
                           cost=self.query_cost(), stats=stats)

    # ------------------------------------------------------------------
    # hardware cost accounting (per query)
    # ------------------------------------------------------------------
    def query_cost(self) -> cm.OpCost:
        e2e = cm.end_to_end_movielens(n_candidates=self.n_candidates)
        return cm.OpCost(latency_ns=e2e["imars_latency_us"] * 1e3,
                         energy_pj=e2e["imars_energy_uj"] * 1e6)


# ---------------------------------------------------------------------------
# jit-compiled pure stages over the engine pytree
# ---------------------------------------------------------------------------
def _features(engine: RecSysEngine, batch: dict):
    """Cached lookups + filtering DNN -> (u, pooled_history, CacheStats).

    `batch["valid"]` (optional, (B,) bool) marks real rows; padding rows'
    ids are dropped to -1 so they never count as cache lookups (and read
    zero rows, which the caller discards anyway).
    """
    valid = batch.get("valid")

    def mask(ids):
        if valid is None:
            return ids
        return jnp.where(valid[:, None], ids, -1)

    stats = CacheStats.zero()
    feats = []
    for name in sorted(engine.cfg.user_features.keys()):
        emb, st = cached_embedding_bag(
            engine.uiet_hot.get(name), engine.tables_q[name],
            mask(batch[name][:, None]))
        feats.append(emb)
        stats = stats + st
    # history pooling reads the ITEM table -> must see pending delta rows
    # (a re-embedded item in someone's history pools its new embedding,
    # exactly as a rebuilt engine would)
    pooled, st = delta_cached_embedding_bag(
        engine.delta, engine.item_hot, engine.item_table_q,
        mask(batch["history"]), mode="mean")
    stats = stats + st
    feats.append(pooled)
    x = jnp.concatenate(feats, axis=-1)
    u = rs._mlp_apply(engine.params["filter_mlp"], x)
    return u, pooled, stats


def _nns(engine: RecSysEngine, q_sigs: jax.Array) -> NNSResult:
    """Filtering scan: routed base scan + (live engines) delta scan + merge.

    The base epoch scans through whichever execution plan the engine is
    configured for, with tombstoned rows masked out; pending delta rows
    scan densely (the shard is bounded) and the two candidate buffers merge
    into the exact rebuilt-table (distance, id) order
    (`core.nns.merge_delta_candidates`).

    Every plan threads the engine's `block_summary` + `prune` knob down to
    the scan: streaming plans skip summary blocks whose sound lower bound
    exceeds the radius (bit-identical results, `blocks_touched` counters
    in the NNSResult); dense plans and `prune=False` scan unpruned.
    """
    summary, prune = engine.block_summary, engine.prune
    if engine.nns_mesh is not None and engine.nns_axis is not None:
        base = sharded_fixed_radius_nns(
            engine.nns_mesh, engine.nns_axis, q_sigs, engine.item_sigs,
            engine.radius, engine.n_candidates,
            n_valid=engine.item_table_q.shape[0],
            scan_block=engine.scan_block,
            query_axis=engine.nns_query_axis,
            db_mask=engine.item_mask, summary=summary, prune=prune)
    elif engine.nns_mesh is not None:  # query-parallel only, db replicated
        # n_valid still matters: item_sigs may carry pad rows from an
        # earlier bank-sharded incarnation of this engine
        base = query_parallel_nns(
            engine.nns_mesh, engine.nns_query_axis, q_sigs, engine.item_sigs,
            engine.radius, engine.n_candidates, scan_block=engine.scan_block,
            n_valid=engine.item_table_q.shape[0],
            db_mask=engine.item_mask, summary=summary, prune=prune)
    else:
        base = fixed_radius_nns(q_sigs, engine.item_sigs, engine.radius,
                                engine.n_candidates,
                                scan_block=engine.scan_block,
                                db_mask=engine.item_mask,
                                summary=summary, prune=prune)
    if engine.delta is None or engine.delta.capacity == 0:
        return base
    if engine.nns_mesh is not None and engine.nns_query_axis is not None:
        # mesh plans with a query axis: shard the (per-query independent)
        # delta scan along it too — 1/P of the shard per device instead of
        # every device scanning all of it replicated. Bank-only meshes keep
        # the replicated scan (no query axis to split over).
        pending = query_parallel_delta_scan(
            engine.nns_mesh, engine.nns_query_axis, q_sigs,
            engine.delta.sigs, engine.delta.ids, engine.radius,
            engine.n_candidates)
    else:
        pending = delta_scan(q_sigs, engine.delta.sigs, engine.delta.ids,
                             engine.radius, engine.n_candidates)
    return merge_delta_candidates(base, pending, engine.n_candidates)


def _filter_step(engine: RecSysEngine, batch: dict):
    """Features + filtering NNS in one jitted call -> (NNSResult, stats).

    The retrieval-only entry (`hit_rate` evaluation, filter-stage tests);
    the serving path uses `serve_step` or the staged split instead.
    """
    u, _, stats = _features(engine, batch)
    q_sigs = lsh_signature(u, engine.lsh_proj)
    return _nns(engine, q_sigs), stats


def _rank(engine: RecSysEngine, batch: dict, cand: jax.Array,
          u: jax.Array, pooled: jax.Array):
    """CTR + threshold top-k given precomputed user features."""
    valid = batch.get("valid")
    if valid is not None:  # padding rows: no candidate lookups, no stats
        cand = jnp.where(valid[:, None], cand, -1)
    # -1 candidates read zero rows and don't count as lookups; their CTR
    # is masked to -inf below either way. Candidate rows resolve through
    # the delta overlay (pending re-embeds/new items rank on their
    # current rows, not the stale base).
    items, st = delta_cached_rows(engine.delta, engine.item_hot,
                                  engine.item_table_q, cand)
    genre = embedding_bag(engine.genre_table_q, batch["genre"][:, None])
    B, N = cand.shape
    ctx = jnp.concatenate([u, genre, pooled], axis=-1)
    x = jnp.concatenate(
        [jnp.broadcast_to(ctx[:, None], (B, N, ctx.shape[-1])), items],
        axis=-1)
    logits = rs._mlp_apply(engine.params["rank_mlp"], x)[..., 0]
    ctr = jax.nn.sigmoid(logits)
    ctr = jnp.where(cand >= 0, ctr, -jnp.inf)  # mask padding candidates
    return threshold_topk(ctr, threshold=0.0, k=engine.top_k), st


def _rank_step(engine: RecSysEngine, batch: dict, cand: jax.Array):
    """Rank externally-supplied candidates -> (TopKResult, stats).

    Recomputes the user features for `batch`; use `rank_stage_step` with
    the outputs of `lookup_step` to avoid the recompute when pipelining.
    """
    u, pooled, stats = _features(engine, batch)
    top, st = _rank(engine, batch, cand, u, pooled)
    return top, stats + st


def _serve_step(engine: RecSysEngine, batch: dict, stats: CacheStats):
    """One fused serving step: features -> NNS -> rank -> final ids.

    `stats` is a running hot-cache hit accumulator; callers jit this with
    the accumulator donated so it updates in place across batches. Composes
    the three stage functions below, so the fused step and the pipelined
    lookup/scan/rank split are the same computation by construction.
    """
    u, pooled, stats = _lookup_stage(engine, batch, stats)
    nns = _scan_stage(engine, u)
    final, top, stats = _rank_stage(engine, batch, nns.indices, u, pooled,
                                    stats)
    return final, top, nns, stats


def _lookup_stage(engine: RecSysEngine, batch: dict, stats: CacheStats):
    """Stage 1 — ET lookups + pooling + filtering DNN.

    Returns (u, pooled, stats'): the user embedding, the pooled history
    (both needed again by the ranking stage), and the donated hot-cache
    accumulator advanced by this batch's feature lookups.
    """
    u, pooled, st = _features(engine, batch)
    return u, pooled, stats + st


def _scan_stage(engine: RecSysEngine, u: jax.Array) -> NNSResult:
    """Stage 2 — the filtering NNS scan, given stage 1's user embedding.

    LSH-signs `u` and runs the fixed-radius Hamming scan (dense, streaming,
    bank-sharded, or query-parallel per the engine's knobs). Pure function
    of (engine, u): no batch dict, no cache counters — so a caller can keep
    bucket i's scan in flight while bucket i+1 runs `lookup_step`.
    """
    return _nns(engine, lsh_signature(u, engine.lsh_proj))


def _rank_stage(engine: RecSysEngine, batch: dict, cand: jax.Array,
                u: jax.Array, pooled: jax.Array, stats: CacheStats):
    """Stage 3 — rank candidates and pick the final items.

    Takes stage 1's (u, pooled) and stage 2's candidate ids; returns
    (final_items, topk, stats') exactly like the tail of `serve_step`.
    Composing the three stages bit-matches the fused step (tested).
    """
    top, st = _rank(engine, batch, cand, u, pooled)
    final = jnp.where(top.indices >= 0,
                      jnp.take_along_axis(cand, jnp.maximum(top.indices, 0),
                                          1),
                      -1)
    return final, top, stats + st


filter_step = jax.jit(_filter_step)
rank_step = jax.jit(_rank_step)
serve_step = jax.jit(_serve_step, donate_argnums=(2,))
# the same pipeline split at its stage boundaries, for pipelined serving
# (serving/async_server.py): lookup -> scan -> rank compose to exactly
# serve_step, but each stage dispatches separately so a driver can overlap
# host-side work (and the next bucket's lookup) with an in-flight scan.
lookup_step = jax.jit(_lookup_stage, donate_argnums=(2,))
scan_step = jax.jit(_scan_stage)
rank_stage_step = jax.jit(_rank_stage, donate_argnums=(5,))


def n_summary_blocks(engine: RecSysEngine) -> int:
    """Total block-summary blocks of the engine's catalog (0 when no
    summary is attached — dense plans can't prune). The denominator for
    the ``scan_frac`` telemetry: blocks touched / summary blocks."""
    summary = engine.block_summary
    return 0 if summary is None else int(summary.n_blocks)


def hit_rate(engine: RecSysEngine, data, batch_size: int = 256,
             k: int = 10, mode: str = "lsh", max_users: int | None = None
             ) -> float:
    """YoutubeDNN leave-one-out HR@k over the test labels.

    mode: "fp32" (cosine, fp32 tables), "int8" (cosine over dequantized
    int8), "lsh" (the iMARS fixed-radius Hamming path) — the three accuracy
    configurations of paper Sec. IV-B.

    Evaluation runs through the batched serving path: users are chunked into
    fixed `batch_size` device batches (last chunk padded, results masked) and
    each chunk goes through one jitted retrieval step.
    """
    n = data.n_users if max_users is None else min(max_users, data.n_users)
    hits = 0
    for lo in range(0, n, batch_size):
        hi = min(lo + batch_size, n)
        idx = np.arange(lo, hi)
        # pad to the fixed batch shape so the jitted step compiles once
        pad_idx = np.concatenate(
            [idx, np.full(batch_size - idx.size, idx[-1], idx.dtype)])
        batch = {
            **{k2: jnp.asarray(v[pad_idx]) for k2, v in data.user_feats.items()},
            "history": jnp.asarray(data.histories[pad_idx]),
            "genre": jnp.asarray(data.genres[pad_idx]),
        }
        got = np.asarray(_hr_step(engine, batch, mode, k))[: idx.size]
        labels = data.test_labels[idx]
        hits += int((got == labels[:, None]).any(axis=1).sum())
    return hits / n


@partial(jax.jit, static_argnames=("mode", "k"))
def _hr_step(engine: RecSysEngine, batch: dict, mode: str, k: int):
    """Top-k retrieved item ids (B, k) for one padded batch."""
    from repro.core.nns import cosine_topk
    from repro.core.quantization import dequantize_rowwise

    if mode == "fp32":
        u = rs.user_tower(engine.params, engine.cfg, batch)
        _, top = cosine_topk(u, engine.params["item_table"], k)
        return top
    if mode == "int8":
        u, _, _ = _features(engine, batch)
        _, top = cosine_topk(u, dequantize_rowwise(engine.item_table_q), k)
        return top
    nns, _ = _filter_step(engine, batch)
    return nns.indices[:, :k]
