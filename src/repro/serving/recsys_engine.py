"""The end-to-end iMARS serving pipeline (paper Fig. 3 computation flow).

Deployment flow (Sec. III-B/C): take a *trained* YoutubeDNN, quantize every
ET to int8 (1a: tables into CMA banks), build 256-bit LSH signatures for the
ItET rows, then per query:

  (1a/1b*) sparse lookups + pooling through the fused int8 kernel path
  (1b/1c)  filtering DNN -> user embedding u_i
  (1d)     fixed-radius Hamming NNS over the ItET signatures -> candidates
  (2a-2d)  ranking: candidate embeddings + ranking UIETs -> CTR per item
  (2e)     CTR-buffer threshold top-k -> final items

The engine also composes the hardware cost model per query so every served
batch reports (latency_us, energy_uj) the iMARS fabric would have spent —
the software pipeline and the analytic model stay in lockstep.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core.embedding import embedding_bag, lookup
from repro.core.lsh import lsh_signature, make_lsh_projections
from repro.core.nns import NNSResult, fixed_radius_nns
from repro.core.quantization import QuantizedTensor, quantize_rowwise
from repro.core.topk import threshold_topk
from repro.models import recsys as rs


@dataclasses.dataclass
class RecSysEngine:
    cfg: rs.YoutubeDNNConfig
    tables_q: dict  # name -> QuantizedTensor (int8 UIETs)
    item_table_q: QuantizedTensor  # int8 ItET
    genre_table_q: QuantizedTensor
    item_sigs: jax.Array  # (n_items, 8) packed 256-bit LSH signatures
    params: dict  # trained MLP weights (crossbar stack)
    lsh_proj: jax.Array
    radius: int
    n_candidates: int
    top_k: int

    @staticmethod
    def build(params: dict, cfg: rs.YoutubeDNNConfig, *, lsh_bits: int = 256,
              radius: int = 96, n_candidates: int = 50, top_k: int = 10,
              key=None) -> "RecSysEngine":
        key = jax.random.key(7) if key is None else key
        tables_q = {k: quantize_rowwise(v) for k, v in params["tables"].items()}
        item_q = quantize_rowwise(params["item_table"])
        genre_q = quantize_rowwise(params["genre_table"])
        proj = make_lsh_projections(key, cfg.embed_dim, lsh_bits)
        # signatures of the int8-dequantized rows (what the CMA stores)
        from repro.core.quantization import dequantize_rowwise

        sigs = lsh_signature(dequantize_rowwise(item_q), proj)
        return RecSysEngine(
            cfg=cfg, tables_q=tables_q, item_table_q=item_q,
            genre_table_q=genre_q, item_sigs=sigs, params=params,
            lsh_proj=proj, radius=radius, n_candidates=n_candidates,
            top_k=top_k)

    # ------------------------------------------------------------------
    # stages
    # ------------------------------------------------------------------
    def user_embedding(self, batch: dict) -> jax.Array:
        """(1a)-(1c): quantized lookups/pooling + filtering DNN."""
        feats = []
        for name in sorted(self.cfg.user_features.keys()):
            ids = batch[name][:, None]
            feats.append(embedding_bag(self.tables_q[name], ids))
        pooled = embedding_bag(self.item_table_q, batch["history"],
                               mode="mean")
        feats.append(pooled)
        x = jnp.concatenate(feats, axis=-1)
        return rs._mlp_apply(self.params["filter_mlp"], x)

    def filter_stage(self, batch: dict) -> NNSResult:
        """(1d): fixed-radius Hamming NNS -> candidate item ids."""
        u = self.user_embedding(batch)
        q_sigs = lsh_signature(u, self.lsh_proj)
        return fixed_radius_nns(q_sigs, self.item_sigs, self.radius,
                                self.n_candidates)

    def rank_stage(self, batch: dict, cand: jax.Array):
        """(2a)-(2e): CTR per candidate + threshold top-k."""
        safe = jnp.maximum(cand, 0)
        items = lookup(self.item_table_q, safe)  # (B, N, d)
        genre = embedding_bag(self.genre_table_q, batch["genre"][:, None])
        pooled = embedding_bag(self.item_table_q, batch["history"],
                               mode="mean")
        u = self.user_embedding(batch)
        B, N = cand.shape
        ctx = jnp.concatenate([u, genre, pooled], axis=-1)
        x = jnp.concatenate(
            [jnp.broadcast_to(ctx[:, None], (B, N, ctx.shape[-1])), items],
            axis=-1)
        logits = rs._mlp_apply(self.params["rank_mlp"], x)[..., 0]
        ctr = jax.nn.sigmoid(logits)
        ctr = jnp.where(cand >= 0, ctr, -jnp.inf)  # mask padding candidates
        return threshold_topk(ctr, threshold=0.0, k=self.top_k)

    def serve(self, batch: dict):
        """Full query pipeline; returns (top-k result, candidates, cost)."""
        nns = self.filter_stage(batch)
        top = self.rank_stage(batch, nns.indices)
        final = jnp.where(top.indices >= 0,
                          jnp.take_along_axis(
                              nns.indices, jnp.maximum(top.indices, 0), 1),
                          -1)
        cost = self.query_cost()
        return final, top, nns, cost

    # ------------------------------------------------------------------
    # hardware cost accounting (per query)
    # ------------------------------------------------------------------
    def query_cost(self) -> cm.OpCost:
        e2e = cm.end_to_end_movielens(n_candidates=self.n_candidates)
        return cm.OpCost(latency_ns=e2e["imars_latency_us"] * 1e3,
                         energy_pj=e2e["imars_energy_uj"] * 1e6)


def hit_rate(engine: RecSysEngine, data, batch_size: int = 256,
             k: int = 10, mode: str = "lsh", max_users: int | None = None
             ) -> float:
    """YoutubeDNN leave-one-out HR@k over the test labels.

    mode: "fp32" (cosine, fp32 tables), "int8" (cosine over dequantized
    int8), "lsh" (the iMARS fixed-radius Hamming path) — the three accuracy
    configurations of paper Sec. IV-B.
    """
    from repro.core.nns import cosine_topk
    from repro.core.quantization import dequantize_rowwise

    n = data.n_users if max_users is None else min(max_users, data.n_users)
    hits = 0
    for lo in range(0, n, batch_size):
        idx = np.arange(lo, min(lo + batch_size, n))
        batch = {
            **{k2: jnp.asarray(v[idx]) for k2, v in data.user_feats.items()},
            "history": jnp.asarray(data.histories[idx]),
            "genre": jnp.asarray(data.genres[idx]),
        }
        if mode == "fp32":
            u = rs.user_tower(engine.params, engine.cfg, batch)
            _, top = cosine_topk(u, engine.params["item_table"], k)
            got = np.asarray(top)
        elif mode == "int8":
            u = engine.user_embedding(batch)
            _, top = cosine_topk(
                u, dequantize_rowwise(engine.item_table_q), k)
            got = np.asarray(top)
        else:  # lsh
            nns = engine.filter_stage(batch)
            got = np.asarray(nns.indices[:, :k])
        labels = data.test_labels[idx]
        hits += int((got == labels[:, None]).any(axis=1).sum())
    return hits / n
