"""One serving API for every front-end: the `Server` protocol + factory.

The serving tier grew three front-ends — the synchronous `MicroBatcher`,
the pipelined `AsyncServer` ring, and the threaded multi-tenant
`ConcurrentFrontend` — and three front-ends must not mean three divergent
submit/ticket/flush APIs. This module pins the one contract they all
implement and the one constructor call sites use:

    server = make_server(engine, mode="sync" | "pipelined" | "concurrent")
    ticket = server.submit(query, tenant=0)   # -> opaque int ticket
    served = server.result(ticket)            # ServedQuery(items, scores,
                                              #             status, tenant)
    server.flush()                            # drain everything submitted
    server.stats()                            # one dict schema, all modes
    server.close()                            # idempotent; submit() after
                                              # close raises ServerClosedError

Tickets are redeemed exactly once and `result()` never raises for an
overloaded request: admission failures come back as a ServedQuery whose
``status`` is ``"shed"`` (and drain-side typed failures as ``"error"``),
so a load-shedding path is an accounted outcome, not an exception that
kills a drain thread. Every *configuration* error, by contrast, is a typed
exception from the `ServingError` family below.

Bit-for-bit contract (tested in tests/test_server_protocol.py): for the
same engine and the same admitted query stream, every mode returns
identical items, scores, and hot-cache counters — front-ends move *time*
(batching, pipelining, threading), never results.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Protocol, runtime_checkable

if TYPE_CHECKING:  # pragma: no cover - type-only import, avoids a cycle
    from repro.serving.batcher import ServedQuery


# ---------------------------------------------------------------------------
# ticket status values (ServedQuery.status)
# ---------------------------------------------------------------------------
STATUS_OK = "ok"  # served by the engine
STATUS_SHED = "shed"  # rejected at admission (tenant queue full)
STATUS_ERROR = "error"  # typed serving failure surfaced through the ticket


# ---------------------------------------------------------------------------
# typed exceptions — the serving layer never raises bare asserts
# ---------------------------------------------------------------------------
class ServingError(RuntimeError):
    """Base class for every typed serving-tier failure."""


class ServerConfigError(ServingError):
    """A front-end was constructed or called with invalid knobs."""


class SchemaMismatchError(ServingError):
    """`swap_engine` was handed an engine with a different query schema."""


class ServerClosedError(ServingError):
    """submit() after close() — the server no longer admits queries."""


class QueueFullError(ServingError):
    """A bounded tenant queue is full and shedding is disabled."""


@runtime_checkable
class Server(Protocol):
    """The unified front-end contract (structural; see module docstring).

    `MicroBatcher` (mode="sync"), `AsyncServer` (mode="pipelined"), and
    `ConcurrentFrontend` (mode="concurrent") all conform; the parity suite
    in tests/test_server_protocol.py runs one query stream through all
    three and asserts bit-identical results and counters.
    """

    def submit(self, query: dict, *, tenant: int = 0) -> int:
        """Enqueue one user query; returns a ticket for `result()`."""
        ...

    def result(self, ticket: int, *, timeout: float | None = None
               ) -> "ServedQuery":
        """Redeem `ticket` (exactly once); blocks/flushes until resolved."""
        ...

    def serve_many(self, queries, *, tenant: int = 0) -> list["ServedQuery"]:
        """Submit + flush + collect, in submission order."""
        ...

    def flush(self) -> None:
        """Drain every submitted query to a resolved ticket."""
        ...

    def close(self) -> None:
        """Flush, then stop admitting queries (idempotent)."""
        ...

    def stats(self) -> dict:
        """One stats schema for every mode (see docs/SERVING.md) — the
        `stats_view` of `snapshot()`."""
        ...

    def snapshot(self) -> dict:
        """The full telemetry snapshot (docs/OBSERVABILITY.md)."""
        ...

    def take_trace(self) -> list:
        """Return-and-clear the completed-ticket `TicketTrace` records
        (each carries its stage-span chain when tracing is on)."""
        ...

    def swap_engine(self, engine) -> None:
        """Atomically swap engine epochs between buckets (LiveCatalog)."""
        ...


_MODES = ("sync", "pipelined", "concurrent")


def stats_view(snapshot: dict) -> dict:
    """The legacy `stats()` dict as a view over a telemetry `snapshot()`.

    Every front-end's `stats()` is this one function applied to its
    `MetricsRegistry.snapshot()` — the single place the unified key
    schema is defined, so the three modes can never drift apart again.
    All modes return the SAME key set; knobs that don't apply to a mode
    take their degenerate values (``depth=1`` / ``in_flight=0`` for the
    synchronous batcher, ``queue_depth=None`` / ``drain_chunk=None`` for
    the single-tenant front-ends). Derived ratios (`padding_fraction`,
    `cache_hit_rate`) are computed here, not stored.
    """
    served = int(snapshot.get("serving.served", 0))
    padded = int(snapshot.get("serving.padded", 0))
    hits = int(snapshot.get("cache.hits", 0))
    lookups = int(snapshot.get("cache.lookups", 0))
    total = served + padded
    drain = snapshot.get("serving.drain_chunk")
    return {
        "mode": snapshot.get("serving.mode"),
        "closed": bool(snapshot.get("serving.closed", False)),
        "n_submitted": int(snapshot.get("serving.submitted", 0)),
        "n_served": served,
        "n_shed": int(snapshot.get("serving.shed", 0)),
        "n_errors": int(snapshot.get("serving.errors", 0)),
        "n_pending": int(snapshot.get("serving.pending", 0)),
        "n_padded": padded,
        "n_batches": int(snapshot.get("serving.batches", 0)),
        "padding_fraction": padded / total if total else 0.0,
        "cache_hits": hits,
        "cache_lookups": lookups,
        "cache_hit_rate": hits / lookups if lookups else 0.0,
        "per_tenant": snapshot.get("serving.per_tenant", {}),
        "depth": int(snapshot.get("serving.ring_depth", 1)),
        "coalesce": int(snapshot.get("serving.coalesce", 1)),
        "in_flight": int(snapshot.get("serving.in_flight", 0)),
        "queue_depth": snapshot.get("serving.queue_depth"),
        "queued_now": snapshot.get("serving.queued_now", {}),
        "drain_chunk": None if drain is None else int(drain),
        "last_error": snapshot.get("serving.last_error"),
    }


def make_server(engine, mode: str = "sync", **knobs) -> "Server":
    """Construct a serving front-end by mode — the one public entry point.

    Args:
      engine: the `RecSysEngine` to serve from (local or sharded).
      mode: ``"sync"`` (MicroBatcher: one bucket at a time),
        ``"pipelined"`` (AsyncServer: depth-N ring of in-flight buckets),
        or ``"concurrent"`` (ConcurrentFrontend: per-tenant bounded queues
        draining through a thread into the pipelined ring, with admission
        control and load shedding).
      **knobs: mode-scoped keyword knobs —
        every mode: ``max_batch``, ``buckets``, ``trace`` (stage-span
        tracing, default True), ``registry`` (a shared
        `repro.obs.MetricsRegistry`; default: one per server);
        pipelined + concurrent: ``depth``, ``coalesce``;
        concurrent only: ``tenants``, ``queue_depth``, ``drain_chunk``,
        ``shed``, ``autostart``.
        An unknown knob (or a knob outside its mode) raises
        `ServerConfigError` instead of being silently ignored.

    Returns:
      a `Server`-conforming front-end.
    """
    from repro.serving.async_server import AsyncServer
    from repro.serving.batcher import MicroBatcher
    from repro.serving.frontend import ConcurrentFrontend

    classes = {"sync": MicroBatcher, "pipelined": AsyncServer,
               "concurrent": ConcurrentFrontend}
    every = {"max_batch", "buckets", "trace", "registry"}
    allowed = {
        "sync": every,
        "pipelined": every | {"depth", "coalesce"},
        "concurrent": every | {"depth", "coalesce", "tenants",
                               "queue_depth", "drain_chunk", "shed",
                               "autostart"},
    }
    if mode not in _MODES:
        raise ServerConfigError(
            f"unknown serving mode {mode!r}; expected one of {_MODES}")
    extra = set(knobs) - allowed[mode]
    if extra:
        raise ServerConfigError(
            f"knobs {sorted(extra)} are not valid for mode={mode!r} "
            f"(allowed: {sorted(allowed[mode])})")
    return classes[mode](engine, **knobs)
