"""Shadow serving: the freshness oracle for train-while-serve.

The live path (`serving/online.py`) folds embedding updates into a
serving engine incrementally — delta shard, tombstones, dense refreshes.
The only trustworthy way to prove those mechanics never cost
recommendation quality is to *shadow* them with the path that has no
mechanics at all: a *cold rebuild* of the trainer's current parameters,
quantized from scratch exactly like first deployment. This module holds
both halves:

  * `rebuild_from_params(engine, params)` — the params-level cold
    oracle. Where `catalog.rebuild_reference` materializes the live
    engine's *table*, this rebuilds from the *model*: every ET
    re-quantizes with the build-time transform, signatures recompute over
    the dequantized rows with the live engine's LSH projections, the
    summary cold-builds, and the hot tiers re-pin the live engine's
    pinned sets (bit-transparent either way). Same treedef and shapes as
    the live engine, so jitted eval steps never recompile.
  * `ShadowHarness` — replays one seeded eval stream (the dataset's
    leave-one-out users) against the live engine and the cold rebuild at
    every checkpoint, asserting HR@k tracks within `tol`, and snapshots
    the trainer's staleness counters between checkpoints.

Checkpoint contract: `checkpoint()` first makes every landed update
visible (``trainer.fold(); trainer.refresh_dense()``) — the assertion
then isolates the *serving-side incremental machinery* (delta overlay,
tombstones, hot tiers, refresh) from training noise: live and shadow
serve the same model, so any HR gap is a freshness-machinery bug, not an
optimizer artifact. Between checkpoints the live path really is stale
(that is the measured axis), so staleness rides along in each record.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.lsh import lsh_signature
from repro.core.nns import EMPTY_ID, build_block_summary
from repro.core.quantization import dequantize_rowwise, quantize_rowwise
from repro.serving.catalog import empty_delta
from repro.serving.hot_cache import pin_rows
from repro.serving.recsys_engine import filter_step, hit_rate


def rebuild_from_params(engine, params):
    """Frozen from-scratch engine over `params` with `engine`'s meta.

    The cold-deployment image of the trainer's current model: item/user/
    genre tables quantize row-wise from scratch, item signatures recompute
    over the dequantized int8 rows with the SAME LSH projections as the
    live engine, the block summary cold-builds, the delta is empty and
    every base row alive. Hot caches pin the live engine's current pinned
    ids over the fresh tables (the cache is bit-transparent; pinning the
    same set keeps `CacheStats` comparable too). Unsharded, like
    `catalog.rebuild_reference`.
    """
    item_q = quantize_rowwise(jnp.asarray(params["item_table"],
                                          jnp.float32))
    sigs = lsh_signature(dequantize_rowwise(item_q), engine.lsh_proj)
    tables_q = {k: quantize_rowwise(v) for k, v in params["tables"].items()}
    n = int(item_q.values.shape[0])

    def repin(cache, table):
        if cache is None or not cache.capacity:
            return cache
        ids = np.asarray(cache.hot_ids)
        return pin_rows(table, ids[ids != EMPTY_ID], cache.capacity)

    cap = engine.delta.capacity if engine.delta is not None else 0
    words = int(np.asarray(sigs).shape[1])
    br = (engine.block_summary.block_rows
          if engine.block_summary is not None else None)
    summary = (build_block_summary(np.asarray(sigs)) if br is None
               else build_block_summary(np.asarray(sigs), br))
    return dataclasses.replace(
        engine, params=params, item_table_q=item_q, item_sigs=sigs,
        tables_q=tables_q,
        genre_table_q=quantize_rowwise(params["genre_table"]),
        item_hot=repin(engine.item_hot, item_q),
        uiet_hot={k: repin(c, tables_q[k])
                  for k, c in engine.uiet_hot.items()},
        item_mask=jnp.ones((n,), jnp.bool_),
        block_summary=summary,
        delta=empty_delta(cap, int(item_q.values.shape[1]), words),
        nns_mesh=None, nns_axis=None, nns_query_axis=None)


class ShadowRecord(NamedTuple):
    """One shadow checkpoint: live vs cold-rebuilt quality + freshness."""

    step: int  # trainer steps at eval time
    hr_live: float  # HR@k of the continuously-updated live engine
    hr_ref: float  # HR@k of the cold rebuild of the current params
    gap: float  # abs(hr_live - hr_ref), asserted <= tol
    agree_frac: float  # top-k retrieval agreement on the probe batch
    staleness_ms: float  # mean staleness of steps folded since last eval
    eval_s: float  # wall time of this checkpoint (both evals)


class ShadowHarness:
    """Replays a seeded eval stream against live and shadow engines.

    Args:
      trainer: the `OnlineTrainer` under test (its catalog's engine is
        the live side; its params feed the cold rebuild).
      data: the `MovieLensSynth` dataset — the seeded query stream and
        leave-one-out labels (`recsys_engine.hit_rate` protocol).
      k / mode: HR@k configuration (mode="lsh" is the iMARS path).
      tol: max allowed ``abs(hr_live - hr_ref)`` per checkpoint.
      max_users: cap the eval stream (None = every user).
      probe_batch: users in the retrieval-agreement probe (0 disables).

    `checkpoint()` raises `AssertionError` the moment the live path's
    quality leaves the tolerance band — benchmarks run it in-line as a
    hard gate, tests call it directly.
    """

    def __init__(self, trainer, data, *, k: int = 10, mode: str = "lsh",
                 tol: float = 0.01, max_users: int | None = None,
                 probe_batch: int = 256):
        self.trainer = trainer
        self.data = data
        self.k = int(k)
        self.mode = mode
        self.tol = float(tol)
        self.max_users = max_users
        self.probe_batch = min(int(probe_batch), data.n_users)
        self.records: list[ShadowRecord] = []
        self._staleness_lo = 0  # trainer.staleness_ms cursor

    def _probe_agreement(self, live, ref) -> float:
        """Fraction of top-k retrieved ids both engines agree on, over
        one fixed probe batch — the replayed-stream texture behind the
        scalar HR (order-sensitive, position by position)."""
        if not self.probe_batch:
            return 1.0
        idx = np.arange(self.probe_batch)
        batch = {
            **{kk: jnp.asarray(v[idx])
               for kk, v in self.data.user_feats.items()},
            "history": jnp.asarray(self.data.histories[idx]),
            "genre": jnp.asarray(self.data.genres[idx]),
        }
        got = np.asarray(filter_step(live, batch)[0].indices[:, : self.k])
        want = np.asarray(filter_step(ref, batch)[0].indices[:, : self.k])
        return float((got == want).mean())

    def checkpoint(self) -> ShadowRecord:
        """Sync the live path, eval both sides, assert the gap, record.

        Folds pending updates and refreshes dense params first — the
        checkpoint compares *current model served incrementally* against
        *current model served from a cold rebuild*.
        """
        t0 = time.perf_counter()
        t = self.trainer
        t.fold()
        t.refresh_dense()
        live = t.catalog.engine
        ref = rebuild_from_params(live, t.params)
        hr_live = hit_rate(live, self.data, k=self.k, mode=self.mode,
                           max_users=self.max_users)
        hr_ref = hit_rate(ref, self.data, k=self.k, mode=self.mode,
                          max_users=self.max_users)
        gap = abs(hr_live - hr_ref)
        lat = t.staleness_ms[self._staleness_lo:]
        self._staleness_lo = len(t.staleness_ms)
        rec = ShadowRecord(
            step=t.steps_done, hr_live=hr_live, hr_ref=hr_ref, gap=gap,
            agree_frac=self._probe_agreement(live, ref),
            staleness_ms=float(np.mean(lat)) if lat else 0.0,
            eval_s=time.perf_counter() - t0)
        self.records.append(rec)
        assert gap <= self.tol, (
            f"shadow checkpoint at step {t.steps_done}: live HR@{self.k} "
            f"{hr_live:.4f} vs cold-rebuilt {hr_ref:.4f} — gap {gap:.4f} "
            f"exceeds tol {self.tol}")
        return rec
