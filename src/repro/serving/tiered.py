"""Frequency-tiered out-of-core catalog: disk -> int8 RAM pool -> f32 hot.

iMARS keeps every embedding-table row resident in the CMA fabric; at
100M-item scale one host cannot. RecFlash's answer — and this module's —
is a residency *hierarchy* driven by measured lookup frequency (RecNMP:
production embedding traffic is heavily skewed):

  * **cold base shard** (`BaseShard`) — the full int8 catalog (values,
    scales, LSH signatures) in memory-mapped files. Nothing is resident
    until touched; the streaming NNS reaches it through
    `core.nns.out_of_core_nns`, which gathers only summary blocks at
    least one query admits, so scan residency tracks the admitted working
    set, not the catalog;
  * **int8 pool** — a pure byte-cache of the hottest P rows, RAM-resident
    so popular history/candidate lookups never fault a disk page. Pool
    bytes are verbatim copies of shard bytes, so the tier can never
    change a served bit;
  * **f32 hot cache** — the existing `HotRowCache` over the hottest
    H <= P rows (hot is a prefix of the pool by construction, so every
    hot lookup is also pool-resident);
  * a bounded **delta shard** (`serving/catalog.py` semantics, verbatim)
    holds pending upserts; touched ids are evicted from BOTH caches the
    moment they change, keeping `delta ∩ hot = ∅` and the pool honest.

Row resolution order per served id: delta > pool > disk, with the hot
cache consulted exactly as the all-RAM engine consults it. Serving is
host-driven in three stages mirroring `recsys_engine`'s staged split —
the host builds one per-batch *overlay* (the bytes every requested id
resolves to), and jitted mirrors of `_features` / `_rank` consume it with
op-for-op the same computation as the all-RAM path, so results AND
`CacheStats` counters bit-match the all-RAM engine over the same state
(tested against `to_ram_engine()` / `rebuild_reference()`).

Promotion/demotion (`rebalance`) recomputes the pool and hot tiers from
the measured `item_freqs` counters — frequency descending, ties by
ascending id (`hot_cache.top_ids_by_freq`, the one tier-selection order)
— and rides epoch compaction (`compact()`), which streams base + delta
into a fresh shard epoch exactly like `catalog.materialize` (same
canonical zero-row quantization for id gaps, same scatter), then
migrates tiers against the new epoch.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lsh import lsh_signature
from repro.core.nns import (
    EMPTY_ID,
    SUMMARY_BLOCK_ROWS,
    BlockSummary,
    build_block_summary,
    delta_scan,
    merge_delta_candidates,
    out_of_core_nns,
    update_block_summary,
)
from repro.core.quantization import (
    QuantizedTensor,
    dequantize_rowwise,
    quantize_rowwise,
)
from repro.core.topk import threshold_topk
from repro.core.embedding import embedding_bag
from repro.kernels.ops import madvise_dontneed, madvise_random
from repro.models import recsys as rs
from repro.serving.catalog import (
    DeltaFullError,
    DeltaShard,
    empty_delta,
    delta_n_live,
    quantize_updates,
)
from repro.serving.hot_cache import (
    CacheStats,
    HotRowCache,
    _probe,
    cached_embedding_bag,
    invalidate_rows,
    pool_rows,
    top_ids_by_freq,
)
from repro.serving.recsys_engine import ServeResult

_META = "meta.json"
_FILES = {"values": ("values.int8.bin", np.int8),
          "scales": ("scales.f32.bin", np.float32),
          "sigs": ("sigs.u32.bin", np.uint32)}


# ---------------------------------------------------------------------------
# cold base shard: memmapped (values, scales, sigs) + sidecar state
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BaseShard:
    """One read-only on-disk catalog epoch, opened as memmaps.

    `values` (n, d) int8 / `scales` (n, 1) f32 / `sigs` (n, words) uint32
    are `np.memmap`s — indexing them faults in only the touched pages.
    The shard is immutable once written; mutation happens in the delta
    shard and lands in a NEW epoch directory at compaction.
    """

    directory: str
    n: int
    d: int
    words: int
    values: np.memmap
    scales: np.memmap
    sigs: np.memmap


class BaseShardWriter:
    """Chunked writer for a `BaseShard` epoch directory.

    `write(lo, values, scales, sigs)` scatters one row-chunk;
    `finish(alive=..., summary=...)` persists the sidecars (alive mask,
    precomputed block summary — computed at write time so opening the
    shard never has to fault in every signature page) and the meta file.
    """

    def __init__(self, directory: str, n: int, d: int, words: int):
        os.makedirs(directory, exist_ok=True)
        self.directory, self.n, self.d, self.words = directory, n, d, words
        shapes = {"values": (n, d), "scales": (n, 1), "sigs": (n, words)}
        self._maps = {
            key: np.memmap(os.path.join(directory, fname), dtype=dtype,
                           mode="w+", shape=shapes[key])
            for key, (fname, dtype) in _FILES.items()}

    def write(self, lo: int, values, scales, sigs) -> None:
        hi = lo + len(values)
        self._maps["values"][lo:hi] = np.asarray(values, np.int8)
        self._maps["scales"][lo:hi] = np.asarray(
            scales, np.float32).reshape(-1, 1)
        self._maps["sigs"][lo:hi] = np.asarray(sigs, np.uint32)

    def finish(self, alive=None, summary: BlockSummary | None = None) -> None:
        for m in self._maps.values():
            m.flush()
        if alive is None:
            alive = np.ones((self.n,), bool)
        np.save(os.path.join(self.directory, "alive.npy"),
                np.asarray(alive, bool))
        if summary is not None:
            np.savez(os.path.join(self.directory, "summary.npz"),
                     or_sigs=np.asarray(summary.or_sigs),
                     and_sigs=np.asarray(summary.and_sigs),
                     min_pc=np.asarray(summary.min_pc),
                     max_pc=np.asarray(summary.max_pc),
                     n_alive=np.asarray(summary.n_alive),
                     block_rows=np.int64(summary.block_rows))
        meta = {"n": self.n, "d": self.d, "words": self.words, "version": 1}
        with open(os.path.join(self.directory, _META), "w") as f:
            json.dump(meta, f)
        self._maps = {}


def write_base_shard(directory: str, values, scales, sigs, *, alive=None,
                     summary: BlockSummary | None = None) -> None:
    """One-shot shard write (small catalogs / tests); the 8M+ benchmark
    path streams chunks through `BaseShardWriter` instead."""
    values = np.asarray(values)
    w = BaseShardWriter(directory, values.shape[0], values.shape[1],
                        np.asarray(sigs).shape[1])
    w.write(0, values, scales, sigs)
    w.finish(alive=alive, summary=summary)


def pread_rows(mm: np.memmap, ids) -> np.ndarray:
    """Scattered row gather from a memmap via `os.pread`, not the mapping.

    `mm[ids]` on scattered ids is an RSS trap: each 4KB fault maps its
    fault-around window (up to 64KB of neighbour pages whenever they are
    in the global page cache — fault-around ignores MADV_RANDOM), so a
    few thousand candidate-row faults can pin hundreds of MB. pread
    copies exactly the requested bytes into an anonymous buffer and maps
    nothing. Duplicate ids are read once. Falls back to the mapping for
    non-file-backed arrays.
    """
    ids = np.asarray(ids, np.int64).reshape(-1)
    fname = getattr(mm, "filename", None)
    if fname is None:
        return np.asarray(mm[ids])
    uniq, inv = np.unique(ids, return_inverse=True)
    row = int(np.prod(mm.shape[1:], dtype=np.int64)) * mm.dtype.itemsize
    base = int(getattr(mm, "offset", 0))
    out = np.empty((uniq.size,) + mm.shape[1:], mm.dtype)
    flat = out.reshape(uniq.size, -1).view(np.uint8)
    fd = os.open(fname, os.O_RDONLY)
    try:
        for i, r in enumerate(uniq):
            flat[i] = np.frombuffer(
                os.pread(fd, row, base + int(r) * row), np.uint8)
    finally:
        os.close(fd)
    return out[inv]


def open_base_shard(directory: str):
    """-> (BaseShard, alive (n,) bool ndarray, BlockSummary | None).

    The memmaps open read-only; `alive` loads fully (1 byte/row — the one
    O(n) RAM sidecar) and the summary, if the writer persisted one, loads
    without touching a single signature page.
    """
    with open(os.path.join(directory, _META)) as f:
        meta = json.load(f)
    n, d, words = meta["n"], meta["d"], meta["words"]
    shapes = {"values": (n, d), "scales": (n, 1), "sigs": (n, words)}
    maps = {key: np.memmap(os.path.join(directory, fname), dtype=dtype,
                           mode="r", shape=shapes[key])
            for key, (fname, dtype) in _FILES.items()}
    for m in maps.values():
        # scattered row faults must not drag in 128KB of readahead each
        madvise_random(m)
    shard = BaseShard(directory=directory, n=n, d=d, words=words, **maps)
    alive = np.load(os.path.join(directory, "alive.npy"))
    summary = None
    spath = os.path.join(directory, "summary.npz")
    if os.path.exists(spath):
        z = np.load(spath)
        summary = BlockSummary(
            or_sigs=jnp.asarray(z["or_sigs"]),
            and_sigs=jnp.asarray(z["and_sigs"]),
            min_pc=jnp.asarray(z["min_pc"]),
            max_pc=jnp.asarray(z["max_pc"]),
            n_alive=jnp.asarray(z["n_alive"]),
            block_rows=int(z["block_rows"]))
    return shard, alive, summary


# ---------------------------------------------------------------------------
# jitted serve mirrors over the per-batch overlay
# ---------------------------------------------------------------------------
def _overlay_rows(cache: HotRowCache | None, ov_ids, ov_vals, ov_scales,
                  ids):
    """Tiered mirror of `catalog.delta_cached_rows` over an overlay.

    The overlay (`ov_ids` sorted ascending int32 with `EMPTY_ID` padding,
    `ov_vals`/`ov_scales` the int8 bytes each id resolves to) carries the
    delta > pool > disk resolution the host performed for every id this
    batch can request; ids absent from it (out-of-catalog) read zero rows.
    The hot cache is probed exactly like the all-RAM path — hot rows are
    pinned dequantized base bytes, disjoint from the delta — so rows AND
    CacheStats come out bit-identical to `delta_cached_rows` on the
    equivalent all-RAM engine.
    """
    valid = ids >= 0
    pos = jnp.searchsorted(ov_ids, ids)
    pos = jnp.clip(pos, 0, ov_ids.shape[0] - 1)
    found = (ov_ids[pos] == ids) & valid
    cold = ov_vals[pos].astype(jnp.float32) * ov_scales[pos]
    lookups = jnp.sum(valid).astype(jnp.int32)
    if cache is None or cache.capacity == 0:
        rows = jnp.where(found[..., None], cold, 0.0)
        return rows, CacheStats(hits=jnp.int32(0), lookups=lookups)
    hit, hpos = _probe(cache, ids)
    rows = jnp.where(hit[..., None], cache.hot_rows[hpos], cold)
    rows = jnp.where(found[..., None], rows, 0.0)
    return rows, CacheStats(hits=jnp.sum(hit).astype(jnp.int32),
                            lookups=lookups)


def _tiered_lookup(inner, batch, ov_ids, ov_vals, ov_scales):
    """Mirror of `recsys_engine._features` (+ the query signing of
    `_scan_stage`): UIET lookups stay all-RAM; history rows resolve
    through the overlay. -> (u, pooled, q_sigs, stats)."""
    valid = batch.get("valid")

    def mask(ids):
        if valid is None:
            return ids
        return jnp.where(valid[:, None], ids, -1)

    stats = CacheStats.zero()
    feats = []
    for name in sorted(inner.cfg.user_features.keys()):
        emb, st = cached_embedding_bag(
            inner.uiet_hot.get(name), inner.tables_q[name],
            mask(batch[name][:, None]))
        feats.append(emb)
        stats = stats + st
    hist = mask(batch["history"])
    rows, st = _overlay_rows(inner.item_hot, ov_ids, ov_vals, ov_scales,
                             hist)
    pooled = pool_rows(rows, hist, None, "mean")
    stats = stats + st
    feats.append(pooled)
    x = jnp.concatenate(feats, axis=-1)
    u = rs._mlp_apply(inner.params["filter_mlp"], x)
    return u, pooled, lsh_signature(u, inner.lsh_proj), stats


def _tiered_rank(inner, batch, cand, u, pooled, ov_ids, ov_vals, ov_scales):
    """Mirror of `recsys_engine._rank` + the final-id selection of
    `_rank_stage`, with candidate rows resolved through the overlay.
    -> (final_items, topk, stats)."""
    valid = batch.get("valid")
    if valid is not None:
        cand = jnp.where(valid[:, None], cand, -1)
    items, st = _overlay_rows(inner.item_hot, ov_ids, ov_vals, ov_scales,
                              cand)
    genre = embedding_bag(inner.genre_table_q, batch["genre"][:, None])
    B, N = cand.shape
    ctx = jnp.concatenate([u, genre, pooled], axis=-1)
    x = jnp.concatenate(
        [jnp.broadcast_to(ctx[:, None], (B, N, ctx.shape[-1])), items],
        axis=-1)
    logits = rs._mlp_apply(inner.params["rank_mlp"], x)[..., 0]
    ctr = jax.nn.sigmoid(logits)
    ctr = jnp.where(cand >= 0, ctr, -jnp.inf)
    top = threshold_topk(ctr, threshold=0.0, k=inner.top_k)
    final = jnp.where(
        top.indices >= 0,
        jnp.take_along_axis(cand, jnp.maximum(top.indices, 0), 1), -1)
    return final, top, st


_tiered_lookup_jit = jax.jit(_tiered_lookup)
_tiered_rank_jit = jax.jit(_tiered_rank)
_delta_scan_jit = jax.jit(delta_scan, static_argnums=(3, 4))
_merge_jit = jax.jit(merge_delta_candidates, static_argnums=(2,))


# ---------------------------------------------------------------------------
# the tiered catalog front door
# ---------------------------------------------------------------------------
class TieredCatalog:
    """Host-driven tiered serving over a memmapped base shard.

    Holds the cold `BaseShard`, the int8 pool + f32 hot tiers, the bounded
    delta shard, the block summary, the alive mask, and measured per-row
    lookup frequencies. `serve()` runs the three-stage pipeline described
    in the module docstring and bit-matches `to_ram_engine().serve()` —
    the all-RAM engine over identical state — results and counters alike.

    `inner` is a `RecSysEngine` whose USER-side leaves (UIET tables, MLP
    params, genre table, LSH projections, hot caches) are real and whose
    item table/signatures are 1-row placeholders — item bytes live on
    disk, in the pool, or in the delta, never as an engine leaf.
    """

    def __init__(self, directory: str, shard: BaseShard, inner, *,
                 alive, summary, pool_rows: int, item_freqs=None,
                 delta_capacity: int = 1024, auto_compact: bool = True,
                 registry=None):
        if inner.nns_mesh is not None:
            raise ValueError("TieredCatalog serving is host-driven; "
                             "use an unsharded engine")
        self.directory = directory
        self.base = shard
        self.alive = np.asarray(alive, bool).copy()
        self.summary = summary
        self.auto_compact = auto_compact
        self.epoch = 0
        n = shard.n
        # A matching (n,) int64 array is ADOPTED (observe() mutates it in
        # place) — at 100M-scale a defensive copy is another 800MB of
        # residency for nothing; callers wanting isolation pass a copy.
        freqs_in = None if item_freqs is None else np.asarray(item_freqs)
        if (freqs_in is not None and freqs_in.shape == (n,)
                and freqs_in.dtype == np.int64 and freqs_in.flags.writeable):
            self.item_freqs = freqs_in
        else:
            self.item_freqs = np.zeros((n,), np.int64)
            if freqs_in is not None:
                m = min(len(freqs_in), n)
                self.item_freqs[:m] = freqs_in[:m]
        self.n_observed = int(self.item_freqs.sum())
        self.delta = empty_delta(delta_capacity, shard.d, shard.words)
        # tiers: pool = top-P by measured frequency, hot = top-H prefix
        self._pool_capacity = int(pool_rows)
        hot_cap = inner.item_hot.capacity if inner.item_hot is not None \
            else 0
        if hot_cap > self._pool_capacity:
            raise ValueError(
                f"hot capacity {hot_cap} exceeds pool capacity "
                f"{self._pool_capacity}: the hot tier must be a subset "
                f"of the pool")
        self.pool_ids = np.zeros((0,), np.int32)
        self.pool_vals = np.zeros((0, shard.d), np.int8)
        self.pool_scales = np.zeros((0, 1), np.float32)
        self.inner = inner
        self.rebalance()
        # telemetry (host counters; never affect results)
        self.n_compactions = 0
        self.pool_hits = 0
        self.delta_hits = 0
        self.disk_rows = 0
        self.last_compact_s = 0.0
        # optional metrics sink (repro.obs.MetricsRegistry): tier
        # residency + hit mix ride whoever's snapshot() as tiered.* keys
        self.registry = registry
        if registry is not None:
            registry.register_collector(self._collect)

    def _collect(self, reg) -> None:
        """Snapshot-time collector: tier residency + hit-mix gauges."""
        reg.gauge("tiered.epoch", self.epoch)
        reg.gauge("tiered.compactions", self.n_compactions)
        reg.gauge("tiered.last_compact_s", self.last_compact_s)
        reg.gauge("tiered.pool_hits", self.pool_hits)
        reg.gauge("tiered.delta_hits", self.delta_hits)
        reg.gauge("tiered.disk_rows", self.disk_rows)
        reg.gauge("tiered.pool_rows", int(self.pool_ids.size))
        reg.gauge("tiered.delta_pending", self.n_pending)
        reg.gauge("tiered.resident_bytes", self.resident_bytes())

    # -- construction --------------------------------------------------
    @classmethod
    def open(cls, directory: str, engine, *, pool_rows: int = 0,
             item_freqs=None, delta_capacity: int = 1024,
             auto_compact: bool = True, registry=None) -> "TieredCatalog":
        """Open the latest shard epoch under `directory` and serve it.

        `engine` supplies the user-side model state (params, UIETs, knobs,
        hot-cache capacity); its item table/sigs leaves are discarded for
        1-row placeholders — at 100M-scale the caller builds it over a
        tiny placeholder item table and never materializes the real one.
        """
        epochs = sorted((e for e in os.listdir(directory)
                         if e.startswith("epoch_")),
                        key=lambda e: int(e.split("_")[1]))
        if not epochs:
            raise FileNotFoundError(f"no epoch_* shard under {directory}")
        shard, alive, summary = open_base_shard(
            os.path.join(directory, epochs[-1]))
        if summary is None:
            summary = build_block_summary(
                np.asarray(shard.sigs), SUMMARY_BLOCK_ROWS, db_mask=alive)
        hot_cap = engine.item_hot.capacity if engine.item_hot is not None \
            else 0
        inner = dataclasses.replace(
            engine,
            item_table_q=QuantizedTensor(
                values=jnp.zeros((1, shard.d), jnp.int8),
                scales=jnp.zeros((1, 1), jnp.float32)),
            item_sigs=jnp.zeros((1, shard.words), jnp.uint32),
            item_hot=HotRowCache(hot_ids=jnp.full((hot_cap,), EMPTY_ID,
                                                  jnp.int32),
                                 hot_rows=jnp.zeros((hot_cap, shard.d),
                                                    jnp.float32),
                                 capacity=hot_cap)
            if hot_cap else engine.item_hot,
            item_mask=None, delta=None, block_summary=None)
        cat = cls(directory, shard, inner, alive=alive, summary=summary,
                  pool_rows=pool_rows, item_freqs=item_freqs,
                  delta_capacity=delta_capacity, auto_compact=auto_compact,
                  registry=registry)
        cat.epoch = int(epochs[-1].split("_")[1])
        return cat

    @classmethod
    def from_engine(cls, engine, directory: str, *, pool_rows: int = 0,
                    item_freqs=None, delta_capacity: int = 1024,
                    auto_compact: bool = True, registry=None
                    ) -> "TieredCatalog":
        """Spill an all-RAM engine's item table to an epoch-0 shard and
        serve it tiered (the small-catalog / test construction path)."""
        sigs = np.asarray(engine.item_sigs)
        n = int(engine.item_table_q.values.shape[0])
        alive = (np.ones((n,), bool) if engine.item_mask is None
                 else np.asarray(engine.item_mask)[:n])
        summary = build_block_summary(sigs[:n], SUMMARY_BLOCK_ROWS,
                                      db_mask=alive)
        write_base_shard(
            os.path.join(directory, "epoch_0"),
            np.asarray(engine.item_table_q.values)[:n],
            np.asarray(engine.item_table_q.scales)[:n], sigs[:n],
            alive=alive, summary=summary)
        return cls.open(directory, engine, pool_rows=pool_rows,
                        item_freqs=item_freqs, delta_capacity=delta_capacity,
                        auto_compact=auto_compact, registry=registry)

    # -- tier mechanics ------------------------------------------------
    def _resolve_bytes(self, ids: np.ndarray, *, use_delta: bool = True):
        """Host resolution of `ids` -> (present, vals, scales) through
        delta > pool > disk. Tombstoned base ids still resolve to their
        (stale) base bytes — mirroring `delta_cached_rows`, which ignores
        the alive mask on the cold path; retrieval correctness rests on
        the NNS mask, not the row gather."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        m = ids.size
        vals = np.zeros((m, self.base.d), np.int8)
        scales = np.zeros((m, 1), np.float32)
        valid = ids >= 0
        safe = np.maximum(ids, 0)
        in_delta = np.zeros(m, bool)
        dids = np.asarray(self.delta.ids)
        if use_delta and dids.size:
            pos = np.clip(np.searchsorted(dids, safe), 0, dids.size - 1)
            in_delta = valid & (dids[pos] == ids)
            if in_delta.any():
                dvals = np.asarray(self.delta.values)
                dscales = np.asarray(self.delta.scales)
                vals[in_delta] = dvals[pos[in_delta]]
                scales[in_delta] = dscales[pos[in_delta]]
        in_pool = np.zeros(m, bool)
        if self.pool_ids.size:
            ppos = np.clip(np.searchsorted(self.pool_ids, safe), 0,
                           self.pool_ids.size - 1)
            in_pool = valid & ~in_delta & (self.pool_ids[ppos] == ids)
            if in_pool.any():
                vals[in_pool] = self.pool_vals[ppos[in_pool]]
                scales[in_pool] = self.pool_scales[ppos[in_pool]]
        in_disk = valid & ~in_delta & ~in_pool & (ids < self.base.n)
        if in_disk.any():
            didx = ids[in_disk]
            vals[in_disk] = pread_rows(self.base.values, didx)
            scales[in_disk] = pread_rows(self.base.scales, didx)
        self.delta_hits += int(in_delta.sum())
        self.pool_hits += int(in_pool.sum())
        self.disk_rows += int(in_disk.sum())
        return (in_delta | in_pool | in_disk), vals, scales

    def _build_overlay(self, ids):
        """ids (any int shape) -> (ov_ids, ov_vals, ov_scales) on device:
        the sorted byte overlay `_overlay_rows` probes. Fixed size
        (= ids.size) per bucket shape, so the jitted mirrors compile once.
        """
        flat = np.asarray(ids, np.int64).reshape(-1)
        present, vals, scales = self._resolve_bytes(flat)
        ov_ids = np.where(present, flat, np.int64(EMPTY_ID)).astype(np.int32)
        order = np.argsort(ov_ids, kind="stable")
        return (jnp.asarray(ov_ids[order]), jnp.asarray(vals[order]),
                jnp.asarray(scales[order]))

    def rebalance(self) -> None:
        """Recompute pool + hot membership from `item_freqs`.

        Promotion and demotion in one move: pool = top-P alive base rows
        by (frequency desc, id asc), hot = the top-H prefix of that same
        ranking (hot ⊆ pool — every f32-pinned row is also byte-resident).
        Pending delta ids never pin (delta ∩ hot = ∅ is the resolution
        contract) and tombstoned rows are ineligible. Pure residency
        movement: pinned bytes are verbatim shard bytes and the hot rows
        their exact dequantization, so serving results cannot change —
        only the hit counters and the resident set do.
        """
        eligible = self.alive.copy()
        dids = np.asarray(self.delta.ids)
        dids = dids[dids != EMPTY_ID]
        eligible[dids[dids < self.base.n]] = False
        ranked = top_ids_by_freq(self.item_freqs[: self.base.n],
                                 self._pool_capacity, eligible=eligible)
        order = np.argsort(ranked, kind="stable")
        self.pool_ids = ranked[order].astype(np.int32)
        self.pool_vals = pread_rows(self.base.values, self.pool_ids)
        self.pool_scales = pread_rows(self.base.scales, self.pool_ids)
        cache = self.inner.item_hot
        if cache is not None and cache.capacity:
            hot = np.sort(ranked[: cache.capacity]).astype(np.int32)
            hot_ids = np.full((cache.capacity,), EMPTY_ID, np.int32)
            hot_ids[: hot.size] = hot
            rows = np.zeros((cache.capacity, self.base.d), np.float32)
            if hot.size:
                hpos = np.searchsorted(self.pool_ids, hot)
                rows[: hot.size] = np.asarray(dequantize_rowwise(
                    QuantizedTensor(
                        values=jnp.asarray(self.pool_vals[hpos]),
                        scales=jnp.asarray(self.pool_scales[hpos]))))
            self.inner = dataclasses.replace(
                self.inner, item_hot=HotRowCache(
                    hot_ids=jnp.asarray(hot_ids), hot_rows=jnp.asarray(rows),
                    capacity=cache.capacity))

    def observe(self, ids) -> None:
        """Count serve-path lookups (`LiveCatalog.observe` semantics)."""
        ids = np.asarray(ids).reshape(-1)
        ids = ids[(ids >= 0) & (ids < EMPTY_ID)]
        if not ids.size:
            return
        hi = int(ids.max()) + 1
        if hi > self.item_freqs.shape[0]:
            grown = np.zeros((hi,), np.int64)
            grown[: self.item_freqs.shape[0]] = self.item_freqs
            self.item_freqs = grown
        np.add.at(self.item_freqs, ids, 1)
        self.n_observed += int(ids.size)

    # -- serving -------------------------------------------------------
    def serve(self, batch: dict) -> ServeResult:
        """Serve one padded batch (the `RecSysEngine.serve` schema) from
        the tiered store; bit-matches `to_ram_engine().serve(batch)`."""
        hist_np = np.asarray(batch["history"])
        batch_j = {k: jnp.asarray(v) for k, v in batch.items()}
        ov = self._build_overlay(hist_np)
        u, pooled, q_sigs, stats = _tiered_lookup_jit(
            self.inner, batch_j, *ov)
        base = out_of_core_nns(
            q_sigs, self.base.sigs, self.inner.radius,
            self.inner.n_candidates, db_mask=self.alive,
            scan_block=self.inner.scan_block, summary=self.summary,
            prune=self.inner.prune)
        pending = _delta_scan_jit(q_sigs, self.delta.sigs, self.delta.ids,
                                  self.inner.radius, self.inner.n_candidates)
        nns = _merge_jit(base, pending, self.inner.n_candidates)
        cand_np = np.asarray(nns.indices)
        ov2 = self._build_overlay(cand_np)
        final, top, st = _tiered_rank_jit(
            self.inner, batch_j, nns.indices, u, pooled, *ov2)
        final_np = np.asarray(final)
        self.observe(np.concatenate(
            [hist_np.reshape(-1).astype(np.int64),
             final_np.reshape(-1).astype(np.int64)]))
        # the RAM tiers ARE the cache: base pages faulted for this batch's
        # cold rows (overlay byte resolution; the NNS drops its own) are
        # copied out already, so evict them — resident stays O(batch
        # working set), never O(every page ever touched)
        for m in (self.base.values, self.base.scales):
            madvise_dontneed(m)
        return ServeResult(items=final, topk=top, nns=nns,
                           cost=self.inner.query_cost(), stats=stats + st)

    # -- mutation ------------------------------------------------------
    def apply_updates(self, upsert_ids=None, upsert_rows=None,
                      delete_ids=None) -> None:
        """`catalog.engine_apply_updates` semantics against the tiered
        state: updates fold into the sorted delta shard, touched base rows
        tombstone + leave BOTH caches (pool and hot — their bytes are
        stale the moment the row changes), and the block summary's touched
        blocks recompute exactly. Forces a compaction when the delta is
        full (unless `auto_compact=False`)."""
        try:
            self._apply_updates(upsert_ids, upsert_rows, delete_ids)
        except DeltaFullError:
            if not self.auto_compact:
                raise
            self.compact()
            self._apply_updates(upsert_ids, upsert_rows, delete_ids)

    def upsert(self, ids, rows) -> None:
        self.apply_updates(upsert_ids=ids, upsert_rows=rows)

    def delete(self, ids) -> None:
        self.apply_updates(delete_ids=ids)

    def _apply_updates(self, upsert_ids, upsert_rows, delete_ids) -> None:
        delta, n_base = self.delta, self.base.n
        live: dict[int, tuple] = {}
        ids_np = np.asarray(delta.ids)
        vals_np, scales_np, sigs_np = (np.asarray(delta.values),
                                       np.asarray(delta.scales),
                                       np.asarray(delta.sigs))
        for slot in np.nonzero(ids_np != EMPTY_ID)[0]:
            live[int(ids_np[slot])] = (vals_np[slot], scales_np[slot],
                                       sigs_np[slot])
        touched: list[int] = []
        mask = self.alive
        if delete_ids is not None:
            for gid in np.asarray(delete_ids, np.int64).reshape(-1):
                gid = int(gid)
                live.pop(gid, None)
                if gid < n_base:
                    mask[gid] = False
                touched.append(gid)
        if upsert_ids is not None:
            ids_arr = np.asarray(upsert_ids, np.int64).reshape(-1)
            if np.any(ids_arr < 0) or np.any(ids_arr >= EMPTY_ID):
                raise ValueError(f"item ids must be in [0, {EMPTY_ID})")
            uvals, uscales, usigs = quantize_updates(self.inner, upsert_rows)
            if len(ids_arr) != len(uvals):
                raise ValueError(f"{len(ids_arr)} ids vs {len(uvals)} rows")
            for i, gid in enumerate(ids_arr):
                gid = int(gid)
                live[gid] = (uvals[i], uscales[i], usigs[i])
                if gid < n_base:
                    mask[gid] = False
                touched.append(gid)
        if len(live) > delta.capacity:
            raise DeltaFullError(
                f"{len(live)} pending rows > delta capacity {delta.capacity}")

        base_touched = [g for g in touched if g < n_base]
        if base_touched:
            self.summary = update_block_summary(
                self.summary, np.asarray(self.base.sigs), mask, base_touched)

        ids_out = np.full(delta.capacity, EMPTY_ID, np.int32)
        vals_out = np.zeros((delta.capacity, self.base.d), np.int8)
        scales_out = np.zeros((delta.capacity, 1), np.float32)
        sigs_out = np.zeros((delta.capacity, self.base.words), np.uint32)
        for slot, gid in enumerate(sorted(live)):
            v, s, g = live[gid]
            ids_out[slot], vals_out[slot] = gid, v
            scales_out[slot], sigs_out[slot] = s, g
        self.delta = DeltaShard(ids=jnp.asarray(ids_out),
                                values=jnp.asarray(vals_out),
                                scales=jnp.asarray(scales_out),
                                sigs=jnp.asarray(sigs_out),
                                capacity=delta.capacity)
        if touched:
            t = np.asarray(touched)
            # evict stale bytes from both RAM tiers (delta ∩ {hot, pool} = ∅)
            self.inner = dataclasses.replace(
                self.inner,
                item_hot=invalidate_rows(self.inner.item_hot, t))
            keep = ~np.isin(self.pool_ids, t)
            if not keep.all():
                self.pool_ids = self.pool_ids[keep]
                self.pool_vals = self.pool_vals[keep]
                self.pool_scales = self.pool_scales[keep]

    # -- compaction + migration ----------------------------------------
    def compact(self, chunk_rows: int = 1 << 18) -> None:
        """Stream base + delta into a fresh shard epoch, then migrate
        tiers against it.

        The fold is `catalog.materialize` row for row — base bytes copy
        verbatim, delta rows scatter in, id-space gaps get the canonical
        zero-row quantization and stay dead — executed as a chunked
        stream (O(chunk) resident, never the table). The new epoch gets a
        cold-built summary, the delta empties, and `rebalance()` promotes
        /demotes pool + hot membership from the measured frequencies —
        tier migration riding the epoch fold.
        """
        t0 = time.perf_counter()
        n_base, d, words = self.base.n, self.base.d, self.base.words
        dids_np = np.asarray(self.delta.ids)
        live = np.nonzero(dids_np != EMPTY_ID)[0]
        gids = dids_np[live].astype(np.int64)
        n_total = int(max(n_base, (gids.max() + 1) if len(gids) else 0))
        zero_q = quantize_rowwise(jnp.zeros((1, d), jnp.float32))
        zero_sig = np.asarray(
            lsh_signature(dequantize_rowwise(zero_q), self.inner.lsh_proj))
        dvals = np.asarray(self.delta.values)[live]
        dscales = np.asarray(self.delta.scales)[live]
        dsigs = np.asarray(self.delta.sigs)[live]

        new_dir = os.path.join(self.directory, f"epoch_{self.epoch + 1}")
        writer = BaseShardWriter(new_dir, n_total, d, words)
        alive_new = np.zeros((n_total,), bool)
        alive_new[:n_base] = self.alive[:n_base]
        alive_new[gids] = True
        for lo in range(0, n_total, chunk_rows):
            hi = min(lo + chunk_rows, n_total)
            m = hi - lo
            if lo < n_base:  # base prefix: verbatim bytes (copied —
                # memmap slices are read-only and the delta may scatter in)
                b = min(hi, n_base) - lo
                vals = np.concatenate(
                    [self.base.values[lo:lo + b],
                     np.broadcast_to(np.asarray(zero_q.values),
                                     (m - b, d))]) if m > b else \
                    np.array(self.base.values[lo:hi])
                scales = np.concatenate(
                    [self.base.scales[lo:lo + b],
                     np.broadcast_to(np.asarray(zero_q.scales),
                                     (m - b, 1))]) if m > b else \
                    np.array(self.base.scales[lo:hi])
                sigs = np.concatenate(
                    [self.base.sigs[lo:lo + b],
                     np.broadcast_to(zero_sig, (m - b, words))]) if m > b \
                    else np.array(self.base.sigs[lo:hi])
            else:  # gap region: canonical zero rows
                vals = np.broadcast_to(np.asarray(zero_q.values),
                                       (m, d)).copy()
                scales = np.broadcast_to(np.asarray(zero_q.scales),
                                         (m, 1)).copy()
                sigs = np.broadcast_to(zero_sig, (m, words)).copy()
            sel = (gids >= lo) & (gids < hi)
            if sel.any():
                vals[gids[sel] - lo] = dvals[sel]
                scales[gids[sel] - lo] = dscales[sel]
                sigs[gids[sel] - lo] = dsigs[sel]
            writer.write(lo, vals, scales, sigs)
        br = self.summary.block_rows if self.summary is not None \
            else SUMMARY_BLOCK_ROWS
        writer._maps["sigs"].flush()
        summary = build_block_summary(writer._maps["sigs"], br,
                                      db_mask=alive_new)
        writer.finish(alive=alive_new, summary=summary)

        self.base = open_base_shard(new_dir)[0]
        self.alive, self.summary = alive_new, summary
        self.delta = empty_delta(self.delta.capacity, d, words)
        self.epoch += 1
        self.n_compactions += 1
        freqs = np.zeros((self.base.n,), np.int64)
        m = min(self.item_freqs.shape[0], self.base.n)
        freqs[:m] = self.item_freqs[:m]
        self.item_freqs = freqs
        self.rebalance()
        self.last_compact_s = time.perf_counter() - t0
        if self.registry is not None:
            self.registry.observe("tiered.compact_pause_s",
                                  self.last_compact_s)
            self.registry.event("compact", epoch=self.epoch,
                                pause_s=self.last_compact_s,
                                n_items=self.n_items,
                                pool_rows=int(self.pool_ids.size))

    # -- persistence ---------------------------------------------------
    def _sidecar_state(self) -> dict:
        """The mutable state the epoch shard does NOT hold: the pending
        delta shard, post-epoch tombstones, and the measured frequency
        counters. (The base shard is already durable as ``epoch_N/``
        files; pool and hot membership are pure functions of the counters
        via `rebalance`, and the block summary of (sigs, alive), so both
        are re-derived at restore rather than persisted.)"""
        return {"delta": self.delta,
                "alive": self.alive,
                "item_freqs": self.item_freqs,
                "n_observed": np.int64(self.n_observed)}

    def snapshot(self, directory) -> None:
        """Epoch-numbered snapshot of the sidecar state through the
        fault-tolerant checkpointer (`checkpoint/checkpointer.py`):
        pending delta rows + frequency counters, so a restored catalog
        resumes with the hot-set ranking it had measured — not a cold
        tier assignment that would have to re-learn the skew."""
        from repro.checkpoint import checkpointer

        checkpointer.save(directory, self.epoch, self._sidecar_state())

    def restore(self, directory) -> None:
        """Restore the latest committed sidecar snapshot into this
        catalog and re-derive the tiers from the restored counters.

        The snapshot's epoch must match the opened shard epoch (the base
        bytes it was taken against); delta shapes are the structural
        template, so `delta_capacity` must match the snapshotted one.
        Pool and hot membership recompute via `rebalance()` — the one
        tier-selection order (`top_ids_by_freq`) over bit-identical
        counters reproduces the exact pre-snapshot ranking.
        """
        from repro.checkpoint import checkpointer

        step = checkpointer.latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed snapshot in {directory}")
        if step != self.epoch:
            raise ValueError(
                f"snapshot epoch {step} does not match the opened shard "
                f"epoch {self.epoch}; open the matching epoch_{step} "
                f"shard first")
        state = checkpointer.restore(directory, step,
                                     self._sidecar_state())
        self.delta = state["delta"]
        self.alive = np.asarray(state["alive"], bool).copy()
        self.item_freqs = np.asarray(state["item_freqs"], np.int64).copy()
        self.n_observed = int(state["n_observed"])
        # the summary is a pure function of (base sigs, alive): cold-build
        # it against the restored tombstones (`update_block_summary`
        # recomputes touched blocks exactly, so this bit-matches the
        # incrementally-maintained one)
        self.summary = build_block_summary(
            np.asarray(self.base.sigs), SUMMARY_BLOCK_ROWS,
            db_mask=self.alive)
        # rebalance() re-derives pool + hot from the restored counters and
        # already excludes pending delta ids, so delta ∩ caches = ∅ holds
        # for the restored pending set too
        self.rebalance()

    # -- introspection / oracles ----------------------------------------
    @property
    def n_pending(self) -> int:
        return delta_n_live(self.delta)

    @property
    def n_items(self) -> int:
        return int(self.alive.sum()) + delta_n_live(self.delta)

    def resident_bytes(self) -> int:
        """RAM bytes the item tiers pin (pool + hot + summary + alive) —
        the residency the memmapped base shard does NOT cost."""
        pool = (self.pool_vals.nbytes + self.pool_scales.nbytes
                + self.pool_ids.nbytes)
        cache = self.inner.item_hot
        hot = 0 if cache is None else int(
            np.asarray(cache.hot_rows).nbytes
            + np.asarray(cache.hot_ids).nbytes)
        summ = sum(int(np.asarray(x).nbytes) for x in
                   (self.summary.or_sigs, self.summary.and_sigs,
                    self.summary.min_pc, self.summary.max_pc,
                    self.summary.n_alive))
        return pool + hot + summ + self.alive.nbytes

    def stats(self) -> dict:
        return {"epoch": self.epoch, "n_items": self.n_items,
                "n_pending": self.n_pending,
                "n_compactions": self.n_compactions,
                "pool_rows": int(self.pool_ids.size),
                "hot_rows": 0 if self.inner.item_hot is None else
                int(self.inner.item_hot.capacity),
                "pool_hits": self.pool_hits, "delta_hits": self.delta_hits,
                "disk_rows": self.disk_rows,
                "resident_bytes": self.resident_bytes()}

    def to_ram_engine(self):
        """The all-RAM live engine over this catalog's EXACT state (base
        loaded from the shard, same delta/mask/summary/hot cache) — the
        bit-match comparator for tests and the benchmark. O(n) RAM."""
        table = QuantizedTensor(
            values=jnp.asarray(np.asarray(self.base.values)),
            scales=jnp.asarray(np.asarray(self.base.scales)))
        return dataclasses.replace(
            self.inner, item_table_q=table,
            item_sigs=jnp.asarray(np.asarray(self.base.sigs)),
            item_mask=jnp.asarray(self.alive), delta=self.delta,
            block_summary=self.summary)

    def rebuild_reference(self):
        """Frozen from-scratch oracle (`catalog.rebuild_reference`) over
        the materialized final table, pinning this catalog's surviving
        hot set — the strongest bit-match target."""
        from repro.serving.catalog import rebuild_reference

        return rebuild_reference(self.to_ram_engine())
