"""Small shared utilities: pytree dataclasses, shape helpers, rng streams."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterator, Mapping

import jax
import jax.numpy as jnp


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` across jax versions.

    Newer jax exposes `jax.shard_map(..., check_vma=...)`; older releases
    only have `jax.experimental.shard_map.shard_map(..., check_rep=...)`.
    Every call site in this repo goes through here so the codebase runs on
    both.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


class FrozenMapping(Mapping):
    """Immutable, hashable mapping — a dict that can live in a static
    (metadata) field of a jit-traced pytree."""

    __slots__ = ("_d",)

    def __init__(self, d: Mapping):
        object.__setattr__(self, "_d", dict(d))

    def __getitem__(self, k):
        return self._d[k]

    def __iter__(self) -> Iterator:
        return iter(self._d)

    def __len__(self) -> int:
        return len(self._d)

    def __hash__(self) -> int:
        return hash(tuple(sorted(self._d.items())))

    def __eq__(self, other) -> bool:
        if isinstance(other, (FrozenMapping, Mapping)):
            return dict(self._d) == dict(other)
        return NotImplemented

    def __repr__(self) -> str:
        return f"FrozenMapping({self._d!r})"


def pytree_dataclass(cls=None, *, meta_fields: tuple = ()):
    """Register a dataclass as a JAX pytree; `meta_fields` stay static."""

    def wrap(c):
        c = dataclasses.dataclass(c)
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        jax.tree_util.register_dataclass(c, data_fields, tuple(meta_fields))
        return c

    return wrap(cls) if cls is not None else wrap


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} EiB"


def human_flops(n: float) -> str:
    for unit in ("", "K", "M", "G", "T", "P", "E"):
        if abs(n) < 1000.0:
            return f"{n:.2f} {unit}FLOP"
        n /= 1000.0
    return f"{n:.2f} ZFLOP"


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (ShapeDtypeStruct or ndarray)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_param_count(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(math.prod(l.shape) for l in leaves if hasattr(l, "shape"))


def fold_key(key: jax.Array, *names: str) -> jax.Array:
    """Deterministically derive a subkey from string path components."""
    for name in names:
        data = sum(ord(c) * (i + 1) for i, c in enumerate(name)) % (2**31 - 1)
        key = jax.random.fold_in(key, data)
    return key
