"""Small shared utilities: pytree dataclasses, shape helpers, rng streams."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


def pytree_dataclass(cls=None, *, meta_fields: tuple = ()):
    """Register a dataclass as a JAX pytree; `meta_fields` stay static."""

    def wrap(c):
        c = dataclasses.dataclass(c)
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        jax.tree_util.register_dataclass(c, data_fields, tuple(meta_fields))
        return c

    return wrap(cls) if cls is not None else wrap


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} EiB"


def human_flops(n: float) -> str:
    for unit in ("", "K", "M", "G", "T", "P", "E"):
        if abs(n) < 1000.0:
            return f"{n:.2f} {unit}FLOP"
        n /= 1000.0
    return f"{n:.2f} ZFLOP"


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (ShapeDtypeStruct or ndarray)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_param_count(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(math.prod(l.shape) for l in leaves if hasattr(l, "shape"))


def fold_key(key: jax.Array, *names: str) -> jax.Array:
    """Deterministically derive a subkey from string path components."""
    for name in names:
        data = sum(ord(c) * (i + 1) for i, c in enumerate(name)) % (2**31 - 1)
        key = jax.random.fold_in(key, data)
    return key
