import os

# Tests run single-device (the dry-run is the only consumer of fake devices).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture
def key():
    return jax.random.key(0)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
