"""Subprocess helper: exercise the real step builders on an 8-device mesh
(reduced configs) — lower + compile + HLO analysis for train/prefill/decode.
Run: XLA flags set below; prints MARKER lines the test asserts on."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# fake CPU devices only ever make sense on the CPU backend — and with
# libtpu installed a bare env would try (and block on) TPU plugin init
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

import jax

from repro.configs.base import ArchBundle, ShapeConfig
from repro.configs.reduced import reduce_config
from repro.configs.registry import get_arch
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import (
    build_decode_step,
    build_prefill_step,
    build_train_step,
)

ARCH = sys.argv[1] if len(sys.argv) > 1 else "qwen2.5-3b"
MULTI = len(sys.argv) > 2 and sys.argv[2] == "multi"


def main():
    bundle = get_arch(ARCH)
    cfg = reduce_config(bundle.model)
    pcfg = bundle.parallel.with_(grad_accum={"tiny_train": 2},
                                 logit_chunk=16)
    tiny = ArchBundle(model=cfg, parallel=pcfg, skip_shapes={})
    mesh = make_test_mesh(multi_pod=MULTI)

    with mesh:
        shp = ShapeConfig("tiny_train", "train", 64, 8)
        built = build_train_step(tiny, shp, mesh)
        co = built.fn.lower(*built.abstract_args).compile()
        st = analyze_hlo(co.as_text(), mesh.devices.size)
        assert st.flops > 0, "no dot flops found"
        assert st.collective_bytes > 0, "no collectives in sharded train"
        print(f"MARKER train ok flops={st.flops:.3e} "
              f"coll={st.collective_bytes:.3e}")

        shp = ShapeConfig("tiny_prefill", "prefill", 64, 4)
        built = build_prefill_step(tiny, shp, mesh)
        co = built.fn.lower(*built.abstract_args).compile()
        print("MARKER prefill ok")

        shp = ShapeConfig("tiny_decode", "decode", 64, 8)
        built = build_decode_step(tiny, shp, mesh)
        co = built.fn.lower(*built.abstract_args).compile()
        mem = co.memory_analysis()
        assert mem.argument_size_in_bytes > 0
        print("MARKER decode ok")


if __name__ == "__main__":
    main()
