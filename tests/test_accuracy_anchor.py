"""Slow accuracy re-anchor: HR@10 through the STREAMING filtering path on a
synthetic catalog 10x the quick MovieLens config (3000 items — past
STREAM_MIN_ITEMS-scale behavior is forced explicitly via scan_block).

The paper's Sec. IV-B result is an accuracy ORDERING (fp32 ~ int8 > LSH);
PR 2 moved the filtering scan to the streaming kernel and this PR rebuilt
its candidate tracking around wide keys — so the three HR numbers are
pinned here as seeded goldens to +-1e-3. Any drift in the retrieval
numerics (key packing, merge order, radius semantics) moves at least one
full user (1/400 = 2.5e-3) and trips the assert, while jit scheduling noise
cannot: the whole pipeline is integer/deterministic for fixed seeds.

Tolerance policy: the goldens are pinned to the jax version nightly
installs (jax[cpu]==0.4.37 — see .github/workflows/nightly.yml); float
TRAINING is reduction-order sensitive, so an XLA upgrade may legally move
the trained embeddings and therefore every downstream HR number, while the
retrieval pipeline itself stays bit-deterministic for a fixed table. The
+-1e-3 band is deliberately tighter than one eval user (2.5e-3): it only
admits exact agreement, and exists so the assert message shows the
measured value.  On a jax bump: re-measure via
``train_and_eval(n_users=400, n_items=3000, steps=1500, radius=144,
seed=0, scan_block=512)``, confirm the ordering asserts below still hold,
update GOLDEN in the same commit as the pin, and note the move here.

Nightly CI runs this (too slow for the per-push lane: it trains the tower).
"""
import pytest

pytestmark = pytest.mark.slow

# measured on the pinned seeds (n_users=400, n_items=3000, steps=1500,
# radius=144, seed=0, scan_block=512) — see benchmarks/accuracy_hr.py.
# radius is re-tuned for the 10x catalog: at 3000 items the 300-item quick
# radius (112) retrieves nothing (lsh HR 0.0) and 128 retrieves BELOW
# chance on jax 0.4.37 (lsh 0.0025 < 0.0033); 144 restores the paper's
# fp32 ~ int8 > lsh > chance structure (chance = 10/3000 = 0.0033), and
# the sweep is flat there (136-168 all land lsh = 0.005), so the anchor
# is not sitting on a radius cliff
GOLDEN = {"fp32": 0.01, "int8": 0.01, "lsh": 0.005}


def test_hr10_streaming_10x_catalog_matches_goldens():
    from benchmarks.accuracy_hr import train_and_eval

    hrs = train_and_eval(n_users=400, n_items=3000, steps=1500, radius=144,
                         seed=0, scan_block=512)
    for mode, want in GOLDEN.items():
        assert abs(hrs[mode] - want) <= 1e-3, (mode, hrs[mode], want)
    # the paper's structure must survive the streaming path: quantization
    # is ~free, the LSH/Hamming filtering costs a few points but stays
    # well above chance
    assert abs(hrs["fp32"] - hrs["int8"]) < 0.05
    assert hrs["lsh"] <= hrs["int8"] + 0.02
    assert hrs["lsh"] > 1.2 * 10 / 3000


def test_streaming_and_dense_hr_identical():
    """The execution plan is not allowed to move accuracy at all: HR@10
    through the forced-streaming engine == the forced-dense engine."""
    from benchmarks.accuracy_hr import train_and_eval

    kw = dict(n_users=120, n_items=600, steps=60, radius=112, seed=3)
    stream = train_and_eval(scan_block=96, **kw)
    dense = train_and_eval(scan_block=0, **kw)
    assert stream == dense
