"""Per-arch smoke tests: reduced config, one forward (train) + serve steps on
CPU, asserting output shapes and finite values."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_arch
from repro.configs.reduced import reduce_config
from repro.models import transformer as tf


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    if cfg.family == "audio":
        toks = rng.integers(0, cfg.vocab_size, (B, cfg.n_codebooks, S))
    else:
        toks = rng.integers(0, cfg.vocab_size, (B, S))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    if cfg.family == "vlm":
        nv = cfg.vision_tokens
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, nv, cfg.d_model)), jnp.float32)
        batch["vision_pos"] = jnp.asarray(
            rng.choice(S, size=(B, nv), replace=False), jnp.int32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_forward_smoke(arch_id):
    cfg = reduce_config(get_arch(arch_id).model)
    params = tf.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    out = tf.forward(params, cfg, batch, mode="train", logits_mode="all")
    B, S = 2, 16
    if cfg.family == "audio":
        assert out.logits.shape == (B, S, cfg.n_codebooks, cfg.padded_vocab)
    else:
        assert out.logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(out.logits, dtype=np.float32)).all()
    assert np.isfinite(float(out.aux_loss))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_grads_smoke(arch_id):
    """One gradient step: finite loss and finite grads for every family."""
    cfg = reduce_config(get_arch(arch_id).model)
    params = tf.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    labels = batch["tokens"]

    def loss_fn(p):
        out = tf.forward(p, cfg, batch, mode="train", logits_mode="all")
        logits = out.logits.astype(jnp.float32)
        if cfg.family == "audio":
            lg = jnp.moveaxis(logits, 2, 1)  # (B, K, S, V)
            ll = jax.nn.log_softmax(lg)
            loss = -jnp.mean(
                jnp.take_along_axis(ll, labels[..., None], -1))
        else:
            ll = jax.nn.log_softmax(logits)
            loss = -jnp.mean(jnp.take_along_axis(ll, labels[..., None], -1))
        return loss + 0.01 * out.aux_loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, dtype=np.float32)).all() for g in leaves)
    # embedding must receive gradient
    g_embed = np.asarray(grads["embed"], np.float32)
    assert np.abs(g_embed).sum() > 0


@pytest.mark.parametrize("arch_id", ["qwen3-8b", "mamba2-1.3b", "zamba2-1.2b",
                                     "musicgen-large", "phi3.5-moe-42b-a6.6b"])
def test_prefill_then_decode_matches_full_forward(arch_id):
    """Serving correctness: prefill(S) + decode(1) logits == forward(S+1)."""
    cfg = reduce_config(get_arch(arch_id).model)
    params = tf.init_params(cfg, jax.random.key(0))
    B, S = 2, 12
    full = _batch(cfg, B, S + 1)
    toks = full["tokens"]
    prefix = {"tokens": toks[..., :S]}
    last = {"tokens": toks[..., S:]}

    from repro.serving.engine import decode_step, prefill

    out_full = tf.forward(params, cfg, full, mode="train", logits_mode="all")
    pre = prefill(params, cfg, prefix, cache_len=S + 4, cache_dtype="bfloat16")
    dec = decode_step(params, cfg, last, pre.caches, jnp.int32(S))

    want = np.asarray(out_full.logits[:, -1], np.float32)
    got = np.asarray(dec.logits[:, -1], np.float32)
    # bf16 cache round-trip: loose tolerance
    np.testing.assert_allclose(got, want, rtol=0.1, atol=0.15)
    # and the argmax (greedy token) must agree
    np.testing.assert_array_equal(
        got.reshape(got.shape[0], -1).argmax(-1),
        want.reshape(want.shape[0], -1).argmax(-1),
    )
