"""Pipelined serving: the AsyncServer ring, the staged serve split, and the
non-blocking scan entry are pure execution knobs — every configuration must
bit-match the synchronous path (items, scores, AND cache counters)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.nns import fixed_radius_nns, fixed_radius_nns_async
from repro.data import synthetic
from repro.data.synthetic import serving_queries as _queries
from repro.models import recsys as rs
from repro.serving import (
    AsyncServer,
    MicroBatcher,
    ServerConfigError,
    RecSysEngine,
    lookup_step,
    rank_stage_step,
    scan_step,
    serve_step,
)
from repro.serving.hot_cache import CacheStats


@pytest.fixture(scope="module")
def served():
    data = synthetic.make_movielens(n_users=120, n_items=90, history_len=6)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=6)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=data.n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=16,
                                top_k=5, hot_rows=32, item_freqs=freqs)
    return engine, data


def _batch(data, idx):
    return {
        **{k: jnp.asarray(v[idx]) for k, v in data.user_feats.items()},
        "history": jnp.asarray(data.histories[idx]),
        "genre": jnp.asarray(data.genres[idx]),
    }


def _assert_same_stream(sync_out, async_out):
    assert len(sync_out) == len(async_out)
    for s, a in zip(sync_out, async_out):
        np.testing.assert_array_equal(s.items, a.items)
        np.testing.assert_array_equal(s.scores, a.scores)


# ---------------------------------------------------------------------------
# AsyncServer == MicroBatcher, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [1, 2, 3])
def test_pipelined_bitmatches_synchronous(served, depth):
    """Any ring depth serves exactly the synchronous results — items,
    scores, and the hot-cache counters (mixed full + padded-tail buckets)."""
    engine, data = served
    idx = np.arange(19) % 7  # 19 queries -> 8 + 8 + padded 4 at max_batch=8
    sync = MicroBatcher(engine, max_batch=8)
    pipe = AsyncServer(engine, max_batch=8, depth=depth)
    _assert_same_stream(sync.serve_many(_queries(data, idx)),
                        pipe.serve_many(_queries(data, idx)))
    assert pipe.in_flight == 0  # flush retires the whole ring
    assert (pipe.n_served, pipe.n_padded) == (sync.n_served, sync.n_padded)
    assert int(pipe._stats.hits) == int(sync._stats.hits)
    assert int(pipe._stats.lookups) == int(sync._stats.lookups)


def test_coalesced_bitmatches_synchronous(served):
    """coalesce > 1 fuses full buckets into one super-batch dispatch without
    changing a single result or counter; the tail still ships alone."""
    engine, data = served
    idx = np.arange(19) % 7
    sync = MicroBatcher(engine, max_batch=8)
    pipe = AsyncServer(engine, max_batch=8, depth=2, coalesce=2)
    _assert_same_stream(sync.serve_many(_queries(data, idx)),
                        pipe.serve_many(_queries(data, idx)))
    # 8 + 8 coalesced into one dispatch, padded 4-tail alone: counters still
    # count per-bucket batches
    assert pipe.n_batches == 3 and pipe.n_served == 19 and pipe.n_padded == 1
    assert int(pipe._stats.hits) == int(sync._stats.hits)
    assert int(pipe._stats.lookups) == int(sync._stats.lookups)


def test_routed_pipelined_bitmatches_synchronous(served):
    """An engine sharded over a query mesh axis auto-coalesces buckets onto
    the query shards; served results must not change."""
    engine, data = served
    mesh = jax.make_mesh((1,), ("qp",))
    routed = engine.shard(mesh, query_axis="qp")
    pipe = AsyncServer(routed, max_batch=8, depth=2)
    assert pipe.coalesce == 1  # one device -> one query block per dispatch
    forced = AsyncServer(routed, max_batch=8, depth=2, coalesce=2)
    idx = np.arange(19) % 7
    sync_out = MicroBatcher(engine, max_batch=8).serve_many(
        _queries(data, idx))
    _assert_same_stream(sync_out, pipe.serve_many(_queries(data, idx)))
    _assert_same_stream(sync_out, forced.serve_many(_queries(data, idx)))


def test_pipelined_result_and_ticket_api(served):
    """submit/result redeem across an unflushed ring, in any order."""
    engine, data = served
    pipe = AsyncServer(engine, max_batch=4, depth=2)
    tickets = [pipe.submit(q) for q in _queries(data, np.arange(6))]
    direct = engine.serve(_batch(data, np.arange(6)))
    for t in reversed(tickets):  # out-of-order redemption
        np.testing.assert_array_equal(pipe.result(t).items,
                                      np.asarray(direct.items)[t])


def test_async_server_rejects_bad_knobs(served):
    engine, _ = served
    with pytest.raises(ServerConfigError, match="depth"):
        AsyncServer(engine, depth=0)
    with pytest.raises(ServerConfigError, match="coalesce"):
        AsyncServer(engine, coalesce=0)


# ---------------------------------------------------------------------------
# staged serve split == fused serve_step
# ---------------------------------------------------------------------------
def test_staged_steps_compose_to_serve_step(served):
    """lookup -> scan -> rank composes to exactly the fused serve_step:
    same items, same topk, same NNS candidates, same stats."""
    engine, data = served
    batch = _batch(data, np.arange(6))
    f_items, f_top, f_nns, f_stats = serve_step(engine, batch,
                                                CacheStats.zero())
    u, pooled, stats = lookup_step(engine, batch, CacheStats.zero())
    nns = scan_step(engine, u)
    items, top, stats = rank_stage_step(engine, batch, nns.indices, u,
                                        pooled, stats)
    np.testing.assert_array_equal(np.asarray(f_items), np.asarray(items))
    np.testing.assert_array_equal(np.asarray(f_top.scores),
                                  np.asarray(top.scores))
    np.testing.assert_array_equal(np.asarray(f_nns.indices),
                                  np.asarray(nns.indices))
    np.testing.assert_array_equal(np.asarray(f_nns.counts),
                                  np.asarray(nns.counts))
    assert (int(f_stats.hits), int(f_stats.lookups)) == (
        int(stats.hits), int(stats.lookups))


def test_staged_steps_respect_engine_knobs(served):
    """The stage split composes with the engine's execution knobs
    (streaming scan plan, bank-sharded mesh) without changing results."""
    engine, data = served
    batch = _batch(data, np.arange(5))
    base = engine.serve(batch)
    for eng in (
        dataclasses.replace(engine, scan_block=16),
        engine.shard(jax.make_mesh((1,), ("model",)), "model"),
    ):
        u, pooled, stats = lookup_step(eng, batch, CacheStats.zero())
        nns = scan_step(eng, u)
        items, _, _ = rank_stage_step(eng, batch, nns.indices, u, pooled,
                                      stats)
        np.testing.assert_array_equal(np.asarray(base.items),
                                      np.asarray(items))


# ---------------------------------------------------------------------------
# non-blocking scan entry
# ---------------------------------------------------------------------------
def test_fixed_radius_nns_async_bitmatches(key):
    """The async entry is dispatch-only sugar: identical results to the
    blocking call on both execution plans, plus n_valid masking."""
    from repro.core.lsh import lsh_signature, make_lsh_projections

    proj = make_lsh_projections(key, 16, 64)
    x = jax.random.normal(jax.random.key(5), (37, 16))
    sigs = lsh_signature(x, proj)
    want = fixed_radius_nns(sigs[:4], sigs, radius=28, max_candidates=12)
    mask = np.arange(37) % 2 == 0
    for kw in ({}, {"scan_block": 16}, {"n_valid": 30},
               {"db_mask": jnp.asarray(mask)},
               {"scan_block": 16, "superblock": 16}):
        got = fixed_radius_nns_async(sigs[:4], sigs, 28, 12, **kw)
        ref = fixed_radius_nns(sigs[:4], sigs, 28, 12, **kw)
        np.testing.assert_array_equal(np.asarray(ref.indices),
                                      np.asarray(got.indices))
        np.testing.assert_array_equal(np.asarray(ref.distances),
                                      np.asarray(got.distances))
        np.testing.assert_array_equal(np.asarray(ref.counts),
                                      np.asarray(got.counts))
    assert (np.asarray(want.indices) == np.asarray(
        fixed_radius_nns_async(sigs[:4], sigs, 28, 12).indices)).all()
