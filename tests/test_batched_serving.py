"""The batched serving subsystem: hot-row cache bit-exactness, batcher
padding invariance, sharded NNS equivalence, and hit-rate accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.embedding import embedding_bag, init_table, lookup
from repro.core.nns import fixed_radius_nns, sharded_fixed_radius_nns
from repro.data import synthetic
from repro.models import recsys as rs
from repro.serving import (
    MicroBatcher,
    RecSysEngine,
    build_hot_cache,
    cached_embedding_bag,
    cached_lookup,
    default_buckets,
    serve_step,
)
from repro.serving.hot_cache import CacheStats


# ---------------------------------------------------------------------------
# hot-row cache
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def table(request):
    return init_table(jax.random.key(3), 200, 32)


def test_cached_lookup_bitmatches_uncached(table, rng):
    cache = build_hot_cache(table, freqs=rng.integers(1, 100, 200),
                            capacity=50)
    ids = jnp.asarray(rng.integers(-1, 200, size=(6, 9)), jnp.int32)
    got, stats = cached_lookup(cache, table, ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(lookup(table, ids)))
    assert int(stats.lookups) == int((np.asarray(ids) >= 0).sum())
    assert 0 <= int(stats.hits) <= int(stats.lookups)


def test_cached_bag_bitmatches_embedding_bag(table, rng):
    freqs = rng.integers(0, 1000, 200)
    cache = build_hot_cache(table, freqs=freqs, capacity=64)
    ids = jnp.asarray(rng.integers(-1, 200, size=(8, 12)), jnp.int32)
    for mode in ("sum", "mean"):
        got, _ = cached_embedding_bag(cache, table, ids, mode=mode)
        want = embedding_bag(table, ids, mode=mode)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # weighted pooling too
    w = jnp.asarray(rng.normal(size=(8, 12)), jnp.float32)
    got, _ = cached_embedding_bag(cache, table, ids, weights=w)
    want = embedding_bag(table, ids, weights=w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hot_cache_pins_most_frequent_rows(table):
    freqs = np.zeros(200)
    hot_set = np.array([3, 77, 150, 199])
    freqs[hot_set] = [10, 20, 30, 40]
    cache = build_hot_cache(table, freqs=freqs, capacity=4)
    np.testing.assert_array_equal(np.asarray(cache.hot_ids), hot_set)
    # lookups of pinned rows are all hits
    _, stats = cached_lookup(cache, table, jnp.asarray(hot_set))
    assert int(stats.hits) == 4 and int(stats.lookups) == 4
    # lookups of cold rows are all misses
    _, stats = cached_lookup(cache, table, jnp.asarray([0, 1, 2]))
    assert int(stats.hits) == 0 and int(stats.lookups) == 3


def test_hot_cache_ties_break_by_ascending_id(table):
    """Tied frequencies must pin deterministically: lowest id wins.

    Regression: `build_hot_cache` used `argpartition`, whose order among
    equal keys is implementation-defined — two processes (or two numpy
    versions) could pin different hot sets for the same frequencies,
    breaking cross-process bit-match of cache counters.
    """
    freqs = np.zeros(200)
    freqs[[7, 42, 141, 190]] = 50  # four-way tie for 2 remaining slots
    freqs[[5, 100]] = 99
    cache = build_hot_cache(table, freqs=freqs, capacity=4)
    np.testing.assert_array_equal(np.asarray(cache.hot_ids), [5, 7, 42, 100])
    # all-zero frequencies: the full tie resolves to the lowest ids
    cache = build_hot_cache(table, freqs=np.zeros(200), capacity=3)
    np.testing.assert_array_equal(np.asarray(cache.hot_ids), [0, 1, 2])


def test_top_ids_by_freq_order_and_eligibility():
    from repro.serving import top_ids_by_freq

    freqs = np.array([5, 9, 9, 1, 9, 0])
    np.testing.assert_array_equal(top_ids_by_freq(freqs, 4), [1, 2, 4, 0])
    # eligibility masks rows out entirely (result may come up short)
    eligible = np.array([True, False, True, True, False, False])
    np.testing.assert_array_equal(
        top_ids_by_freq(freqs, 4, eligible=eligible), [2, 0, 3])
    np.testing.assert_array_equal(
        top_ids_by_freq(freqs, 2, eligible=np.zeros(6, bool)), [])


def test_zero_capacity_cache_is_uncached_path(table, rng):
    cache = build_hot_cache(table, capacity=0)
    ids = jnp.asarray(rng.integers(-1, 200, size=(4, 7)), jnp.int32)
    got, stats = cached_embedding_bag(cache, table, ids)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(embedding_bag(table, ids)))
    assert int(stats.hits) == 0


# ---------------------------------------------------------------------------
# engine + batcher
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served():
    data = synthetic.make_movielens(n_users=120, n_items=90, history_len=6)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=6)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=data.n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=16,
                                top_k=5, hot_rows=32, item_freqs=freqs)
    return engine, data


def _queries(data, idx):
    return [{**{k: v[i] for k, v in data.user_feats.items()},
             "history": data.histories[i], "genre": data.genres[i]}
            for i in idx]


def _batch(data, idx):
    return {
        **{k: jnp.asarray(v[idx]) for k, v in data.user_feats.items()},
        "history": jnp.asarray(data.histories[idx]),
        "genre": jnp.asarray(data.genres[idx]),
    }


def test_batcher_padding_never_changes_topk(served):
    """Queries served through a padded bucket == exact-shape serve."""
    engine, data = served
    mb = MicroBatcher(engine, max_batch=16)
    n = 5  # pads to the 8-bucket
    out = mb.serve_many(_queries(data, range(n)))
    assert mb.n_padded > 0  # the bucket really padded
    direct = engine.serve(_batch(data, np.arange(n)))
    for i in range(n):
        np.testing.assert_array_equal(out[i].items,
                                      np.asarray(direct.items)[i])
        np.testing.assert_array_equal(out[i].scores,
                                      np.asarray(direct.topk.scores)[i])


def test_batcher_buckets_and_order(served):
    engine, data = served
    mb = MicroBatcher(engine, max_batch=8)
    assert default_buckets(8) == (1, 2, 4, 8)
    # 19 queries -> 8 + 8 + 4(padded from 3) batches, results in order
    idx = np.arange(19) % 7  # users repeat: 0 and 7 and 14 are user 0, ...
    out = mb.serve_many(_queries(data, idx))
    assert len(out) == 19 and mb.n_batches == 3
    direct = engine.serve(_batch(data, idx))
    for i in range(19):
        np.testing.assert_array_equal(out[i].items,
                                      np.asarray(direct.items)[i])
    # the same user served in different micro-batches gets identical
    # recommendations (determinism across bucket shapes)
    np.testing.assert_array_equal(out[0].items, out[7].items)
    np.testing.assert_array_equal(out[7].items, out[14].items)
    assert 0.0 <= mb.stats()["cache_hit_rate"] <= 1.0 and mb.n_served == 19


def test_padding_rows_excluded_from_cache_stats(served):
    """Bucket padding must not inflate the hot-cache hit/lookup counters."""
    engine, data = served
    n = 5  # pads to the 8-bucket
    mb = MicroBatcher(engine, max_batch=16)
    mb.serve_many(_queries(data, range(n)))
    assert mb.n_padded == 3
    _, _, _, unpadded = serve_step(engine, _batch(data, np.arange(n)),
                                   CacheStats.zero())
    assert int(mb._stats.lookups) == int(unpadded.lookups)
    assert int(mb._stats.hits) == int(unpadded.hits)


def test_serve_stats_accumulate_across_batches(served):
    engine, data = served
    batch = _batch(data, np.arange(4))
    _, _, _, stats = serve_step(engine, batch, CacheStats.zero())
    one = (int(stats.hits), int(stats.lookups))
    assert one[1] > 0
    _, _, _, stats2 = serve_step(engine, batch, stats)
    assert (int(stats2.hits), int(stats2.lookups)) == (2 * one[0], 2 * one[1])


def test_tail_bucket_pads_with_invalid_ids(served):
    """Regression: a pending queue smaller than the smallest bucket pads
    with INVALID query ids (-1) — the padded tail must neither touch the
    hot-row cache counters nor change the served result. (Padding used to
    replicate the last real query and rely on the `valid` mask alone.)"""
    engine, data = served
    mb = MicroBatcher(engine, max_batch=8, buckets=(4, 8))
    out = mb.serve_many(_queries(data, [3]))  # 1 pending < smallest bucket 4
    assert mb.n_padded == 3 and mb.n_batches == 1
    # the padded rows really are invalid queries, not clones of query 3
    batch = mb._stack([q for _, q in [(0, _queries(data, [3])[0])]], 4)
    assert (np.asarray(batch["history"])[1:] == -1).all()
    assert (np.asarray(batch["genre"])[1:] == -1).all()
    # counters match a padding-free serve of the same single query exactly
    _, _, _, unpadded = serve_step(engine, _batch(data, np.array([3])),
                                   CacheStats.zero())
    assert int(mb._stats.lookups) == int(unpadded.lookups)
    assert int(mb._stats.hits) == int(unpadded.hits)
    # and the recommendation is unchanged
    direct = engine.serve(_batch(data, np.array([3])))
    np.testing.assert_array_equal(out[0].items, np.asarray(direct.items)[0])


def test_sharded_engine_matches_local(served):
    """CPU 1-device mesh: sharded filter stage == single-device, end to end."""
    engine, data = served
    mesh = jax.make_mesh((1,), ("model",))
    sharded = engine.shard(mesh, "model")
    batch = _batch(data, np.arange(6))
    local, dist = engine.serve(batch), sharded.serve(batch)
    np.testing.assert_array_equal(np.asarray(local.items),
                                  np.asarray(dist.items))
    np.testing.assert_array_equal(np.asarray(local.nns.counts),
                                  np.asarray(dist.nns.counts))


def test_engine_scan_block_serves_identically(served):
    """The streaming filtering plan is a pure execution knob: forcing it on
    the engine (locally and sharded) must not change a single served item."""
    import dataclasses

    engine, data = served
    batch = _batch(data, np.arange(6))
    base = engine.serve(batch)
    for eng in (
        dataclasses.replace(engine, scan_block=16),
        dataclasses.replace(engine.shard(jax.make_mesh((1,), ("model",)),
                                         "model"), scan_block=8),
    ):
        got = eng.serve(batch)
        np.testing.assert_array_equal(np.asarray(base.items),
                                      np.asarray(got.items))
        np.testing.assert_array_equal(np.asarray(base.nns.indices),
                                      np.asarray(got.nns.indices))
        np.testing.assert_array_equal(np.asarray(base.nns.counts),
                                      np.asarray(got.nns.counts))


def test_query_parallel_engine_matches_local(served):
    """engine.shard with a query axis (with and without a db axis) must not
    change a single served item."""
    engine, data = served
    batch = _batch(data, np.arange(6))
    base = engine.serve(batch)
    qp_only = engine.shard(jax.make_mesh((1,), ("qp",)), query_axis="qp")
    both = engine.shard(jax.make_mesh((1, 1), ("qp", "banks")), "banks",
                        query_axis="qp")
    for eng in (qp_only, both):
        got = eng.serve(batch)
        np.testing.assert_array_equal(np.asarray(base.items),
                                      np.asarray(got.items))
        np.testing.assert_array_equal(np.asarray(base.nns.indices),
                                      np.asarray(got.nns.indices))
        np.testing.assert_array_equal(np.asarray(base.nns.counts),
                                      np.asarray(got.nns.counts))
    with pytest.raises(ValueError, match="query_axis"):
        engine.shard(jax.make_mesh((1,), ("qp",)))


def test_query_parallel_engine_masks_padded_sigs(served):
    """Regression: an engine whose item_sigs carry pad rows (e.g. from an
    earlier bank-sharded incarnation) re-sharded to query-parallel-only
    must never surface a pad row (index >= n_items) as a candidate."""
    import dataclasses

    engine, data = served
    batch = _batch(data, np.arange(5))
    want = engine.serve(batch)
    n_items = engine.item_table_q.shape[0]
    padded = jnp.pad(engine.item_sigs, ((0, 3), (0, 0)))  # all-zero sigs
    qp = dataclasses.replace(engine, item_sigs=padded).shard(
        jax.make_mesh((1,), ("qp",)), query_axis="qp")
    got = qp.serve(batch)
    assert (np.asarray(got.nns.indices) < n_items).all()
    np.testing.assert_array_equal(np.asarray(want.items),
                                  np.asarray(got.items))


def test_sharded_nns_with_padding_excludes_pad_rows(key):
    """n not divisible by shards: pad rows must never appear as candidates."""
    from repro.core.lsh import lsh_signature, make_lsh_projections

    proj = make_lsh_projections(key, 16, 64)
    x = jax.random.normal(jax.random.key(5), (37, 16))
    sigs = lsh_signature(x, proj)
    padded = jnp.pad(sigs, ((0, 3), (0, 0)))  # 40 rows, 3 pads
    mesh = jax.make_mesh((1,), ("model",))
    local = fixed_radius_nns(sigs[:4], sigs, radius=28, max_candidates=12)
    shard = sharded_fixed_radius_nns(mesh, "model", sigs[:4], padded,
                                     radius=28, max_candidates=12, n_valid=37)
    np.testing.assert_array_equal(np.asarray(local.counts),
                                  np.asarray(shard.counts))
    assert (np.asarray(shard.indices) < 37).all()
    np.testing.assert_array_equal(
        np.sort(np.asarray(local.indices), -1),
        np.sort(np.asarray(shard.indices), -1))
