"""Block-summary pruning: sound bounds, bit-identical pruned scans.

The contract under test (docs/KERNELS.md): `summary_block_bounds` is a
sound lower bound on the Hamming distance from any query to any eligible
row of a DB block, so a pruned streaming scan — on any plan (streaming,
sharded, query-parallel, delta-aware), masked or not — returns the exact
bits of the unpruned scan, while `blocks_touched` reports how much of the
catalog each query actually admitted.

Runs in the CI pallas-interpret lane too: the pruned streaming tests drive
the real kernel body with the per-cell scan/skip operand.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.nns import (
    BIG_DIST,
    BlockSummary,
    build_block_summary,
    delta_aware_nns,
    fixed_radius_nns,
    query_parallel_nns,
    sharded_fixed_radius_nns,
    summary_block_bounds,
    update_block_summary,
)
from repro.kernels.ref import hamming_distance_ref

WORDS = 8
K = 16
BR = 128  # smallest legal summary granularity: one Pallas lane tile


def _uniform(rng, n):
    return rng.integers(0, 2**32, size=(n, WORDS), dtype=np.uint32)


def _clustered(rng, n_clusters=4, rows_per=BR, flip_positions=20):
    """Blocked clusters: rows of block b are small perturbations of center
    b, with flips confined to `flip_positions` designated bit positions so
    the block OR/AND stays tight (the layout pruning is designed for)."""
    centers = _uniform(rng, n_clusters)
    pos = rng.choice(256, size=flip_positions, replace=False)
    rows = np.repeat(centers, rows_per, axis=0)
    for i in range(rows.shape[0]):
        for p in rng.choice(pos, size=rng.integers(0, 6), replace=False):
            rows[i, p // 32] ^= np.uint32(1) << np.uint32(p % 32)
    queries = centers.copy()
    for i in range(queries.shape[0]):
        p = rng.choice(pos, size=2, replace=False)
        for q in p:
            queries[i, q // 32] ^= np.uint32(1) << np.uint32(q % 32)
    return queries, rows


def _assert_same(pruned, unpruned):
    np.testing.assert_array_equal(np.asarray(pruned.indices),
                                  np.asarray(unpruned.indices))
    np.testing.assert_array_equal(np.asarray(pruned.distances),
                                  np.asarray(unpruned.distances))
    np.testing.assert_array_equal(np.asarray(pruned.counts),
                                  np.asarray(unpruned.counts))


# ---------------------------------------------------------------------------
# the bound itself
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["uniform", "clustered"])
@pytest.mark.parametrize("masked", [False, True])
def test_bound_is_sound(layout, masked):
    """bound(q, b) <= min over eligible rows r in b of d(q, r) — always."""
    rng = np.random.default_rng(3)
    if layout == "uniform":
        db = _uniform(rng, 4 * BR)
        queries = _uniform(rng, 8)
    else:
        queries, db = _clustered(rng)
    mask = rng.random(db.shape[0]) > 0.3 if masked else None
    summary = build_block_summary(db, BR, db_mask=mask)
    bounds = np.asarray(summary_block_bounds(jnp.asarray(queries), summary))
    d = np.asarray(hamming_distance_ref(queries, db))
    elig = np.ones(db.shape[0], bool) if mask is None else mask
    for b in range(summary.n_blocks):
        sel = elig[b * BR:(b + 1) * BR]
        db_blk = d[:, b * BR:(b + 1) * BR][:, sel]
        true_min = (db_blk.min(axis=1) if db_blk.shape[1]
                    else np.full(d.shape[0], BIG_DIST))
        assert np.all(bounds[:, b] <= true_min), (b, bounds[:, b], true_min)


def test_empty_block_bounds_to_big():
    """A fully-tombstoned block bounds to BIG: always pruned, never wrong."""
    rng = np.random.default_rng(4)
    db = _uniform(rng, 3 * BR)
    mask = np.ones(db.shape[0], bool)
    mask[BR:2 * BR] = False  # block 1 fully dead
    summary = build_block_summary(db, BR, db_mask=mask)
    assert int(summary.n_alive[1]) == 0
    bounds = np.asarray(summary_block_bounds(jnp.asarray(db[:2]), summary))
    assert np.all(bounds[:, 1] == BIG_DIST)
    pruned = fixed_radius_nns(jnp.asarray(db[:2]), jnp.asarray(db), 64, K,
                              db_mask=jnp.asarray(mask), scan_block=24,
                              summary=summary)
    plain = fixed_radius_nns(jnp.asarray(db[:2]), jnp.asarray(db), 64, K,
                             db_mask=jnp.asarray(mask), scan_block=24)
    _assert_same(pruned, plain)


def test_builder_rejects_unaligned_block_rows():
    rng = np.random.default_rng(5)
    with pytest.raises(ValueError, match="multiple of 128"):
        build_block_summary(_uniform(rng, 256), 100)


def test_update_matches_cold_rebuild():
    """The upsert/delete maintenance rule: recomputed blocks bit-match a
    from-scratch build over the same (sigs, mask)."""
    rng = np.random.default_rng(6)
    db = _uniform(rng, 4 * BR + 40)  # ragged tail block
    mask = np.ones(db.shape[0], bool)
    summary = build_block_summary(db, BR, db_mask=mask)
    touched = np.asarray([0, 5, BR + 1, 3 * BR, db.shape[0] - 1])
    db[touched] = _uniform(rng, touched.size)
    mask[[5, 3 * BR]] = False  # tombstones must tighten, not loosen
    upd = update_block_summary(summary, db, mask, touched)
    cold = build_block_summary(db, BR, db_mask=mask)
    for f in ("or_sigs", "and_sigs", "min_pc", "max_pc", "n_alive"):
        np.testing.assert_array_equal(np.asarray(getattr(upd, f)),
                                      np.asarray(getattr(cold, f)),
                                      err_msg=f)


# ---------------------------------------------------------------------------
# pruned == unpruned, bit for bit, across the plan matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scan_block", [24, 256])
@pytest.mark.parametrize("superblock", [None, 256])
@pytest.mark.parametrize("masked", [False, True])
def test_pruned_streaming_bit_matches(scan_block, superblock, masked):
    rng = np.random.default_rng(7)
    queries, db = _clustered(rng)
    n = db.shape[0]
    mask = jnp.asarray(rng.random(n) > 0.25) if masked else None
    n_valid = n - 37
    summary = build_block_summary(db, BR, db_mask=mask, n_valid=n_valid)
    kw = dict(db_mask=mask, scan_block=scan_block, superblock=superblock,
              n_valid=n_valid)
    pruned = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db), 12, K,
                              summary=summary, **kw)
    plain = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db), 12, K,
                             **kw)
    _assert_same(pruned, plain)
    assert plain.blocks_touched is None
    touched = np.asarray(pruned.blocks_touched)
    assert touched.shape == (queries.shape[0],)
    assert np.all((touched >= 1) & (touched <= summary.n_blocks))
    # clustered layout + tight radius: each query admits its own block only
    assert np.all(touched < summary.n_blocks)


def test_prune_false_disables_and_drops_counter():
    rng = np.random.default_rng(8)
    queries, db = _clustered(rng)
    summary = build_block_summary(db, BR)
    res = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db), 12, K,
                           scan_block=24, summary=summary, prune=False)
    assert res.blocks_touched is None
    plain = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db), 12, K,
                             scan_block=24)
    _assert_same(res, plain)


def test_all_but_one_block_prunes():
    """Adversarial best case: every query matches exactly one cluster —
    every other block's bound exceeds the radius."""
    rng = np.random.default_rng(9)
    queries, db = _clustered(rng, n_clusters=8, flip_positions=12)
    summary = build_block_summary(db, BR)
    pruned = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db), 8, K,
                              scan_block=BR, summary=summary)
    plain = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db), 8, K,
                             scan_block=BR)
    _assert_same(pruned, plain)
    assert np.all(np.asarray(pruned.blocks_touched) == 1)


def test_no_block_prunes_on_uniform_noise():
    """Adversarial worst case: uniform random rows saturate the block OR
    (or ~ all-ones, and ~ all-zeros) so no block prunes — outputs still
    match and the counter honestly reports a full scan."""
    rng = np.random.default_rng(10)
    db = _uniform(rng, 4 * BR)
    queries = _uniform(rng, 6)
    summary = build_block_summary(db, BR)
    pruned = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db), 120, K,
                              scan_block=24, summary=summary)
    plain = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db), 120, K,
                             scan_block=24)
    _assert_same(pruned, plain)
    assert np.all(np.asarray(pruned.blocks_touched) == summary.n_blocks)


def test_dense_plan_ignores_summary():
    """scan_block=0 forces the dense plan: no pruning, no counter."""
    rng = np.random.default_rng(11)
    queries, db = _clustered(rng)
    summary = build_block_summary(db, BR)
    res = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db), 12, K,
                           scan_block=0, summary=summary)
    assert res.blocks_touched is None


def test_pruned_delta_aware_bit_matches():
    rng = np.random.default_rng(12)
    queries, db = _clustered(rng)
    n = db.shape[0]
    mask = jnp.asarray(rng.random(n) > 0.2)
    summary = build_block_summary(db, BR, db_mask=mask)
    cap = 32
    d_sigs = np.full((cap, WORDS), 0xFFFFFFFF, np.uint32)
    d_ids = np.full((cap,), 2**31 - 1, np.int32)
    d_sigs[:3] = queries[:3]
    d_ids[:3] = np.asarray([n + 5, n + 9, n + 11], np.int32)
    kw = dict(db_mask=mask, scan_block=24)
    pruned = delta_aware_nns(jnp.asarray(queries), jnp.asarray(db),
                             jnp.asarray(d_sigs), jnp.asarray(d_ids),
                             12, K, summary=summary, **kw)
    plain = delta_aware_nns(jnp.asarray(queries), jnp.asarray(db),
                            jnp.asarray(d_sigs), jnp.asarray(d_ids),
                            12, K, **kw)
    _assert_same(pruned, plain)
    assert pruned.blocks_touched is not None


@pytest.mark.parametrize("path", ["sharded", "query_parallel"])
def test_pruned_distributed_bit_matches(path):
    rng = np.random.default_rng(13)
    queries, db = _clustered(rng)
    summary = build_block_summary(db, BR)
    if path == "sharded":
        mesh = jax.make_mesh((1,), ("banks",))
        run = lambda **kw: sharded_fixed_radius_nns(  # noqa: E731
            mesh, "banks", jnp.asarray(queries), jnp.asarray(db), 12, K,
            scan_block=24, **kw)
    else:
        mesh = jax.make_mesh((1,), ("qp",))
        run = lambda **kw: query_parallel_nns(  # noqa: E731
            mesh, "qp", jnp.asarray(queries), jnp.asarray(db), 12, K,
            scan_block=24, **kw)
    pruned = run(summary=summary)
    plain = run()
    _assert_same(pruned, plain)
    touched = np.asarray(pruned.blocks_touched)
    assert np.all((touched >= 1) & (touched <= summary.n_blocks))


def test_sharded_misaligned_summary_falls_back_unpruned():
    """per_shard not a multiple of block_rows: pruning silently disables
    (results match, no counter) instead of mis-mapping blocks to shards."""
    rng = np.random.default_rng(14)
    queries, db = _clustered(rng, n_clusters=3)  # n=384; summary at 256
    summary = build_block_summary(db, 256)
    mesh = jax.make_mesh((1,), ("banks",))
    pruned = sharded_fixed_radius_nns(
        mesh, "banks", jnp.asarray(queries), jnp.asarray(db[:300]), 12, K,
        scan_block=24, summary=summary)
    plain = sharded_fixed_radius_nns(
        mesh, "banks", jnp.asarray(queries), jnp.asarray(db[:300]), 12, K,
        scan_block=24)
    _assert_same(pruned, plain)
    assert pruned.blocks_touched is None


# ---------------------------------------------------------------------------
# randomized property (hypothesis, where available)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(n_rows=st.integers(1, 500), n_queries=st.integers(1, 8),
           radius=st.integers(0, 256), scan_block=st.sampled_from([24, 200]),
           masked=st.booleans(), seed=st.integers(0, 2**16))
    def test_pruned_equals_unpruned_property(n_rows, n_queries, radius,
                                             scan_block, masked, seed):
        rng = np.random.default_rng(seed)
        db = _uniform(rng, n_rows)
        queries = _uniform(rng, n_queries)
        mask = jnp.asarray(rng.random(n_rows) > 0.3) if masked else None
        summary = build_block_summary(db, BR, db_mask=mask)
        kw = dict(db_mask=mask, scan_block=scan_block)
        pruned = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db),
                                  radius, K, summary=summary, **kw)
        plain = fixed_radius_nns(jnp.asarray(queries), jnp.asarray(db),
                                 radius, K, **kw)
        _assert_same(pruned, plain)
        touched = np.asarray(pruned.blocks_touched)
        assert np.all((touched >= 0) & (touched <= summary.n_blocks))
except ImportError:  # pragma: no cover - hypothesis is in the CI image
    pass


# ---------------------------------------------------------------------------
# engine level: the prune knob routes without changing a bit
# ---------------------------------------------------------------------------
def test_engine_prune_knob_serves_identically():
    from repro.data import synthetic
    from repro.models import recsys as rs
    from repro.serving import LiveCatalog, MicroBatcher, RecSysEngine

    data = synthetic.make_movielens(n_users=40, n_items=80, history_len=6)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=6)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=16,
                                top_k=5, hot_rows=32)
    assert engine.block_summary is not None

    cat = LiveCatalog(engine, delta_capacity=64)
    rng = np.random.default_rng(0)
    d = cat.engine.item_table_q.shape[1]
    cat.upsert(np.arange(200, 206, dtype=np.int32),
               rng.normal(size=(6, d)).astype(np.float32))
    cat.delete(np.asarray([2, 9], np.int32))
    eng = cat.engine

    # maintained summary bit-matches a cold rebuild over the base table
    cold = build_block_summary(np.asarray(eng.item_sigs),
                               eng.block_summary.block_rows,
                               db_mask=np.asarray(eng.item_mask))
    for f in ("or_sigs", "and_sigs", "min_pc", "max_pc", "n_alive"):
        np.testing.assert_array_equal(np.asarray(getattr(eng.block_summary,
                                                         f)),
                                      np.asarray(getattr(cold, f)),
                                      err_msg=f)

    streaming = dataclasses.replace(eng, scan_block=24)
    queries = synthetic.serving_queries(data, range(12))
    base = None
    for prune in (False, True, None):
        e = dataclasses.replace(streaming, prune=prune)
        out = MicroBatcher(e, max_batch=6).serve_many(queries)
        items = np.stack([o.items for o in out])
        scores = np.stack([o.scores for o in out])
        if base is None:
            base = (items, scores)
        else:
            np.testing.assert_array_equal(items, base[0])
            np.testing.assert_array_equal(scores, base[1])


def test_summary_is_pytree_with_static_block_rows():
    rng = np.random.default_rng(15)
    summary = build_block_summary(_uniform(rng, 2 * BR), BR)
    leaves, treedef = jax.tree.flatten(summary)
    assert len(leaves) == 5
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, BlockSummary)
    assert rebuilt.block_rows == BR  # static metadata survives the pytree
