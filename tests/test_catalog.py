"""Live-catalog churn matrix: every update sequence must serve bit-identically
to an engine rebuilt from scratch with the final table — items, scores, AND
hot-cache counters (the cache is invalidated only for touched rows, and the
reference pins exactly the surviving hot set). Pre- and post-compaction,
through the synchronous batcher and the AsyncServer ring alike.

Runs in the CI pallas-interpret lane too: the masked streaming tests below
drive the real kernel body with the tombstone-mask operand.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.nns import (
    EMPTY_ID,
    delta_aware_nns,
    delta_scan,
    fixed_radius_nns,
    merge_delta_candidates,
)
from repro.data import synthetic
from repro.data.synthetic import serving_queries as _queries
from repro.models import recsys as rs
from repro.serving import (
    AsyncServer,
    DeltaFullError,
    LiveCatalog,
    MicroBatcher,
    RecSysEngine,
    SchemaMismatchError,
    invalidate_rows,
    pin_rows,
)
from repro.serving.hot_cache import INVALID_ID, cached_lookup


@pytest.fixture(scope="module")
def served():
    data = synthetic.make_movielens(n_users=120, n_items=90, history_len=6)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=6)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=data.n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=16,
                                top_k=5, hot_rows=32, item_freqs=freqs)
    return engine, data


def _rows(rng, m, d):
    return rng.normal(size=(m, d)).astype(np.float32)


def _serve(engine, queries, max_batch=8):
    server = MicroBatcher(engine, max_batch=max_batch)
    out = server.serve_many(queries)
    return (np.stack([o.items for o in out]),
            np.stack([o.scores for o in out]),
            (int(server._stats.hits), int(server._stats.lookups)))


def _assert_matches_reference(cat, queries):
    """serve(live) == serve(rebuilt-from-final-table), bit for bit."""
    items, scores, stats = _serve(cat.engine, queries)
    r_items, r_scores, r_stats = _serve(cat.rebuild_reference(), queries)
    np.testing.assert_array_equal(items, r_items)
    np.testing.assert_array_equal(scores, r_scores)
    assert stats == r_stats
    return items, scores, stats


# ---------------------------------------------------------------------------
# kernel/NNS layer: tombstone masks + delta merge are exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [
    {"scan_block": 64}, {"scan_block": 200},
    {"scan_block": 64, "superblock": 256},
    {"scan_block": 64, "n_valid": 600},
])
def test_masked_streaming_matches_dense(kw):
    """db_mask (tombstones) on the streaming plan — any chunk/superblock —
    bit-matches the dense masked path (kernel + ref + interpret)."""
    rng = np.random.default_rng(0)
    db = jnp.asarray(rng.integers(0, 2**32, (700, 8), dtype=np.uint32))
    qs = jnp.asarray(rng.integers(0, 2**32, (9, 8), dtype=np.uint32))
    mask = jnp.asarray(rng.random(700) > 0.3)
    want = fixed_radius_nns(qs, db, 120, 16, db_mask=mask, scan_block=0,
                            n_valid=kw.get("n_valid"))
    got = fixed_radius_nns(qs, db, 120, 16, db_mask=mask, **kw)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("scan_block", [0, 64])
def test_delta_aware_nns_matches_rebuilt(scan_block):
    """base+delta+merge == one dense scan over the folded final table, for
    overwrites (ids interleave with base), new ids, and deletions."""
    rng = np.random.default_rng(1)
    n, words, D = 500, 8, 64
    db = rng.integers(0, 2**32, (n, words), dtype=np.uint32)
    qs = jnp.asarray(rng.integers(0, 2**32, (7, words), dtype=np.uint32))
    over = rng.choice(n, 30, replace=False)
    new = np.arange(n, n + 10)
    ids = np.sort(np.concatenate([over, new]).astype(np.int32))
    delta_ids = np.full(D, EMPTY_ID, np.int32)
    delta_ids[: len(ids)] = ids
    dsigs = rng.integers(0, 2**32, (D, words), dtype=np.uint32)
    deleted = rng.choice(np.setdiff1d(np.arange(n), over), 12, replace=False)
    alive = np.ones(n, bool)
    alive[over] = False
    alive[deleted] = False

    folded = np.zeros((n + 10, words), np.uint32)
    folded[:n] = db
    folded[ids] = dsigs[: len(ids)]
    folded_alive = np.concatenate([alive, np.zeros(10, bool)])
    folded_alive[ids] = True
    want = fixed_radius_nns(qs, jnp.asarray(folded), 120, 16,
                            db_mask=jnp.asarray(folded_alive), scan_block=0)
    got = delta_aware_nns(qs, jnp.asarray(db), jnp.asarray(dsigs),
                          jnp.asarray(delta_ids), 120, 16,
                          db_mask=jnp.asarray(alive), scan_block=scan_block)
    for name, a, b in zip(("indices", "distances", "counts"), want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_empty_delta_merge_is_identity():
    """An all-free delta shard changes nothing — the steady-state serve."""
    rng = np.random.default_rng(2)
    db = jnp.asarray(rng.integers(0, 2**32, (300, 8), dtype=np.uint32))
    qs = jnp.asarray(rng.integers(0, 2**32, (5, 8), dtype=np.uint32))
    base = fixed_radius_nns(qs, db, 120, 16)
    pend = delta_scan(qs, jnp.zeros((32, 8), jnp.uint32),
                      jnp.full((32,), EMPTY_ID, jnp.int32), 120, 16)
    assert int(jnp.sum(pend.counts)) == 0
    got = merge_delta_candidates(base, pend, 16)
    for a, b in zip(base, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_query_parallel_delta_scan_matches_replicated():
    """The query-sharded delta scan (size-1 mesh: exercises the shard_map
    spec path without multi-device) bit-matches the replicated scan."""
    from repro.core.nns import query_parallel_delta_scan

    rng = np.random.default_rng(5)
    qs = jnp.asarray(rng.integers(0, 2**32, (7, 8), dtype=np.uint32))
    dsigs = jnp.asarray(rng.integers(0, 2**32, (32, 8), dtype=np.uint32))
    dids = np.full(32, EMPTY_ID, np.int32)
    dids[:10] = np.sort(rng.choice(500, 10, replace=False))
    dids = jnp.asarray(dids)
    mesh = jax.make_mesh((1,), ("qp",))
    want = delta_scan(qs, dsigs, dids, 120, 16)
    got = query_parallel_delta_scan(mesh, "qp", qs, dsigs, dids, 120, 16)
    for name, a, b in zip(("indices", "distances", "counts"), want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


@pytest.mark.slow
def test_query_parallel_delta_scan_two_devices_subprocess():
    """Regression: the delta-shard scan used to run fully replicated on
    query-sharded mesh plans (every device scanning every query). On 2
    fake CPU devices the query-sharded scan — odd query count, so the pad
    row is exercised — must bit-match the replicated path, and the
    query-routed engine must serve identically to the local one under a
    live delta."""
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.nns import (EMPTY_ID, delta_scan,
                                    query_parallel_delta_scan)
        rng = np.random.default_rng(0)
        qs = jnp.asarray(rng.integers(0, 2**32, (5, 8), dtype=np.uint32))
        dsigs = jnp.asarray(rng.integers(0, 2**32, (64, 8), dtype=np.uint32))
        dids = np.full(64, EMPTY_ID, np.int32)
        dids[:20] = np.sort(rng.choice(900, 20, replace=False))
        dids = jnp.asarray(dids)
        mesh = jax.make_mesh((2,), ("qp",))
        want = delta_scan(qs, dsigs, dids, 110, 16)
        got = query_parallel_delta_scan(mesh, "qp", qs, dsigs, dids, 110, 16)
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("MARKER delta qp ok", jax.device_count())
    """)
    import os
    import pathlib

    repo = pathlib.Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=420, cwd=repo,
        env={"PYTHONPATH": str(repo / "src"),
             "HOME": os.environ.get("HOME", str(repo)),
             "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-3000:]
    assert "MARKER delta qp ok 2" in out.stdout


# ---------------------------------------------------------------------------
# churn scenario matrix (engine-level bit-match vs rebuilt frozen engine)
# ---------------------------------------------------------------------------
def test_upsert_new_rows_bitmatch(served):
    """Brand-new item ids extend the catalog through the delta and become
    retrievable immediately; serving bit-matches the rebuilt engine before
    and after compaction."""
    engine, data = served
    rng = np.random.default_rng(10)
    cat = LiveCatalog(engine, delta_capacity=16)
    queries = _queries(data, np.arange(25) % 60)
    cat.upsert(np.arange(90, 96), _rows(rng, 6, engine.cfg.embed_dim))
    assert cat.n_pending == 6 and cat.n_items == 96
    pre = _assert_matches_reference(cat, queries)
    cat.compact()
    assert cat.epoch == 1 and cat.n_pending == 0
    post = _assert_matches_reference(cat, queries)
    np.testing.assert_array_equal(pre[0], post[0])  # compaction moves no bit
    np.testing.assert_array_equal(pre[1], post[1])


def test_overwrite_hot_cached_rows_bitmatch(served):
    """Re-embedding rows pinned in the hot cache: the touched rows leave
    the hot set (stale pins can never serve), everything else stays pinned,
    and results + counters bit-match the reference."""
    engine, data = served
    rng = np.random.default_rng(11)
    cat = LiveCatalog(engine, delta_capacity=16)
    hot = np.asarray(engine.item_hot.hot_ids)[:4]
    assert (hot != INVALID_ID).all()
    queries = _queries(data, np.arange(25) % 60)
    base_stats = _serve(cat.engine, queries)[2]
    cat.upsert(hot, _rows(rng, len(hot), engine.cfg.embed_dim))
    live_ids = np.asarray(cat.engine.item_hot.hot_ids)
    assert not np.isin(hot, live_ids).any()  # evicted
    assert (live_ids == INVALID_ID).sum() == len(hot)  # only touched rows
    _, _, stats = _assert_matches_reference(cat, queries)
    # the touched ids are top-frequency history items: their pooling
    # lookups now miss the hot set (lookup counts themselves shift with
    # the changed candidate sets; the reference equality above is the
    # binding contract)
    assert stats[0] < base_stats[0]
    cat.compact()
    _assert_matches_reference(cat, queries)


def test_delete_then_readd_bitmatch(served):
    """delete -> (absent from every result) -> re-add same id -> rankable
    again with the new embedding; bit-match at every step."""
    engine, data = served
    rng = np.random.default_rng(12)
    cat = LiveCatalog(engine, delta_capacity=16)
    queries = _queries(data, np.arange(25) % 60)
    victim = np.asarray(_serve(cat.engine, queries)[0])
    victim = int(victim[victim >= 0].flat[0])  # an id that actually serves

    cat.delete([victim])
    items, _, _ = _assert_matches_reference(cat, queries)
    assert not (items == victim).any()  # tombstoned everywhere
    cat.upsert([victim], _rows(rng, 1, engine.cfg.embed_dim))
    assert cat.n_pending == 1
    _assert_matches_reference(cat, queries)
    cat.compact()
    items, _, _ = _assert_matches_reference(cat, queries)
    # post-compaction the id lives in the new base epoch
    assert bool(np.asarray(cat.engine.item_mask)[victim])


def test_delta_full_forces_compact(served):
    """Overflowing the bounded delta forces an epoch fold first (the update
    itself still lands); auto_compact=False surfaces DeltaFullError; a
    batch larger than the shard can never fit."""
    engine, data = served
    rng = np.random.default_rng(13)
    queries = _queries(data, np.arange(25) % 60)
    cat = LiveCatalog(engine, delta_capacity=4)
    cat.upsert([0, 1, 2], _rows(rng, 3, engine.cfg.embed_dim))
    assert cat.epoch == 0
    cat.upsert([3, 4], _rows(rng, 2, engine.cfg.embed_dim))  # 5 > 4: fold
    assert cat.epoch == 1 and cat.n_pending == 2
    _assert_matches_reference(cat, queries)

    frozen = LiveCatalog(engine, delta_capacity=4, auto_compact=False)
    frozen.upsert([0, 1, 2], _rows(rng, 3, engine.cfg.embed_dim))
    with pytest.raises(DeltaFullError):
        frozen.upsert([3, 4], _rows(rng, 2, engine.cfg.embed_dim))
    with pytest.raises(DeltaFullError):  # can never fit, even post-compact
        cat.upsert(np.arange(5), _rows(rng, 5, engine.cfg.embed_dim))


def test_compact_during_pipelined_serving_depth3(served):
    """Epoch swap under the AsyncServer ring at depth 3: buckets dispatched
    before the swap finish on the old epoch, buckets after serve the new
    one — every bucket is entirely one epoch, asserted bucket by bucket
    against the two rebuilt frozen references."""
    engine, data = served
    rng = np.random.default_rng(14)
    cat = LiveCatalog(engine, delta_capacity=16)
    cat.upsert(np.arange(90, 94), _rows(rng, 4, engine.cfg.embed_dim))
    old_ref = cat.rebuild_reference()

    pipe = AsyncServer(cat.engine, max_batch=8, depth=3)
    cat.attach(pipe)
    idx = np.arange(48) % 60
    tickets = [pipe.submit(q) for q in _queries(data, idx)]
    # dispatch the first two buckets onto the ring, then swap epochs
    for _ in range(2):
        pipe._ring.append(pipe._dispatch(pipe._take_parts()))
    assert pipe.in_flight == 2
    cat.upsert(np.arange(94, 98), _rows(rng, 4, engine.cfg.embed_dim))
    cat.compact()  # publishes the new epoch to the attached server
    new_ref = cat.rebuild_reference()
    pipe.flush()

    got = np.stack([pipe.result(t).items for t in tickets])
    want_old = _serve(old_ref, _queries(data, idx))[0]
    want_new = _serve(new_ref, _queries(data, idx))[0]
    np.testing.assert_array_equal(got[:16], want_old[:16])  # old epoch
    np.testing.assert_array_equal(got[16:], want_new[16:])  # new epoch
    # never stale once flushed: a fresh stream is pure new-epoch
    out = pipe.serve_many(_queries(data, idx))
    np.testing.assert_array_equal(np.stack([o.items for o in out]), want_new)


def test_snapshot_restore_roundtrip(served, tmp_path):
    """Epoch-numbered snapshot through the fault-tolerant checkpointer:
    restore reproduces the exact engine (delta + tombstones + caches) and
    serves bit-identically."""
    engine, data = served
    rng = np.random.default_rng(15)
    queries = _queries(data, np.arange(17) % 60)
    cat = LiveCatalog(engine, delta_capacity=16)
    cat.upsert([5, 6, 90], _rows(rng, 3, engine.cfg.embed_dim))
    cat.compact()
    cat.delete([7])
    cat.upsert([8], _rows(rng, 1, engine.cfg.embed_dim))
    want = _serve(cat.engine, queries)
    cat.snapshot(tmp_path)

    other = LiveCatalog(cat.engine, delta_capacity=16)  # structural template
    other.restore(tmp_path)
    assert other.epoch == 1
    got = _serve(other.engine, queries)
    np.testing.assert_array_equal(want[0], got[0])
    np.testing.assert_array_equal(want[1], got[1])
    assert want[2] == got[2]


def test_live_serving_on_mesh_plans(served):
    """The delta path composes with the bank-sharded / query-parallel NNS
    routes (tombstone mask rides the banks) without changing one bit."""
    engine, data = served
    rng = np.random.default_rng(16)
    cat = LiveCatalog(engine, delta_capacity=16)
    cat.upsert([0, 1, 90], _rows(rng, 3, engine.cfg.embed_dim))
    cat.delete([2])
    queries = _queries(data, np.arange(9) % 60)
    want = _serve(cat.engine, queries)
    mesh = jax.make_mesh((1,), ("banks",))
    qmesh = jax.make_mesh((1,), ("qp",))
    for live in (cat.engine.shard(mesh, "banks"),
                 cat.engine.shard(qmesh, query_axis="qp"),
                 cat.engine.compact().shard(mesh, "banks")):
        got = _serve(live, queries)
        np.testing.assert_array_equal(want[0], got[0])
        np.testing.assert_array_equal(want[1], got[1])


def test_retired_new_id_in_history_bitmatch(served):
    """A beyond-base id that was launched then retired can linger in user
    histories; pooling must resolve it identically (the canonical zero
    row) on the live engine, its compaction, AND the reference rebuild —
    whose base tables are different sizes (clamped gathers would diverge).
    """
    engine, data = served
    rng = np.random.default_rng(17)
    cat = LiveCatalog(engine, delta_capacity=16)
    cat.upsert([95, 99], _rows(rng, 2, engine.cfg.embed_dim))
    cat.delete([95])  # gap id: dead, below n_total on the rebuilt table

    idx = np.arange(6)
    hist = data.histories[idx].copy()
    hist[:, 0] = 95  # retired new-id still in everyone's history
    batch = {
        **{k: jnp.asarray(v[idx]) for k, v in data.user_feats.items()},
        "history": jnp.asarray(hist), "genre": jnp.asarray(data.genres[idx]),
    }
    live = cat.engine.serve(batch)
    ref = cat.rebuild_reference().serve(batch)
    np.testing.assert_array_equal(np.asarray(live.items),
                                  np.asarray(ref.items))
    np.testing.assert_array_equal(np.asarray(live.topk.scores),
                                  np.asarray(ref.topk.scores))
    post = cat.engine.compact().serve(batch)
    np.testing.assert_array_equal(np.asarray(live.items),
                                  np.asarray(post.items))


# ---------------------------------------------------------------------------
# units: hot-row invalidation + epoch swap guards
# ---------------------------------------------------------------------------
def test_invalidate_and_pin_rows_units(served):
    engine, _ = served
    cache = engine.item_hot
    victims = np.asarray(cache.hot_ids)[[1, 3]]
    out = invalidate_rows(cache, victims)
    assert out.capacity == cache.capacity
    ids = np.asarray(out.hot_ids)
    assert not np.isin(victims, ids[ids != INVALID_ID]).any()
    assert (np.diff(ids) >= 0).all()  # searchsorted contract survives
    assert (np.asarray(out.hot_rows)[ids == INVALID_ID] == 0).all()
    # untouched ids still hit, with identical pinned rows
    keep = ids[ids != INVALID_ID][:4]
    rows, st = cached_lookup(out, engine.item_table_q, jnp.asarray(keep))
    ref, _ = cached_lookup(cache, engine.item_table_q, jnp.asarray(keep))
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(ref))
    assert int(st.hits) == len(keep)
    # no-op invalidation returns the same cache object
    assert invalidate_rows(cache, np.asarray([10**9])) is cache
    # pin_rows reproduces an invalidated cache's surviving set exactly
    repin = pin_rows(engine.item_table_q, ids[ids != INVALID_ID],
                     cache.capacity)
    np.testing.assert_array_equal(np.asarray(repin.hot_ids), ids)
    np.testing.assert_array_equal(np.asarray(repin.hot_rows),
                                  np.asarray(out.hot_rows))


def test_swap_engine_rejects_schema_change(served):
    engine, _ = served
    server = MicroBatcher(engine, max_batch=8)
    cfg = engine.cfg._replace(user_features={"user_id": 10})
    with pytest.raises(SchemaMismatchError, match="schema"):
        server.swap_engine(dataclasses.replace(engine, cfg=cfg))


def test_frozen_engine_stays_frozen(served):
    """A frozen engine (delta=None) refuses updates with a pointer to the
    catalog, and an empty live view serves bit-identically to frozen."""
    engine, data = served
    with pytest.raises(ValueError, match="delta"):
        engine.apply_updates(upsert_ids=[0], upsert_rows=np.zeros((1, 32)))
    queries = _queries(data, np.arange(9) % 60)
    frozen = _serve(engine, queries)
    live = _serve(engine.live(8), queries)
    np.testing.assert_array_equal(frozen[0], live[0])
    np.testing.assert_array_equal(frozen[1], live[1])
    assert frozen[2] == live[2]


# ---------------------------------------------------------------------------
# property: ANY churn interleaving bit-matches the cold rebuild (hypothesis)
# ---------------------------------------------------------------------------
def test_any_churn_interleaving_bitmatches_cold_rebuild(served):
    """Property over the whole op space the matrix above samples: for any
    interleaving of upsert / delete / compact batches (ids overlapping,
    beyond-base, re-deleted; delta overflow auto-compacting mid-sequence)
    the live catalog serves bit-identically to a cold rebuild of the
    final table. Row payloads are a deterministic function of (id, salt),
    so every example is exactly reproducible from its shrunk form."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    engine, data = served
    d = engine.cfg.embed_dim
    queries = list(_queries(data, np.arange(15) % 60))

    ids_st = st.lists(st.integers(0, 99), min_size=1, max_size=4,
                      unique=True)
    op_st = st.one_of(
        st.tuples(st.just("upsert"), ids_st, st.integers(0, 2**16)),
        st.tuples(st.just("delete"), ids_st, st.just(0)),
        st.tuples(st.just("compact"), st.just([]), st.just(0)),
    )

    def row(gid, salt):
        return np.random.default_rng([int(gid), int(salt)]).normal(
            size=(d,)).astype(np.float32)

    @settings(max_examples=10, deadline=None)
    @given(ops=st.lists(op_st, min_size=1, max_size=6))
    def run(ops):
        cat = LiveCatalog(engine, delta_capacity=8)
        for kind, ids, salt in ops:
            if kind == "upsert":
                cat.upsert(ids, np.stack([row(g, salt) for g in ids]))
            elif kind == "delete":
                cat.delete(ids)
            else:
                cat.compact()
        _assert_matches_reference(cat, queries)

    run()
