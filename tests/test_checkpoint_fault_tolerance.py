import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, restore, save
from repro.distributed.fault_tolerance import (
    FaultPolicy,
    SimulatedTransientFailure,
    TrainLoop,
)


def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v)}, "step": jnp.int32(v)}


def test_save_restore_roundtrip(tmp_path):
    s = _state(3.0)
    save(tmp_path, 7, s)
    assert latest_step(tmp_path) == 7
    template = jax.eval_shape(lambda: _state())
    r = restore(tmp_path, 7, template)
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]), 3.0)
    assert int(r["step"]) == 3


def test_atomic_commit_ignores_uncommitted(tmp_path):
    save(tmp_path, 1, _state(1.0))
    # simulate a crash: uncommitted dir with a bigger step number
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 1  # COMMITTED marker missing -> ignored


def test_checksum_verification(tmp_path):
    save(tmp_path, 1, _state(1.0))
    # corrupt a leaf
    leaf = next((tmp_path / "step_00000001").glob("*.npy"))
    arr = np.load(leaf)
    arr = arr + 1
    np.save(leaf, arr)
    with pytest.raises(IOError):
        restore(tmp_path, 1, jax.eval_shape(lambda: _state()))


def test_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(float(s)))
    ck.wait()
    assert latest_step(tmp_path) == 4
    assert not (tmp_path / "step_00000001").exists()
    assert (tmp_path / "step_00000003").exists()


def test_async_save(tmp_path):
    ck = Checkpointer(tmp_path, async_=True)
    ck.save(5, _state(5.0))
    ck.wait()
    assert latest_step(tmp_path) == 5


def _toy_train_step(state, batch):
    w = state["params"]["w"] + batch["x"].sum()
    return ({"params": {"w": w}, "step": state["step"] + 1},
            {"loss": jnp.sum(w)})


def _data():
    i = 0
    while True:
        yield {"x": jnp.full((2,), 0.5)}
        i += 1


def test_trainloop_checkpoint_restart_bitwise(tmp_path):
    """Kill mid-run, restart, final state must equal an uninterrupted run."""
    policy = FaultPolicy(checkpoint_every=5)

    # uninterrupted reference
    ck0 = Checkpointer(tmp_path / "ref")
    loop0 = TrainLoop(_toy_train_step, ck0, policy)
    ref_state, _ = loop0.run(_state(0.0), _data(), 12)

    # crash at step 8 (after the step-5 checkpoint)
    ck1 = Checkpointer(tmp_path / "crash")
    crashes = {"armed": True}

    def bomb(step):
        if step == 8 and crashes["armed"]:
            crashes["armed"] = False
            raise KeyboardInterrupt  # hard kill, not a retryable fault

    loop1 = TrainLoop(_toy_train_step, ck1, policy, fault_hook=bomb)
    with pytest.raises(KeyboardInterrupt):
        loop1.run(_state(0.0), _data(), 12)

    # restart: resume from checkpoint 5, replay the data stream from there.
    # the toy stream is stateless-per-step so skipping consumed batches is a
    # no-op; real pipelines restore their cursor from the step number.
    loop2 = TrainLoop(_toy_train_step, Checkpointer(tmp_path / "crash"),
                      policy)
    state, start = loop2.resume_or_init(lambda: _state(0.0))
    assert start == 5
    final, _ = loop2.run(state, _data(), 12, start_step=start)
    np.testing.assert_array_equal(np.asarray(final["params"]["w"]),
                                  np.asarray(ref_state["params"]["w"]))


def test_trainloop_retries_transient(tmp_path):
    attempts = {"n": 0}

    def flaky(step):
        if step == 3 and attempts["n"] < 2:
            attempts["n"] += 1
            raise SimulatedTransientFailure("link flap")

    loop = TrainLoop(_toy_train_step, Checkpointer(tmp_path),
                     FaultPolicy(max_retries_per_step=3), fault_hook=flaky)
    _, end = loop.run(_state(0.0), _data(), 6)
    assert end == 6
    rec = [r for r in loop.records if r.step == 3][0]
    assert rec.retries == 2


def test_trainloop_straggler_detection(tmp_path):
    import time

    def slow_step(state, batch):
        if int(state["step"]) == 4:
            time.sleep(0.25)
        else:
            time.sleep(0.01)
        return _toy_train_step(state, batch)

    loop = TrainLoop(slow_step, Checkpointer(tmp_path),
                     FaultPolicy(straggler_factor=5.0))
    loop.run(_state(0.0), _data(), 8)
    assert 4 in loop.straggler_events


def test_elastic_restore_different_sharding(tmp_path):
    """Checkpoint written under one mesh restores onto another (resharding
    happens at load — elastic scaling)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    s = {"w": jnp.arange(16.0).reshape(4, 4)}
    save(tmp_path, 1, s)
    mesh = jax.make_mesh((1,), ("data",))
    shard = {"w": NamedSharding(mesh, P("data", None))}
    r = restore(tmp_path, 1, jax.eval_shape(lambda: s), shardings=shard)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(s["w"]))
    assert r["w"].sharding == shard["w"]
