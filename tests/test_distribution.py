"""Distribution-layer tests: param spec rules + real multi-device lowering
(subprocess: 8 fake CPU devices so the main process keeps 1 device)."""
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from jax.sharding import PartitionSpec as P

from repro.configs.reduced import reduce_config
from repro.configs.registry import get_arch
from repro.distributed.sharding import (
    ShardingRules,
    constrain,
    param_partition_specs,
    use_rules,
)
from repro.models import transformer as tf

HELPER = pathlib.Path(__file__).parent / "helpers" / "mini_dryrun.py"


def _run_helper(arch, mesh="single", timeout=420):
    out = subprocess.run(
        [sys.executable, str(HELPER), arch, mesh],
        capture_output=True, text=True, timeout=timeout,
        cwd="/root/repo", env={"PYTHONPATH": "src", "HOME": "/root",
                               "PATH": "/usr/local/bin:/usr/bin:/bin",
                               # a bare env must still pin the CPU backend:
                               # with libtpu installed, TPU plugin init
                               # blocks forever on /tmp/libtpu_lockfile
                               "JAX_PLATFORMS": "cpu"},
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2.5-3b", "phi3.5-moe-42b-a6.6b",
                                  "mamba2-1.3b", "zamba2-1.2b",
                                  "musicgen-large"])
def test_mini_dryrun_single(arch):
    out = _run_helper(arch, "single")
    assert "MARKER train ok" in out
    assert "MARKER prefill ok" in out
    assert "MARKER decode ok" in out


@pytest.mark.slow
def test_mini_dryrun_multi_pod():
    out = _run_helper("qwen2.5-3b", "multi")
    assert "MARKER decode ok" in out


def test_param_specs_follow_rules():
    cfg = reduce_config(get_arch("qwen3-8b").model)
    params = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.key(0)))
    rules = ShardingRules(data_axes=("data",), fsdp=True)
    specs = param_partition_specs(params, rules)
    # embeddings: vocab over model, embed over data (fsdp)
    assert specs["embed"] == P("model", ("data",))
    # attention qkv: embed over data, heads over model
    assert specs["layers"]["attn"]["wq"]["w"] == P(None, ("data",), "model")
    # norms replicated
    assert specs["final_norm"] == P(None)
    # without fsdp the data axis disappears
    specs2 = param_partition_specs(
        params, ShardingRules(data_axes=("data",), fsdp=False))
    assert specs2["embed"] == P("model", None)


def test_moe_param_specs():
    cfg = reduce_config(get_arch("phi3.5-moe-42b-a6.6b").model)
    params = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.key(0)))
    rules = ShardingRules(data_axes=("pod", "data"), fsdp=True)
    specs = param_partition_specs(params, rules)
    # expert-stacked weights: EP over model, inner dim over (pod, data)
    assert specs["layers"]["moe"]["wi"] == P(None, "model", ("pod", "data"), None)


def test_constrain_noop_without_rules():
    x = jnp.ones((4, 4))
    y = constrain(x, ("act_batch", None))
    assert y is x


def test_unshardable_heads_rules():
    rules = ShardingRules(shard_heads=False)
    assert rules.act_axis("act_heads") is None
    rules2 = ShardingRules(shard_heads=True)
    assert rules2.act_axis("act_heads") == "model"
