"""Docs stay alive: the public serving/NNS API surface must carry real
docstrings, and every path referenced from docs/*.md + ROADMAP.md must
exist (tools/check_docs.py — also a CI step)."""
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _public_api():
    """(name, object) pairs whose docstrings the docs sweep guarantees."""
    from repro import obs
    from repro.core import nns
    from repro.kernels import ops
    from repro.obs import registry as obs_registry
    from repro.obs import tracing as obs_tracing
    from repro.serving import (
        AsyncServer,
        ConcurrentFrontend,
        DeltaShard,
        LiveCatalog,
        LoadGen,
        MicroBatcher,
        RecSysEngine,
        Server,
        async_server,
        batcher,
        catalog,
        filter_step,
        frontend,
        hot_cache,
        load_gen,
        lookup_step,
        make_server,
        rank_stage_step,
        rank_step,
        recsys_engine,
        scan_step,
        serve_step,
        server,
        stats_view,
        summarize_trace,
    )

    return [
        # observability layer
        ("obs", obs),
        ("obs.registry", obs_registry),
        ("obs.tracing", obs_tracing),
        ("MetricsRegistry", obs.MetricsRegistry),
        ("MetricsRegistry.count", obs.MetricsRegistry.count),
        ("MetricsRegistry.observe", obs.MetricsRegistry.observe),
        ("MetricsRegistry.gauge", obs.MetricsRegistry.gauge),
        ("MetricsRegistry.event", obs.MetricsRegistry.event),
        ("MetricsRegistry.register_collector",
         obs.MetricsRegistry.register_collector),
        ("MetricsRegistry.snapshot", obs.MetricsRegistry.snapshot),
        ("MetricsRegistry.to_prometheus", obs.MetricsRegistry.to_prometheus),
        ("TicketTrace", obs.TicketTrace),
        ("stage_durations", obs.stage_durations),
        ("well_ordered", obs.well_ordered),
        ("dump_trace", obs.dump_trace),
        ("stats_view", stats_view),
        ("MicroBatcher.snapshot", MicroBatcher.snapshot),
        ("MicroBatcher.take_trace", MicroBatcher.take_trace),
        # modules
        ("serving.batcher", batcher),
        ("serving.async_server", async_server),
        ("serving.recsys_engine", recsys_engine),
        ("serving.hot_cache", hot_cache),
        ("serving.catalog", catalog),
        ("core.nns", nns),
        ("kernels.ops", ops),
        # live catalog subsystem
        ("LiveCatalog", LiveCatalog),
        ("LiveCatalog.attach", LiveCatalog.attach),
        ("LiveCatalog.apply_updates", LiveCatalog.apply_updates),
        ("LiveCatalog.upsert", LiveCatalog.upsert),
        ("LiveCatalog.delete", LiveCatalog.delete),
        ("LiveCatalog.compact", LiveCatalog.compact),
        ("LiveCatalog.snapshot", LiveCatalog.snapshot),
        ("LiveCatalog.restore", LiveCatalog.restore),
        ("DeltaShard", DeltaShard),
        ("catalog.materialize", catalog.materialize),
        ("catalog.rebuild_reference", catalog.rebuild_reference),
        ("catalog.engine_apply_updates", catalog.engine_apply_updates),
        ("catalog.compact_engine", catalog.compact_engine),
        ("core.nns.delta_aware_nns", nns.delta_aware_nns),
        ("core.nns.delta_scan", nns.delta_scan),
        ("core.nns.merge_delta_candidates", nns.merge_delta_candidates),
        ("hot_cache.invalidate_rows", hot_cache.invalidate_rows),
        ("hot_cache.pin_rows", hot_cache.pin_rows),
        ("MicroBatcher.swap_engine", MicroBatcher.swap_engine),
        ("RecSysEngine.live", RecSysEngine.live),
        ("RecSysEngine.apply_updates", RecSysEngine.apply_updates),
        ("RecSysEngine.compact", RecSysEngine.compact),
        # engine + methods
        ("RecSysEngine", RecSysEngine),
        ("RecSysEngine.build", RecSysEngine.build),
        ("RecSysEngine.shard", RecSysEngine.shard),
        ("RecSysEngine.serve", RecSysEngine.serve),
        ("RecSysEngine.filter_stage", RecSysEngine.filter_stage),
        ("RecSysEngine.rank_stage", RecSysEngine.rank_stage),
        # batching front-ends
        ("MicroBatcher", MicroBatcher),
        ("MicroBatcher.submit", MicroBatcher.submit),
        ("MicroBatcher.result", MicroBatcher.result),
        ("MicroBatcher.serve_many", MicroBatcher.serve_many),
        ("MicroBatcher.flush", MicroBatcher.flush),
        ("AsyncServer", AsyncServer),
        ("AsyncServer.flush", AsyncServer.flush),
        ("AsyncServer.in_flight", AsyncServer.in_flight.fget),
        # the unified Server API + concurrent tier + load harness
        ("serving.server", server),
        ("serving.frontend", frontend),
        ("serving.load_gen", load_gen),
        ("Server", Server),
        ("make_server", make_server),
        ("MicroBatcher.close", MicroBatcher.close),
        ("MicroBatcher.stats", MicroBatcher.stats),
        ("ConcurrentFrontend", ConcurrentFrontend),
        ("ConcurrentFrontend.submit", ConcurrentFrontend.submit),
        ("ConcurrentFrontend.result", ConcurrentFrontend.result),
        ("ConcurrentFrontend.flush", ConcurrentFrontend.flush),
        ("ConcurrentFrontend.close", ConcurrentFrontend.close),
        ("ConcurrentFrontend.stats", ConcurrentFrontend.stats),
        ("ConcurrentFrontend.swap_engine", ConcurrentFrontend.swap_engine),
        ("ConcurrentFrontend.take_trace", ConcurrentFrontend.take_trace),
        ("LoadGen", LoadGen),
        ("LoadGen.schedule", LoadGen.schedule),
        ("LoadGen.replay", LoadGen.replay),
        ("summarize_trace", summarize_trace),
        # jitted steps (fused + staged)
        ("serve_step", serve_step),
        ("filter_step", filter_step),
        ("rank_step", rank_step),
        ("lookup_step", lookup_step),
        ("scan_step", scan_step),
        ("rank_stage_step", rank_stage_step),
        # NNS entries
        ("fixed_radius_nns", nns.fixed_radius_nns),
        ("BlockSummary", nns.BlockSummary),
        ("build_block_summary", nns.build_block_summary),
        ("update_block_summary", nns.update_block_summary),
        ("summary_block_bounds", nns.summary_block_bounds),
        ("fixed_radius_nns_async", nns.fixed_radius_nns_async),
        ("sharded_fixed_radius_nns", nns.sharded_fixed_radius_nns),
        ("query_parallel_nns", nns.query_parallel_nns),
        ("cosine_topk", nns.cosine_topk),
        # hot cache
        ("build_hot_cache", hot_cache.build_hot_cache),
        ("cached_lookup", hot_cache.cached_lookup),
        ("cached_embedding_bag", hot_cache.cached_embedding_bag),
        # kernel registry
        ("register_kernel", ops.register_kernel),
        ("dispatch", ops.dispatch),
        ("kernel_mode", ops.kernel_mode),
        ("streaming_nns", ops.streaming_nns),
        ("hamming_distances", ops.hamming_distances),
    ]


@pytest.mark.parametrize("name,obj", _public_api(),
                         ids=[n for n, _ in _public_api()])
def test_public_api_has_docstrings(name, obj):
    """Every public object documents itself: a real docstring, not a stub."""
    doc = getattr(obj, "__doc__", None)
    assert doc and len(doc.strip()) >= 20, (
        f"{name} is public API but has no (or a trivial) docstring")


def test_docs_tree_exists():
    for f in ("ARCHITECTURE.md", "KERNELS.md", "BENCHMARKS.md"):
        assert (REPO / "docs" / f).is_file(), f"docs/{f} missing"


def test_docs_references_resolve():
    """tools/check_docs.py over docs/*.md + ROADMAP.md finds no dangling
    file references (same command the CI docs step runs)."""
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py")],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, f"dangling docs refs:\n{proc.stdout}"


def test_docs_checker_catches_dangling_refs(tmp_path):
    """The checker actually fails on a dead reference (no silent passes)."""
    bad = tmp_path / "BAD.md"
    bad.write_text("see [x](src/repro/does_not_exist.py) and "
                   "`tests/nope_missing.py`\n")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(bad)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 1
    assert "does_not_exist" in proc.stdout
    assert "nope_missing" in proc.stdout


def test_docs_checker_catches_absolute_paths(tmp_path):
    """Machine-local absolute paths are flagged even when they exist on the
    machine running the checker — they reference an author's box, not the
    repo."""
    bad = tmp_path / "ABS.md"
    bad.write_text("data lives in /tmp/scratch/data and the checkout at "
                   "/home/someone/repo; a URL http://x/usr/share is fine\n")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), str(bad)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 1
    assert "/tmp/scratch/data" in proc.stdout
    assert "/home/someone/repo" in proc.stdout
    assert "absolute path" in proc.stdout
    # URLs whose path component merely contains /usr/... are not flagged
    assert "/usr/share" not in proc.stdout
