import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import (
    embedding_bag,
    init_table,
    lookup,
    multi_table_pool,
    table_to_dense,
)
from repro.core.hierarchy import hierarchical_psum, sharded_embedding_bag, tree_sum
from repro.kernels.ref import embedding_pool_ref
from repro.utils import shard_map


def test_lookup_matches_dense(key):
    t = init_table(key, 100, 32)
    dense = table_to_dense(t)
    ids = jnp.array([3, 0, 99, -1])
    out = lookup(t, ids)
    np.testing.assert_allclose(np.asarray(out[:3]), np.asarray(dense[jnp.array([3, 0, 99])]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out[3]), 0.0)


def test_bag_sum_and_mean(key):
    t = init_table(key, 50, 16)
    dense = np.asarray(table_to_dense(t))
    ids = jnp.array([[1, 2, 3, -1], [5, -1, -1, -1]])
    out = np.asarray(embedding_bag(t, ids, mode="sum"))
    want0 = dense[1] + dense[2] + dense[3]
    np.testing.assert_allclose(out[0], want0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out[1], dense[5], rtol=1e-5, atol=1e-6)
    mean = np.asarray(embedding_bag(t, ids, mode="mean"))
    np.testing.assert_allclose(mean[0], want0 / 3, rtol=1e-5, atol=1e-6)


def test_weighted_bag(key):
    t = init_table(key, 20, 8)
    dense = np.asarray(table_to_dense(t))
    ids = jnp.array([[0, 1]])
    w = jnp.array([[2.0, -1.0]])
    out = np.asarray(embedding_bag(t, ids, weights=w))
    np.testing.assert_allclose(out[0], 2 * dense[0] - dense[1], rtol=1e-5, atol=1e-6)


def test_multi_table_concat_and_sum(key):
    k1, k2 = jax.random.split(key)
    tables = {"a": init_table(k1, 10, 4), "b": init_table(k2, 10, 4)}
    feats = {"a": jnp.array([[1, -1]]), "b": jnp.array([[2, 3]])}
    cat = multi_table_pool(tables, feats, combine="concat")
    assert cat.shape == (1, 8)
    s = multi_table_pool(tables, feats, combine="sum")
    np.testing.assert_allclose(np.asarray(s), np.asarray(cat[:, :4] + cat[:, 4:]), rtol=1e-6)


def test_tree_sum_matches_sum_any_fanin(key):
    x = jax.random.normal(key, (13, 7))
    for fan in (2, 4, 8):
        np.testing.assert_allclose(
            np.asarray(tree_sum(x, fan)), np.asarray(x.sum(0)), rtol=1e-5, atol=1e-5
        )


def test_hierarchical_psum_single_device(key):
    mesh = jax.make_mesh((1,), ("model",))

    def f(x):
        return hierarchical_psum(x, ("model",))

    y = shard_map(f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                      out_specs=jax.sharding.PartitionSpec(), check_vma=False)(
        jnp.ones((4,))
    )
    np.testing.assert_array_equal(np.asarray(y), 1.0)


def test_sharded_embedding_bag_matches_local(key):
    mesh = jax.make_mesh((1,), ("model",))
    t = init_table(key, 64, 16)
    ids = jnp.array([[1, 5, 63, -1], [0, -1, -1, -1]])
    want = embedding_pool_ref(t.values, t.scales, ids)
    got = sharded_embedding_bag(mesh, "model", t, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
