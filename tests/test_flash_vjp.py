"""The flash-style custom VJP (recompute-per-block backward) must match
autodiff through the full-softmax oracle — values AND gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import attention_ref
from repro.models.attention import gqa_blocked_attention


def _ref_gqa(q5, k, v, causal=True):
    B, R, G, Sq, hd = q5.shape
    q = q5.reshape(B, R * G, Sq, hd)
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    out = attention_ref(q, kk, vv, causal=causal)
    return out.reshape(B, R, G, Sq, hd).astype(jnp.float32)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [
    (2, 2, 2, 16, 8),   # B, R, G, S, hd
    (1, 4, 1, 33, 16),  # non-multiple of block
])
def test_flash_forward_matches_oracle(key, shape, causal):
    B, R, G, S, hd = shape
    kq, kk, kv = jax.random.split(key, 3)
    q5 = jax.random.normal(kq, shape)
    k = jax.random.normal(kk, (B, R, S, hd))
    v = jax.random.normal(kv, (B, R, S, hd))
    got = gqa_blocked_attention(q5, k, v, causal=causal, block_k=8)
    want = _ref_gqa(q5, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_vjp_matches_oracle_grads(key, causal):
    B, R, G, S, hd = 1, 2, 2, 24, 8
    kq, kk, kv, kc = jax.random.split(key, 4)
    q5 = jax.random.normal(kq, (B, R, G, S, hd))
    k = jax.random.normal(kk, (B, R, S, hd))
    v = jax.random.normal(kv, (B, R, S, hd))
    cot = jax.random.normal(kc, (B, R, G, S, hd))

    def loss_flash(q5, k, v):
        out = gqa_blocked_attention(q5, k, v, causal=causal, block_k=8)
        return jnp.sum(out * cot)

    def loss_ref(q5, k, v):
        return jnp.sum(_ref_gqa(q5, k, v, causal=causal) * cot)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q5, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q5, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5, err_msg=name)


def test_flash_vjp_no_quadratic_residuals(key):
    """The residuals saved for backward must be O(S*hd), not O(S^2):
    check via the jaxpr of the VJP (no (..., S, S)-shaped constants)."""
    B, R, G, S, hd = 1, 1, 1, 64, 8
    q5 = jax.random.normal(key, (B, R, G, S, hd))
    k = jax.random.normal(key, (B, R, S, hd))
    v = jax.random.normal(key, (B, R, S, hd))

    def f(q5, k, v):
        return jnp.sum(gqa_blocked_attention(q5, k, v, block_k=16))

    # linearize: residuals live in the returned function's closure
    _, vjp_fn = jax.vjp(f, q5, k, v)
    leaves = jax.tree_util.tree_leaves(vjp_fn)
    biggest = max((l.size for l in leaves if hasattr(l, "size")), default=0)
    # O(S^2) would be >= 64*64*16(blocks as stacked) = 65536; O(S*hd) is
    # 64*8 * small-constant
    assert biggest <= 4 * S * hd * 4, biggest
