"""Validate the trip-count-aware HLO analyzer against known computations."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo, parse_hlo
from repro.utils import shard_map


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_single_matmul_flops():
    xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = _compile(lambda x, w: x @ w, xs, ws)
    stats = analyze_hlo(c.as_text())
    want = 2 * 128 * 256 * 64
    assert stats.flops == pytest.approx(want, rel=0.01)


def test_scan_multiplies_flops_by_trip_count():
    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = _compile(f, xs, ws)
    stats = analyze_hlo(c.as_text())
    one = 2 * 128 * 128 * 128
    assert stats.flops == pytest.approx(10 * one, rel=0.05)
    # XLA's own cost_analysis undercounts (body visited once) — that is the
    # reason this analyzer exists
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns one dict per device
        ca = ca[0]
    assert ca["flops"] < 2 * one


def test_nested_scan_trip_counts():
    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = _compile(f, xs, ws)
    stats = analyze_hlo(c.as_text())
    one = 2 * 64 * 64 * 64
    assert stats.flops == pytest.approx(12 * one, rel=0.05)


def test_collective_bytes_with_groups():
    import subprocess, sys, textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.utils import shard_map
        mesh = jax.make_mesh((8,), ("model",))
        def f(x):
            return shard_map(lambda a: jax.lax.psum(a, "model"),
                                 mesh=mesh, in_specs=P("model", None),
                                 out_specs=P(), check_vma=False)(x)
        xs = jax.ShapeDtypeStruct((8, 1024), jnp.float32)
        c = jax.jit(f).lower(xs).compile()
        st = analyze_hlo(c.as_text(), total_devices=8)
        # all-reduce of a (1, 1024) f32 shard -> 4096 operand bytes
        assert st.collective_bytes == 4096, st
        assert "all-reduce" in st.per_collective, st.per_collective
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                          "HOME": "/root",
                                          # pin CPU: with libtpu installed,
                                          # TPU plugin init can block on the
                                          # libtpu lockfile in a bare env
                                          "JAX_PLATFORMS": "cpu"},
                         cwd="/root/repo")
    assert "OK" in out.stdout, out.stdout + out.stderr


def test_hbm_bytes_scale_with_scan():
    xs = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f1(x, w):
        return x @ w

    def f10(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    s1 = analyze_hlo(_compile(f1, xs, ws).as_text())
    s10 = analyze_hlo(_compile(f10, xs, ws).as_text())
    assert s10.hbm_bytes > 5 * s1.hbm_bytes
