"""Per-kernel allclose tests: Pallas (interpret=True) vs ref.py oracles,
swept across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import quantize_rowwise
from repro.kernels import ops, ref
from repro.kernels.embedding_pool import embedding_pool_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hamming_nns import hamming_distances_pallas
from repro.kernels.int8_matmul import int8_matmul_pallas
from repro.kernels.streaming_nns import streaming_nns_pallas


def _sig_pair(key, q, n, words):
    kq, kd = jax.random.split(key)
    queries = jax.random.randint(kq, (q, words), 0, 2**31 - 1).astype(jnp.uint32)
    db = jax.random.randint(kd, (n, words), 0, 2**31 - 1).astype(jnp.uint32)
    return queries, db


# ---------------------------------------------------------------------------
# hamming_nns
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q,n,words", [(1, 16, 8), (8, 100, 8), (5, 1025, 4), (3, 2048, 1)])
def test_hamming_kernel_vs_ref(key, q, n, words):
    queries, db = _sig_pair(key, q, n, words)
    want = ref.hamming_distance_ref(queries, db)
    got = hamming_distances_pallas(queries, db, block_q=4, block_n=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hamming_block_sizing_never_rounds_past_lane_padding(key):
    """Regression: n=300 used to get a 512 block via next-pow2 rounding;
    the block must stay within the 128-lane-aligned row count."""
    assert ops._hamming_block_n(300) == 384
    assert ops._hamming_block_n(100) == 128
    assert ops._hamming_block_n(1) == 128
    assert ops._hamming_block_n(5000) == 1024
    for n in (1, 100, 130, 300, 1023):
        block = ops._hamming_block_n(n)
        assert block % 128 == 0
        assert block - n < 128 or n < 128  # never a whole wasted lane-row
    # and the sized interpret path still matches the oracle at n=300
    queries, db = _sig_pair(key, 3, 300, 8)
    want = ref.hamming_distance_ref(queries, db)
    got = hamming_distances_pallas(
        queries, db, block_n=ops._hamming_block_n(300), interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# streaming_nns
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q,n,words,radius,K,block_n", [
    (3, 100, 8, 110, 8, 32),    # matches overflow the buffer
    (5, 1000, 4, 60, 16, 128),  # multi-block, ~40% match rate
    (2, 257, 1, 12, 300, 64),   # K > n, blocks don't divide n
    (4, 37, 8, 128, 12, 7),     # db smaller than one lane row
    (1, 64, 8, 0, 4, 64),       # radius 0: only exact duplicates
])
def test_streaming_nns_kernel_vs_ref(key, q, n, words, radius, K, block_n):
    """Pallas interpret path == lax.scan oracle, bit-exact, all fields."""
    queries, db = _sig_pair(key, q, n, words)
    want = ref.streaming_nns_ref(queries, db, radius, K, scan_block=block_n)
    got = streaming_nns_pallas(
        queries, db, jnp.int32(n), radius=radius, max_candidates=K,
        block_q=4, block_n=block_n, interpret=True)
    for g, w, name in zip(got, want, ("indices", "distances", "counts")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)


def test_streaming_nns_kernel_n_valid_masks_tail(key):
    """Dynamic n_valid: rows >= n_valid never match, in kernel and oracle."""
    queries, db = _sig_pair(key, 2, 96, 2)
    want = ref.streaming_nns_ref(queries, db, 40, 10, scan_block=32,
                                 n_valid=61)
    got = streaming_nns_pallas(
        queries, db, jnp.int32(61), radius=40, max_candidates=10,
        block_n=32, interpret=True)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    assert (np.asarray(got[0]) < 61).all()


def test_streaming_past_packed_key_capacity():
    """Regression for the 4.19M-row cap: DBs beyond the packed-key index
    capacity used to raise; they now scan as multiple superblocks (wide
    keys) in both the oracle and the kernel — shape-level check here, value
    equivalence in the superblock tests below and the benchmark sweep."""
    from repro.kernels.streaming_nns import max_streamable_items

    assert max_streamable_items(8) == 1 << 22  # 256-bit sigs: 4.19M rows/sb
    wide = jax.ShapeDtypeStruct(((1 << 22) + 129, 8), jnp.uint32)
    q = jax.ShapeDtypeStruct((2, 8), jnp.uint32)
    idx, dist, cnt = jax.eval_shape(
        lambda qq, d: ref.streaming_nns_ref(qq, d, 10, 4), q, wide)
    assert idx.shape == (2, 4) and cnt.shape == (2,)
    idx, dist, cnt = jax.eval_shape(
        lambda qq, d: streaming_nns_pallas(
            qq, d, jnp.int32(d.shape[0]), radius=10, max_candidates=4),
        q, wide)
    assert idx.shape == (2, 4) and idx.dtype == jnp.int32


@pytest.mark.parametrize("superblock,block_n", [(256, 128), (512, 256),
                                                (384, 128)])
def test_streaming_nns_kernel_superblocks_vs_ref(key, superblock, block_n):
    """Wide-key path: multiple superblocks with host-side merge must
    bit-match the oracle run at a DIFFERENT superblock split (results are
    superblock-invariant) and at the default (single-superblock) split."""
    queries, db = _sig_pair(key, 5, 1111, 8)
    want = ref.streaming_nns_ref(queries, db, 105, 12, scan_block=256)
    for sb_ref in (None, 128):
        got_ref = ref.streaming_nns_ref(queries, db, 105, 12, scan_block=96,
                                        superblock=sb_ref)
        for g, w in zip(got_ref, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    got = streaming_nns_pallas(
        queries, db, jnp.int32(1111), radius=105, max_candidates=12,
        block_q=4, block_n=block_n, superblock=superblock, interpret=True)
    for g, w, name in zip(got, want, ("indices", "distances", "counts")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_streaming_nns_kernel_n_valid_across_superblocks(key):
    """Dynamic n_valid landing mid-superblock masks the tail exactly."""
    queries, db = _sig_pair(key, 3, 700, 8)
    want = ref.streaming_nns_ref(queries, db, 110, 8, scan_block=64,
                                 n_valid=389)
    got = streaming_nns_pallas(
        queries, db, jnp.int32(389), radius=110, max_candidates=8,
        block_n=128, superblock=256, interpret=True)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    assert (np.asarray(got[0]) < 389).all()


# ---------------------------------------------------------------------------
# ops registry dispatch
# ---------------------------------------------------------------------------
def test_registry_contents_and_modes(monkeypatch):
    assert set(ops.registered_kernels()) >= {
        "hamming_distances", "embedding_pool", "int8_matmul",
        "flash_attention", "streaming_nns"}
    monkeypatch.setenv("REPRO_PALLAS", "ref")
    monkeypatch.setenv("REPRO_PALLAS_HAMMING_DISTANCES", "interpret")
    assert ops.kernel_mode("hamming_distances") == "interpret"
    assert ops.kernel_mode("int8_matmul") == "ref"
    monkeypatch.delenv("REPRO_PALLAS")
    monkeypatch.setenv("REPRO_PALLAS_HAMMING_DISTANCES", "bogus")
    assert ops.kernel_mode("hamming_distances") in ("pallas", "ref")  # auto


def test_registry_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        ops.register_kernel("hamming_distances", ref=lambda: None)


def test_per_op_interpret_override_dispatches_pallas(key, monkeypatch):
    """REPRO_PALLAS_<OP>=interpret runs the real kernel via the interpreter
    and must agree with the ref path bit-for-bit."""
    queries, db = _sig_pair(key, 4, 300, 8)
    want = ops.hamming_distances(queries, db)  # default CPU mode: ref
    monkeypatch.setenv("REPRO_PALLAS_HAMMING_DISTANCES", "interpret")
    got = ops.hamming_distances(queries, db)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# embedding_pool
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,B,L,block_d", [
    (64, 128, 4, 3, 128),
    (100, 256, 2, 7, 128),
    (16, 512, 3, 2, 512),
])
def test_embedding_pool_kernel_vs_ref(key, n, d, B, L, block_d):
    kt, ki, kw = jax.random.split(key, 3)
    table = quantize_rowwise(jax.random.normal(kt, (n, d)))
    ids = jax.random.randint(ki, (B, L), -1, n)
    weights = jax.random.normal(kw, (B, L))
    want = ref.embedding_pool_ref(table.values, table.scales, ids, weights)
    valid = (ids >= 0).astype(jnp.float32)
    got = embedding_pool_pallas(
        table.values, table.scales, ids, weights * valid,
        block_d=block_d, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_embedding_pool_all_padding(key):
    table = quantize_rowwise(jax.random.normal(key, (8, 128)))
    ids = jnp.full((2, 3), -1, dtype=jnp.int32)
    got = embedding_pool_pallas(
        table.values, table.scales, ids, jnp.zeros((2, 3)), interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), 0.0)


# ---------------------------------------------------------------------------
# int8_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(8, 32, 16), (128, 256, 128), (100, 130, 50)])
def test_int8_matmul_kernel_vs_ref(key, m, k, n):
    kx, kw = jax.random.split(key)
    x = jax.random.randint(kx, (m, k), -127, 128).astype(jnp.int8)
    w = jax.random.randint(kw, (k, n), -127, 128).astype(jnp.int8)
    sx = jnp.abs(jax.random.normal(jax.random.key(5), (m, 1))) + 0.01
    sw = jnp.abs(jax.random.normal(jax.random.key(6), (1, n))) + 0.01
    want = ref.int8_matmul_ref(x, w, sx, sw)
    got = int8_matmul_pallas(x, w, sx, sw, block_m=64, block_n=64, block_k=64,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,sq,sk,d,causal", [
    (2, 128, 128, 64, True),
    (1, 64, 192, 64, True),   # sk > sq, block padding
    (2, 100, 100, 32, False), # non-multiple of block
])
def test_flash_attention_vs_oracle(key, bh, sq, sk, d, causal, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (bh, sq, d), dtype=jnp.float32)
    k = jax.random.normal(kk, (bh, sk, d), dtype=jnp.float32)
    v = jax.random.normal(kv, (bh, sk, d), dtype=jnp.float32)
    want = ref.attention_ref(
        q[None].swapaxes(0, 1), k[None].swapaxes(0, 1), v[None].swapaxes(0, 1),
        causal=causal, q_offset=sk - sq if causal else 0,
    )[:, 0]
    got = flash_attention_pallas(
        q.astype(dtype), k.astype(dtype), v.astype(dtype),
        causal=causal, block_q=64, block_k=64,
        q_offset=sk - sq if causal else 0, interpret=True,
    ).astype(jnp.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_blocked_ref_matches_full_ref(key):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 2, 37, 16))
    k = jax.random.normal(kk, (1, 2, 53, 16))
    v = jax.random.normal(kv, (1, 2, 53, 16))
    want = ref.attention_ref(q, k, v, causal=True, q_offset=53 - 37)
    got = ref.blocked_attention_ref(q, k, v, causal=True, q_offset=53 - 37, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
