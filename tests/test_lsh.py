import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lsh import (
    expected_hamming,
    lsh_signature,
    make_lsh_projections,
    pack_bits,
    unpack_bits,
)
from repro.kernels.ref import hamming_distance_ref


def test_pack_unpack_roundtrip(key):
    bits = jax.random.bernoulli(key, 0.5, (5, 256)).astype(jnp.int32)
    packed = pack_bits(bits)
    assert packed.shape == (5, 8) and packed.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(unpack_bits(packed, 256)), np.asarray(bits))


def test_signature_shape_and_determinism(key):
    proj = make_lsh_projections(key, 32, 256)
    x = jax.random.normal(jax.random.key(1), (10, 32))
    s1, s2 = lsh_signature(x, proj), lsh_signature(x, proj)
    assert s1.shape == (10, 8)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_identical_vectors_zero_distance(key):
    proj = make_lsh_projections(key, 16, 128)
    x = jax.random.normal(jax.random.key(2), (4, 16))
    sig = lsh_signature(x, proj)
    d = hamming_distance_ref(sig, sig)
    np.testing.assert_array_equal(np.asarray(jnp.diagonal(d)), 0)


def test_srp_collision_statistics(key):
    """E[hamming] ~ n_bits * angle / pi (the SRP-LSH guarantee)."""
    dim, n_bits = 32, 4096  # many bits -> tight concentration
    proj = make_lsh_projections(key, dim, n_bits)
    k1, k2 = jax.random.split(jax.random.key(3))
    a = jax.random.normal(k1, (8, dim))
    b = a + 0.5 * jax.random.normal(k2, (8, dim))
    cos = jnp.sum(a * b, -1) / (
        jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1)
    )
    exp = expected_hamming(cos, n_bits)
    d = jnp.diagonal(hamming_distance_ref(lsh_signature(a, proj), lsh_signature(b, proj)))
    # concentration: within 8% of n_bits
    np.testing.assert_allclose(np.asarray(d), np.asarray(exp), atol=0.08 * n_bits)
