"""Paper-claim reproduction tests: Table I mapping + Table III + end-to-end."""
import pytest

from repro.core import cost_model as cm
from repro.core import mapping as mp


def test_table1_movielens():
    m = mp.movielens_mapping()
    assert (m.banks, m.mats, m.cmas) == (7, 8, 54)


def test_table1_criteo():
    m = mp.criteo_mapping()
    assert (m.banks, m.mats, m.cmas) == (26, 104, 2860)


def test_itet_two_cmas_per_entry():
    itet = [e for e in mp.MOVIELENS_ETS if e.kind == "itet"][0]
    assert itet.width_cmas == 2  # "256 LSH signature ... 2 CMAs per entry"


def test_table3_reproduction():
    t3 = cm.table3_model()
    for stage, row in t3.items():
        assert abs(row["latency_rel_err"]) < 0.03, (stage, row)
        assert abs(row["energy_rel_err"]) < 0.01, (stage, row)


def test_table3_speedups_match_paper():
    """Paper: 43.61x/45.17x/61.83x latency, 516/458/47.9x energy."""
    t3 = cm.table3_model()
    paper = {
        "ml_filter": (43.61, 516.05),
        "ml_rank": (45.17, 458.12),
        "criteo_rank": (61.83, 47.90),
    }
    for stage, (sp, er) in paper.items():
        assert t3[stage]["speedup_vs_gpu"] == pytest.approx(sp, rel=0.05)
        assert t3[stage]["energy_reduction_vs_gpu"] == pytest.approx(er, rel=0.05)


def test_end_to_end_movielens():
    e = cm.end_to_end_movielens()
    assert e["latency_speedup"] == pytest.approx(16.8, rel=0.01)
    assert e["energy_reduction"] == pytest.approx(713.0, rel=0.01)
    assert e["imars_qps"] == pytest.approx(22025, rel=0.01)
    assert e["gpu_qps"] == pytest.approx(1311, rel=0.01)


def test_end_to_end_criteo():
    e = cm.end_to_end_criteo()
    assert e["latency_speedup"] == pytest.approx(13.2, rel=0.01)
    assert e["energy_reduction"] == pytest.approx(57.8, rel=0.01)


def test_nns_improvements():
    n = cm.ml_nns_model()
    assert n["latency_speedup"] == pytest.approx(3.8e4, rel=0.1)
    assert n["energy_reduction"] == pytest.approx(2.8e4, rel=0.05)


def test_design_space_tradeoffs():
    """Paper Sec. III-A1: larger C -> slower intra-mat tree; more mats ->
    more serialized intra-bank rounds."""
    small_c = cm.design_space_lookup_cost(28000, 1, cmas_per_mat=8)
    big_c = cm.design_space_lookup_cost(28000, 1, cmas_per_mat=128)
    # bigger C: fewer mats -> fewer intra-bank rounds -> lower latency there,
    # but the intra-mat tree slows down; both effects must be present
    assert big_c.latency_ns != small_c.latency_ns
    tiny = cm.design_space_lookup_cost(256, 1, cmas_per_mat=32)
    assert tiny.latency_ns < cm.design_space_lookup_cost(28000 * 4, 1, 32).latency_ns
