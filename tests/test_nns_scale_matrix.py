"""Scenario test matrix for the filtering-stage NNS execution plans.

Every (path x scenario) cell is checked bit-for-bit against an independent
numpy oracle (threshold + lexicographic (distance, row) sort) — not against
another jax path — so a shared bug between plans cannot hide. The matrix
runs under whatever backend `REPRO_PALLAS` selects: the CI pallas-interpret
job replays it through the real Pallas kernel bodies, the fast lane through
the jnp oracles.

Paths: dense (q, n) matrix | streaming scan (superblock-split wide keys) |
db-sharded shard_map | query-parallel shard_map.
Scenarios: the edges that historically break bounded-candidate scans —
empty n_valid prefix, a single-row DB/shard, non-lane-aligned row counts,
duplicate signatures (distance ties), and a radius admitting every row
(candidate-buffer overflow).

Deterministic wide-key boundary tests (superblock offsets, tie order,
threshold inclusivity, beyond-cap scan blocks) live here too so they run
even where hypothesis is unavailable; the randomized versions are in
tests/test_properties.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.nns import (
    fixed_radius_nns,
    query_parallel_nns,
    sharded_fixed_radius_nns,
)
from repro.kernels.ref import hamming_distance_ref
from repro.kernels.streaming_nns import (
    BIG_DIST,
    big_key,
    max_streamable_items,
    pack_key,
    unpack_key,
)

WORDS = 2
K = 16


def _oracle(queries, db, radius, k, n_valid=None):
    """Brute-force numpy fixed-radius NNS: the matrix's ground truth."""
    d = np.asarray(hamming_distance_ref(queries, db))
    n = db.shape[0]
    nv = n if n_valid is None else n_valid
    rows = np.arange(n)
    idxs, dists, cnts = [], [], []
    for i in range(queries.shape[0]):
        within = (d[i] <= radius) & (rows < nv)
        m = np.nonzero(within)[0]
        m = m[np.lexsort((m, d[i][m]))][:k]  # (distance, row) ascending
        pad = k - len(m)
        idxs.append(np.concatenate([m, np.full(pad, -1)]).astype(np.int32))
        dists.append(np.concatenate(
            [d[i][m], np.full(pad, BIG_DIST)]).astype(np.int32))
        cnts.append(within.sum())
    return (np.stack(idxs), np.stack(dists), np.asarray(cnts, np.int32))


def _scenario(name):
    """-> (queries, db, radius, n_valid)."""
    rng = np.random.default_rng(17)

    def sigs(n):
        return rng.integers(0, 2**32, size=(n, WORDS), dtype=np.uint32)

    if name == "n_valid_zero":
        db = sigs(96)
        return db[:4], db, 30, 0
    if name == "single_row_shard":
        db = sigs(1)  # one row total: a 1-device mesh sees a 1-row shard
        return sigs(3), db, 64, None
    if name == "non_aligned_n":
        db = sigs(300)  # not a multiple of the 128-lane row tile
        return db[:5], db, 28, 211
    if name == "duplicate_signatures":
        db = np.tile(sigs(5), (8, 1))  # 40 rows, every distance 8-way tied
        return db[:3], db, 40, None
    if name == "radius_overflow":
        # every row within radius (max dist = 64 at words=2): the bounded
        # candidate buffer overflows and must keep the best K by (dist, row)
        db = sigs(200)
        return db[:4], db, 32 * WORDS, None
    raise AssertionError(name)


SCENARIOS = ("n_valid_zero", "single_row_shard", "non_aligned_n",
             "duplicate_signatures", "radius_overflow")
PATHS = ("dense", "streaming", "sharded", "query_parallel")


def _run(path, queries, db, radius, n_valid):
    queries, db = jnp.asarray(queries), jnp.asarray(db)
    if path == "dense":
        return fixed_radius_nns(queries, db, radius, K, scan_block=0,
                                n_valid=n_valid)
    if path == "streaming":
        # superblock < n in the bigger scenarios: exercises the wide-key
        # split + host-side merge inside the matrix
        return fixed_radius_nns(queries, db, radius, K, scan_block=24,
                                n_valid=n_valid, superblock=128)
    if path == "sharded":
        mesh = jax.make_mesh((1,), ("banks",))
        return sharded_fixed_radius_nns(
            mesh, "banks", queries, db, radius, K, n_valid=n_valid,
            scan_block=16)
    if path == "query_parallel":
        mesh = jax.make_mesh((1,), ("qp",))
        return query_parallel_nns(mesh, "qp", queries, db, radius, K,
                                  scan_block=16, n_valid=n_valid)
    raise AssertionError(path)


@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("path", PATHS)
def test_nns_matrix(path, scenario):
    queries, db, radius, n_valid = _scenario(scenario)
    want_idx, want_dist, want_cnt = _oracle(queries, db, radius, K, n_valid)
    res = _run(path, queries, db, radius, n_valid)
    np.testing.assert_array_equal(np.asarray(res.indices), want_idx,
                                  err_msg=f"{path}/{scenario} indices")
    np.testing.assert_array_equal(np.asarray(res.distances), want_dist,
                                  err_msg=f"{path}/{scenario} distances")
    np.testing.assert_array_equal(np.asarray(res.counts), want_cnt,
                                  err_msg=f"{path}/{scenario} counts")


# ---------------------------------------------------------------------------
# deterministic wide-key boundary checks
# ---------------------------------------------------------------------------
def test_key_capacity_boundary_is_exact():
    """Row 2**22-1 packs at words=8; row 2**22 must NOT round-trip in one
    key (it aliases dist+1, row 0) — which is exactly why DBs past the
    capacity scan as offset superblocks."""
    cap = max_streamable_items(8)
    assert cap == 1 << 22
    assert unpack_key(pack_key(0, cap - 1, 8), 8) == (0, cap - 1)
    assert unpack_key(pack_key(0, cap, 8), 8) == (1, 0)  # the alias
    assert pack_key(32 * 8, cap - 1, 8) < big_key(8) < 2**31


def test_degenerate_superblocks_equal_dense():
    """1- and 2-row superblocks (every row its own candidate buffer)."""
    rng = np.random.default_rng(11)
    codes = jnp.asarray(rng.integers(0, 2**32, size=(7, 2), dtype=np.uint32))
    dense = fixed_radius_nns(codes[:2], codes, 30, 4, scan_block=0)
    for sb in (1, 2):
        wide = fixed_radius_nns(codes[:2], codes, 30, 4, scan_block=3,
                                superblock=sb)
        np.testing.assert_array_equal(
            np.asarray(dense.indices), np.asarray(wide.indices))
        np.testing.assert_array_equal(
            np.asarray(dense.counts), np.asarray(wide.counts))


def test_superblock_boundary_ties_keep_global_order():
    """Duplicate signatures straddling a superblock boundary: equal
    distances must come back in ascending GLOBAL row order even though the
    local key of the later superblock's row is smaller."""
    sb = 16
    row = np.asarray([0xdeadbeef, 0x1234], np.uint32)
    db = np.zeros((40, 2), np.uint32)
    db[sb - 1] = row  # local key sb-1 in superblock 0
    db[sb] = row      # local key 0 in superblock 1 — smaller local key!
    db[2 * sb] = row  # superblock 2
    res = fixed_radius_nns(jnp.asarray(row[None]), jnp.asarray(db),
                           radius=0, max_candidates=4, scan_block=4,
                           superblock=sb)
    np.testing.assert_array_equal(np.asarray(res.indices[0]),
                                  [sb - 1, sb, 2 * sb, -1])
    assert int(res.counts[0]) == 3


def test_radius_threshold_is_inclusive_at_the_boundary():
    """dist == radius matches, dist == radius+1 does not — across a
    superblock split so the threshold compare is exercised in the wide
    merge too."""
    base = np.asarray([0, 0], np.uint32)
    db = np.zeros((24, 2), np.uint32)
    db[5] = [0b111, 0]       # dist 3
    db[17] = [0b1111, 0]     # dist 4 (superblock 2 at sb=8)
    res = fixed_radius_nns(jnp.asarray(base[None]), jnp.asarray(db),
                           radius=3, max_candidates=24, scan_block=4,
                           superblock=8, n_valid=18)
    idx = set(int(i) for i in np.asarray(res.indices[0]) if i >= 0)
    assert 5 in idx and 17 not in idx  # 17 is outside the radius
    zeros = {i for i in range(18)} - {5, 17}
    assert zeros <= idx  # every dist-0 row within n_valid matched


def test_streaming_equals_dense_beyond_old_scan_block_cap():
    """scan_block larger than the old 4.19M-row packed-key cap: the chunk
    padding overflows the per-superblock row budget and must still decode
    exactly (masked pad rows never pack keys)."""
    rng = np.random.default_rng(3)
    codes = jnp.asarray(rng.integers(0, 2**32, size=(64, 8), dtype=np.uint32))
    dense = fixed_radius_nns(codes[:2], codes, 100, 8, scan_block=0)
    stream = fixed_radius_nns(codes[:2], codes, 100, 8,
                              scan_block=(1 << 22) + 17)
    np.testing.assert_array_equal(
        np.asarray(dense.indices), np.asarray(stream.indices))
    np.testing.assert_array_equal(
        np.asarray(dense.distances), np.asarray(stream.distances))
    np.testing.assert_array_equal(
        np.asarray(dense.counts), np.asarray(stream.counts))


# ---------------------------------------------------------------------------
# benchmark artifact row schema (benchmarks/bench_io.py)
# ---------------------------------------------------------------------------
def test_bench_row_schema_accepts_uniform_rows():
    from benchmarks.bench_io import check_row_schema, csv_rows_to_json

    rows = csv_rows_to_json([
        ("nns_scale/streaming/n1024", 1.5, "qps=100.0;mem_lt_10pct_dense=True"),
        ("nns_scale/streaming/n2048", 2.5, "qps=50.0;mem_lt_10pct_dense=False"),
        ("nns_scale/dense/n4096", 0.0, "status=skipped_oom_guard;dense_bytes=1"),
    ])
    check_row_schema(rows, required=("qps",),
                     within=("nns_scale/streaming/", "nns_scale/dense/"))


def test_bench_row_schema_rejects_dropped_metric():
    """The satellite-2 regression shape: one cell of a sweep silently
    missing a metric its mates emit (mem_lt_10pct_dense used to appear on
    the `streaming` path only) must fail the schema gate."""
    from benchmarks.bench_io import check_row_schema, csv_rows_to_json

    rows = csv_rows_to_json([
        ("b/stream/n1", 1.0, "qps=9.0;mem_lt_10pct_dense=True"),
        ("b/stream/n2", 1.0, "qps=8.0"),  # metric silently dropped
    ])
    with pytest.raises(ValueError, match="inconsistent derived schemas"):
        check_row_schema(rows, within=("b/stream/",))
    # failed cells are exempt from group consistency
    rows[1]["derived"] = "status=failed"
    check_row_schema(rows, within=("b/stream/",))


def test_bench_row_schema_rejects_malformed_rows():
    from benchmarks.bench_io import check_row_schema

    with pytest.raises(ValueError, match="not key=value"):
        check_row_schema([{"name": "x", "us_per_call": 1.0,
                           "derived": "qps100"}])
    with pytest.raises(ValueError, match="keys"):
        check_row_schema([{"name": "x", "us_per_call": 1.0}])
    with pytest.raises(ValueError, match="missing required"):
        check_row_schema([{"name": "x", "us_per_call": 1.0,
                           "derived": "qps=1.0"}], required=("rss_delta",))


def test_nns_scale_rows_carry_memory_metric_on_all_streaming_cells():
    """`_derived` + `_cell` row schema: the zipf cells emit the same
    memory metric as the plain streaming cell (the fixed asymmetry)."""
    from benchmarks.nns_scale import _derived

    row = {"qps": 10.0, "rss_peak_delta_bytes": 5, "dense_matrix_bytes": 100,
           "mem_lt_10pct_dense": True}
    assert "mem_lt_10pct_dense=True" in _derived(row)
