import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nns
from repro.core.lsh import lsh_signature, make_lsh_projections
from repro.core.nns import (
    BIG,
    cosine_topk,
    fixed_radius_nns,
    query_parallel_nns,
    sharded_fixed_radius_nns,
)
from repro.core.topk import threshold_topk


def _sigs(key, n, dim=16, n_bits=128):
    proj = make_lsh_projections(key, dim, n_bits)
    x = jax.random.normal(jax.random.key(7), (n, dim))
    return x, lsh_signature(x, proj)


def _assert_same_result(a, b):
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_array_equal(
        np.asarray(a.distances), np.asarray(b.distances))
    np.testing.assert_array_equal(np.asarray(a.counts), np.asarray(b.counts))


def test_fixed_radius_exact_semantics(key):
    x, sigs = _sigs(key, 100)
    q = sigs[:3]
    res = fixed_radius_nns(q, sigs, radius=20, max_candidates=50)
    # brute force oracle
    from repro.kernels.ref import hamming_distance_ref

    d = np.asarray(hamming_distance_ref(q, sigs))
    for i in range(3):
        want = set(np.nonzero(d[i] <= 20)[0].tolist())
        got = set(int(j) for j in np.asarray(res.indices[i]) if j >= 0)
        assert int(res.counts[i]) == len(want)
        if len(want) <= 50:
            assert got == want
        # returned distances are within radius and sorted ascending
        dist = np.asarray(res.distances[i])
        valid = dist < 2**30
        assert (dist[valid] <= 20).all()
        assert (np.diff(dist[valid]) >= 0).all()


def test_fixed_radius_self_match(key):
    _, sigs = _sigs(key, 32)
    res = fixed_radius_nns(sigs, sigs, radius=0, max_candidates=4)
    # each item matches at least itself at distance 0
    assert (np.asarray(res.counts) >= 1).all()
    assert (np.asarray(res.distances[:, 0]) == 0).all()


def test_big_sentinel_exported_and_used(key):
    """Invalid candidate slots carry the one exported BIG sentinel."""
    _, sigs = _sigs(key, 20)
    res = fixed_radius_nns(sigs[:2], sigs, radius=0, max_candidates=8)
    invalid = np.asarray(res.indices) < 0
    assert invalid.any()
    assert (np.asarray(res.distances)[invalid] == int(BIG)).all()
    assert int(BIG) == 2**30 and nns._BIG is BIG  # backwards alias


@pytest.mark.parametrize("scan_block", [7, 64, 100, 512])
def test_streaming_matches_dense(key, scan_block):
    """Any scan_block (dividing n or not, larger than n or not) must return
    the identical NNSResult to the dense (q, n) path."""
    _, sigs = _sigs(key, 300)
    q = sigs[:5]
    dense = fixed_radius_nns(q, sigs, radius=30, max_candidates=24,
                             scan_block=0)
    stream = fixed_radius_nns(q, sigs, radius=30, max_candidates=24,
                              scan_block=scan_block)
    _assert_same_result(dense, stream)


def test_streaming_matches_dense_with_n_valid(key):
    _, sigs = _sigs(key, 128)
    dense = fixed_radius_nns(sigs[:3], sigs, radius=28, max_candidates=16,
                             scan_block=0, n_valid=77)
    stream = fixed_radius_nns(sigs[:3], sigs, radius=28, max_candidates=16,
                              scan_block=32, n_valid=77)
    _assert_same_result(dense, stream)
    assert (np.asarray(stream.indices) < 77).all()


def test_auto_routing_by_db_size(key, monkeypatch):
    """scan_block=None picks dense below STREAM_MIN_ITEMS and streaming at or
    above it — verified by spying on the streaming op — and both plans
    agree."""
    from repro.kernels import ops

    calls = []
    real = ops.streaming_nns
    monkeypatch.setattr(
        ops, "streaming_nns",
        lambda *a, **kw: calls.append(kw) or real(*a, **kw))

    _, sigs = _sigs(key, 200)
    q = sigs[:3]
    dense = fixed_radius_nns(q, sigs, radius=30, max_candidates=16)
    assert not calls  # 200 < STREAM_MIN_ITEMS: dense plan
    monkeypatch.setattr(nns, "STREAM_MIN_ITEMS", 64)
    monkeypatch.setattr(nns, "DEFAULT_SCAN_BLOCK", 96)
    auto = fixed_radius_nns(q, sigs, radius=30, max_candidates=16)
    assert len(calls) == 1 and calls[0]["scan_block"] == 96
    _assert_same_result(dense, auto)


def test_streaming_accepts_arbitrary_db_mask(key):
    """The streaming plan carries arbitrary row masks (live-catalog
    tombstones) since PR 5 — bit-matching the dense masked path."""
    _, sigs = _sigs(key, 64)
    mask = jnp.arange(64) % 2 == 0
    want = fixed_radius_nns(sigs[:3], sigs, radius=30, max_candidates=4,
                            db_mask=mask, scan_block=0)
    got = fixed_radius_nns(sigs[:3], sigs, radius=30, max_candidates=4,
                           db_mask=mask, scan_block=16)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_matches_unsharded(key):
    """1-device mesh: the sharded path must equal the local path exactly."""
    mesh = jax.make_mesh((1,), ("model",))
    x, sigs = _sigs(key, 64)
    q = sigs[:2]
    local = fixed_radius_nns(q, sigs, radius=25, max_candidates=16)
    shard = sharded_fixed_radius_nns(mesh, "model", q, sigs, radius=25,
                                     max_candidates=16)
    np.testing.assert_array_equal(np.asarray(local.counts), np.asarray(shard.counts))
    np.testing.assert_array_equal(
        np.sort(np.asarray(local.indices), -1), np.sort(np.asarray(shard.indices), -1)
    )


def test_sharded_composes_with_streaming(key):
    """Sharding over devices + streaming within the shard == dense local."""
    mesh = jax.make_mesh((1,), ("model",))
    _, sigs = _sigs(key, 96)
    q = sigs[:3]
    local = fixed_radius_nns(q, sigs, radius=25, max_candidates=16,
                             scan_block=0)
    shard = sharded_fixed_radius_nns(mesh, "model", q, sigs, radius=25,
                                     max_candidates=16, scan_block=17)
    _assert_same_result(local, shard)


def test_query_parallel_matches_local(key):
    """Query-sharded scan (db replicated) == the plain local scan exactly,
    for dense and streaming plans."""
    mesh = jax.make_mesh((1,), ("qp",))
    _, sigs = _sigs(key, 80)
    q = sigs[:5]
    for scan_block in (0, 13, None):
        local = fixed_radius_nns(q, sigs, radius=25, max_candidates=16,
                                 scan_block=scan_block)
        par = query_parallel_nns(mesh, "qp", q, sigs, radius=25,
                                 max_candidates=16, scan_block=scan_block)
        _assert_same_result(local, par)


def test_query_parallel_respects_n_valid(key):
    mesh = jax.make_mesh((1,), ("qp",))
    _, sigs = _sigs(key, 64)
    local = fixed_radius_nns(sigs[:3], sigs, radius=25, max_candidates=8,
                             scan_block=16, n_valid=41)
    par = query_parallel_nns(mesh, "qp", sigs[:3], sigs, radius=25,
                             max_candidates=8, scan_block=16, n_valid=41)
    _assert_same_result(local, par)
    assert (np.asarray(par.indices) < 41).all()


def test_sharded_composes_with_query_axis(key):
    """(query block x bank) 2D partition == the plain local scan."""
    mesh = jax.make_mesh((1, 1), ("qp", "model"))
    _, sigs = _sigs(key, 96)
    q = sigs[:5]
    local = fixed_radius_nns(q, sigs, radius=25, max_candidates=16,
                             scan_block=0)
    both = sharded_fixed_radius_nns(mesh, "model", q, sigs, radius=25,
                                    max_candidates=16, scan_block=17,
                                    query_axis="qp")
    _assert_same_result(local, both)


@pytest.mark.slow
def test_query_parallel_multi_device_subprocess():
    """Real 8-fake-device run: query axis 4 x bank axis 2, query count not
    divisible by the query axis (pad rows sliced off), vs the local scan."""
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.nns import (fixed_radius_nns, query_parallel_nns,
                                    sharded_fixed_radius_nns)
        rng = np.random.default_rng(0)
        sigs = jnp.asarray(rng.integers(0, 2**32, (256, 8), dtype=np.uint32))
        q = sigs[:10]  # 10 % 4 != 0: exercises query padding
        local = fixed_radius_nns(q, sigs, radius=100, max_candidates=16,
                                 scan_block=0)
        mesh = jax.make_mesh((4,), ("qp",))
        par = query_parallel_nns(mesh, "qp", q, sigs, radius=100,
                                 max_candidates=16, scan_block=32)
        mesh2 = jax.make_mesh((4, 2), ("qp", "banks"))
        both = sharded_fixed_radius_nns(mesh2, "banks", q, sigs, radius=100,
                                        max_candidates=16, scan_block=32,
                                        query_axis="qp")
        for got in (par, both):
            np.testing.assert_array_equal(np.asarray(local.indices),
                                          np.asarray(got.indices))
            np.testing.assert_array_equal(np.asarray(local.distances),
                                          np.asarray(got.distances))
            np.testing.assert_array_equal(np.asarray(local.counts),
                                          np.asarray(got.counts))
        print("MARKER qp ok", jax.device_count())
    """)
    import os
    import pathlib

    repo = pathlib.Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=420, cwd=repo,
        env={"PYTHONPATH": str(repo / "src"),
             "HOME": os.environ.get("HOME", str(repo)),
             "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-3000:]
    assert "MARKER qp ok 8" in out.stdout


def test_cosine_topk_oracle(key):
    x = jax.random.normal(key, (50, 8))
    q = x[:2] + 0.01
    vals, idx = cosine_topk(q, x, k=1)
    np.testing.assert_array_equal(np.asarray(idx[:, 0]), np.array([0, 1]))


def test_threshold_topk(key):
    scores = jnp.array([[0.1, 0.9, 0.5, 0.95, 0.2]])
    res = threshold_topk(scores, threshold=0.4, k=3)
    assert int(res.counts[0]) == 3
    np.testing.assert_array_equal(np.asarray(res.indices[0]), [3, 1, 2])
    res2 = threshold_topk(scores, threshold=0.99, k=3)
    assert int(res2.counts[0]) == 0
    assert (np.asarray(res2.indices[0]) == -1).all()
