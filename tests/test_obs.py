"""The telemetry layer: registry semantics, trace completeness, exporters.

Three contracts (docs/OBSERVABILITY.md):

  * **registry** — counters/histograms merge correctly across thread
    shards, collectors publish gauges at snapshot time (registration
    order wins), the event log is bounded, and both exporters
    (`snapshot()` dict, Prometheus text) agree with the writes;
  * **trace completeness** — every ticket in every `make_server` mode
    carries a well-ordered stage-span chain for every status (ok, shed,
    error), including close-with-inflight and epoch-swap-mid-ring, and
    the chain's stage durations sum to the ticket's measured latency
    exactly (the contiguity property `benchmarks/obs_overhead.py` gates);
  * **unification** — `stats()` is one schema over `snapshot()` in all
    three modes, and BENCH artifacts' embedded telemetry passes
    `bench_io.check_telemetry_schema`.
"""
import threading
from collections import deque

import jax
import numpy as np
import pytest

from repro.data import synthetic
from repro.data.synthetic import serving_queries as _queries
from repro.models import recsys as rs
from repro.obs import (
    STAGES,
    EventLog,
    MetricsRegistry,
    TicketTrace,
    bucket_upper_bounds,
    dump_trace,
    stage_durations,
    well_ordered,
)
from repro.serving import (
    STATUS_ERROR,
    STATUS_OK,
    STATUS_SHED,
    RecSysEngine,
    ServingError,
    make_server,
)
from tools.obs_report import load_trace, render_breakdown, stage_breakdown

MODES = ("sync", "pipelined", "concurrent")


# ---------------------------------------------------------------------------
# registry units (no engine needed)
# ---------------------------------------------------------------------------
def test_counters_merge_across_thread_shards():
    reg = MetricsRegistry()

    def bump():
        for _ in range(500):
            reg.count("t.hits")
            reg.observe("t.lat_s", 1e-4)

    threads = [threading.Thread(target=bump) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    reg.count("t.hits", 3)  # main thread gets its own shard too
    snap = reg.snapshot()
    assert snap["t.hits"] == 4 * 500 + 3
    assert snap["t.lat_s.count"] == 4 * 500
    assert snap["t.lat_s.sum"] == pytest.approx(4 * 500 * 1e-4)


def test_histogram_summary_and_bucket_bounds():
    bounds = bucket_upper_bounds()
    # bounds double each bucket — strictly increasing
    assert all(a < b for a, b in zip(bounds, bounds[1:]))
    reg = MetricsRegistry()
    vals = [1e-5, 1e-4, 1e-3, 1e-2, 0.1]
    for v in vals:
        reg.observe("h.lat_s", v)
    snap = reg.snapshot()
    assert snap["h.lat_s.count"] == len(vals)
    assert snap["h.lat_s.sum"] == pytest.approx(sum(vals))
    assert snap["h.lat_s.mean"] == pytest.approx(sum(vals) / len(vals))
    assert snap["h.lat_s.max"] == pytest.approx(0.1)
    # quantiles are conservative upper bucket bounds: within 2x of exact
    assert 1e-3 <= snap["h.lat_s.p50"] <= 2e-3
    assert 0.1 <= snap["h.lat_s.p99"] <= 0.2


def test_collector_registration_order_wins():
    reg = MetricsRegistry()
    reg.register_collector(lambda r: r.gauge("g.x", 1))
    reg.register_collector(lambda r: r.gauge("g.x", 2))  # outer wins
    assert reg.snapshot()["g.x"] == 2


def test_event_log_is_bounded_and_counts_drops():
    log = EventLog(cap=10)
    for i in range(25):
        log.append("tick", i=i)
    recs = log.records()
    assert len(recs) == 10 and log.n_dropped == 15
    assert [r["i"] for r in recs] == list(range(15, 25))  # newest retained
    assert all(a["seq"] < b["seq"] for a, b in zip(recs, recs[1:]))
    assert log.to_jsonl().count("\n") == 10
    reg = MetricsRegistry()
    reg.event("compact", epoch=3)
    snap = reg.snapshot()
    assert snap["events.count"] == 1 and snap["events.dropped"] == 0


def test_prometheus_export_shapes():
    reg = MetricsRegistry()
    reg.count("c.total", 7)
    reg.gauge("g.depth", 3)
    reg.info("i.mode", "sync")  # info never exports to Prometheus
    reg.observe("h.lat_s", 2e-6)
    text = reg.to_prometheus()
    assert "# TYPE repro_c_total counter" in text and "repro_c_total 7" in text
    assert "# TYPE repro_g_depth gauge" in text
    assert "# TYPE repro_h_lat_s histogram" in text
    assert 'repro_h_lat_s_bucket{le="+Inf"} 1' in text
    assert "repro_h_lat_s_count 1" in text
    assert "i_mode" not in text and "sync" not in text


# ---------------------------------------------------------------------------
# tracing units
# ---------------------------------------------------------------------------
def test_well_ordered_accepts_subsequences_rejects_junk():
    full = tuple((s, float(i)) for i, s in enumerate(STAGES))
    assert well_ordered(full)
    shed = (("submit", 1.0), ("admit", 1.0), ("resolve", 1.0))
    assert well_ordered(shed)
    assert not well_ordered(())  # empty
    assert not well_ordered((("admit", 0.0), ("resolve", 1.0)))  # no submit
    assert not well_ordered((("submit", 0.0), ("rank", 1.0)))  # no resolve
    assert not well_ordered(  # out of canonical order
        (("submit", 0.0), ("scan", 1.0), ("bucket", 2.0), ("resolve", 3.0)))
    assert not well_ordered(  # time goes backwards
        (("submit", 2.0), ("admit", 1.0), ("resolve", 3.0)))
    assert not well_ordered(  # unknown stage name
        (("submit", 0.0), ("warp", 1.0), ("resolve", 2.0)))


def test_stage_durations_sum_to_span():
    chain = tuple((s, 0.5 * i) for i, s in enumerate(STAGES))
    dur = stage_durations(chain)
    assert set(dur) == set(STAGES[1:])  # submit anchors, never charged
    assert sum(dur.values()) == pytest.approx(chain[-1][1] - chain[0][1])


def test_dump_trace_roundtrip_and_breakdown(tmp_path):
    recs = []
    for i in range(8):
        t0 = 10.0 * i
        chain = (("submit", t0), ("admit", t0), ("bucket", t0 + 1),
                 ("dispatch", t0 + 2), ("scan", t0 + 5), ("rank", t0 + 6),
                 ("resolve", t0 + 7))
        recs.append(TicketTrace(i, i % 2, t0, t0 + 7, STATUS_OK, chain))
    recs.append(TicketTrace(99, 0, 0.0, 0.0, STATUS_SHED,
                            (("submit", 0.0), ("admit", 0.0),
                             ("resolve", 0.0))))
    path = tmp_path / "trace.jsonl"
    assert dump_trace(recs, path) == 9
    loaded = load_trace(path)
    assert len(loaded) == 9
    bd = stage_breakdown(loaded, status=STATUS_OK)
    assert bd["n"] == 8 and bd["by_status"] == {STATUS_OK: 8}
    assert bd["latency_s"]["mean"] == pytest.approx(7.0)
    # contiguity: stage-sum mean equals measured latency mean exactly
    assert bd["stage_sum_mean_s"] == pytest.approx(bd["latency_s"]["mean"])
    assert bd["stages"]["scan"]["mean_s"] == pytest.approx(3.0)
    assert bd["stages"]["scan"]["frac"] == pytest.approx(3.0 / 7.0)
    # tenant filter partitions the records
    assert stage_breakdown(loaded, tenant=1)["n"] == 4
    table = render_breakdown(bd)
    assert "stage-sum mean" in table and "scan" in table
    # TicketTrace records render without a dump/load round-trip too
    assert stage_breakdown(recs, status=STATUS_OK)["n"] == 8


def test_check_telemetry_schema():
    from benchmarks.bench_io import check_telemetry_schema
    good = {"serving.served": 10, "serving.mode": "sync",
            "serving.per_tenant": {0: {"served": 10}},
            "serving.ticket_latency_s.mean": 1.5e-3,
            "serving.last_error": None, "serving.closed": False}
    check_telemetry_schema(good, required=("serving.served",))
    with pytest.raises(ValueError, match="must be a dict"):
        check_telemetry_schema(["not", "a", "dict"])
    with pytest.raises(ValueError, match="dotted"):
        check_telemetry_schema({"nodots": 1})
    with pytest.raises(ValueError, match="lowercase"):
        check_telemetry_schema({"serving.Served": 1})
    with pytest.raises(ValueError, match="JSON"):
        check_telemetry_schema({"serving.bad": object()})
    with pytest.raises(ValueError, match="missing"):
        check_telemetry_schema(good, required=("nns.blocks_touched",))


# ---------------------------------------------------------------------------
# trace completeness across the serving stack
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served():
    data = synthetic.make_movielens(n_users=120, n_items=90, history_len=6)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=6)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=data.n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=16,
                                top_k=5, hot_rows=32, item_freqs=freqs)
    return engine, data, params, cfg, freqs


def _make(engine, mode, **knobs):
    knobs.setdefault("max_batch", 8)
    if mode == "concurrent":
        knobs.setdefault("tenants", 2)
    return make_server(engine, mode, **knobs)


def _stream(data, n=19):
    return _queries(data, np.arange(n) % 7)


@pytest.mark.parametrize("mode", MODES)
def test_ok_tickets_carry_full_contiguous_chains(served, mode):
    """Every served ticket's chain hits all seven stages in order, rides
    both the ServedQuery and the TicketTrace, and its stage durations sum
    to the measured latency exactly (contiguity)."""
    engine, data = served[:2]
    server = _make(engine, mode)
    stream = _stream(data)
    out = server.serve_many(stream)
    for s in out:
        assert tuple(n for n, _ in s.stages) == STAGES
        assert well_ordered(s.stages)
    trace = server.take_trace()
    assert len(trace) == len(stream)
    for rec in trace:
        assert rec.status == STATUS_OK and well_ordered(rec.stages)
        assert sum(stage_durations(rec.stages).values()) == pytest.approx(
            rec.latency_s, abs=1e-9)
        assert rec.stages[0][1] == rec.submit_s
        assert rec.stages[-1][1] == rec.done_s
    assert server.take_trace() == []  # take clears
    server.close()


@pytest.mark.parametrize("mode", MODES)
def test_trace_false_disables_spans(served, mode):
    engine, data = served[:2]
    server = _make(engine, mode, trace=False)
    out = server.serve_many(_stream(data, 5))
    assert all(s.stages == () for s in out)
    trace = server.take_trace()
    if mode == "concurrent":
        # the load harness still needs submit/done timestamps per ticket
        assert len(trace) == 5 and all(r.stages == () for r in trace)
    else:
        assert trace == []
    snap = server.snapshot()
    assert snap.get("serving.ticket_latency_s.count", 0) == 0
    server.close()


def test_shed_tickets_carry_degenerate_chains(served):
    """A shed ticket resolves at admission: its chain is the well-ordered
    submit -> admit -> resolve subsequence, on the sentinel and the trace."""
    engine, data = served[:2]
    server = _make(engine, "concurrent", queue_depth=3, autostart=False)
    stream = _stream(data, 9)
    tickets = [server.submit(q) for q in stream]
    server.start()
    server.flush()
    got = [server.result(t, timeout=30.0) for t in tickets]
    shed = [g for g in got if g.status == STATUS_SHED]
    assert len(shed) == len(stream) - 3
    for g in shed:
        assert tuple(n for n, _ in g.stages) == ("submit", "admit", "resolve")
        assert well_ordered(g.stages)
    trace = server.take_trace()
    assert len(trace) == len(stream)
    assert all(well_ordered(r.stages) for r in trace)
    by_status = {}
    for r in trace:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    assert by_status == {STATUS_OK: 3, STATUS_SHED: len(stream) - 3}
    server.close()


def test_error_tickets_carry_degenerate_chains(served):
    """Drain failures resolve tickets as status=error with the degenerate
    submit -> admit -> resolve chain — traced, never lost."""
    engine, data = served[:2]
    server = _make(engine, "concurrent", autostart=False)
    real_inner = server._inner

    class _Exploding:
        engine = real_inner.engine
        _pending: list = []
        _ring = deque()
        _results: dict = {}

        def submit(self, q):
            raise ServingError("injected serve failure")

    server._inner = _Exploding()
    stream = _stream(data, 4)
    tickets = [server.submit(q) for q in stream]
    server.start()
    server.flush()
    got = [server.result(t, timeout=30.0) for t in tickets]
    assert all(g.status == STATUS_ERROR for g in got)
    trace = server.take_trace()
    assert len(trace) == len(stream)
    for rec in trace:
        assert rec.status == STATUS_ERROR
        assert tuple(n for n, _ in rec.stages) == \
            ("submit", "admit", "resolve")
        assert well_ordered(rec.stages)
    server._inner = real_inner
    server.close()


def test_close_with_inflight_tickets_traces_everything(served):
    """close() drains queued work — and every drained ticket still gets a
    complete, well-ordered chain (the drain-at-shutdown path is traced
    like any other)."""
    engine, data = served[:2]
    server = _make(engine, "concurrent", autostart=False)
    stream = _stream(data, 9)
    tickets = [server.submit(q, tenant=i % 2) for i, q in enumerate(stream)]
    server.close()
    got = [server.result(t, timeout=30.0) for t in tickets]
    assert all(g.status == STATUS_OK for g in got)
    trace = server.take_trace()
    assert len(trace) == len(stream)
    for rec in trace:
        assert tuple(n for n, _ in rec.stages) == STAGES
        assert well_ordered(rec.stages)


def test_epoch_swap_mid_ring_keeps_chains_well_ordered(served):
    """An engine swap while the pipelined ring holds in-flight buckets:
    every ticket (old epoch and new) resolves with a complete chain."""
    engine, data, params, cfg, freqs = served
    engine2 = RecSysEngine.build(params, cfg, radius=112, n_candidates=16,
                                 top_k=5, hot_rows=32, item_freqs=freqs)
    server = _make(engine, "pipelined", depth=2)
    stream = _stream(data, 16)
    tickets = [server.submit(q) for q in stream[:8]]
    server.swap_engine(engine2)  # ring may still hold old-epoch buckets
    tickets += [server.submit(q) for q in stream[8:]]
    server.flush()
    got = [server.result(t) for t in tickets]
    assert all(g.status == STATUS_OK for g in got)
    trace = server.take_trace()
    assert len(trace) == len(stream)
    for rec in trace:
        assert tuple(n for n, _ in rec.stages) == STAGES
        assert well_ordered(rec.stages)
        assert sum(stage_durations(rec.stages).values()) == pytest.approx(
            rec.latency_s, abs=1e-9)
    server.close()


# ---------------------------------------------------------------------------
# unification: one stats schema, one snapshot, shared registries
# ---------------------------------------------------------------------------
def test_stats_schema_is_identical_across_modes(served):
    engine, data = served[:2]
    keysets, servers = [], []
    for mode in MODES:
        server = _make(engine, mode)
        server.serve_many(_stream(data, 5))
        st = server.stats()
        assert st["mode"] == mode and st["n_served"] == 5
        keysets.append(set(st))
        servers.append(server)
    assert keysets[0] == keysets[1] == keysets[2]
    for server in servers:
        server.close()


@pytest.mark.parametrize("mode", MODES)
def test_snapshot_covers_serving_and_stage_histograms(served, mode):
    engine, data = served[:2]
    server = _make(engine, mode)
    n = len(_stream(data))
    server.serve_many(_stream(data))
    snap = server.snapshot()
    assert snap["serving.mode"] == mode
    assert snap["serving.served"] == n
    assert snap["serving.ticket_latency_s.count"] == n
    assert snap["serving.stage.dispatch_s.count"] >= 1
    assert snap["serving.ticket_latency_s.mean"] > 0
    assert snap["cache.lookups"] > 0
    if mode == "concurrent":
        assert snap["serving.e2e_latency_s.count"] == n
        assert snap["serving.per_tenant"][0]["served"] == n
    from benchmarks.bench_io import check_telemetry_schema
    check_telemetry_schema(snap, required=("serving.served",
                                           "serving.ticket_latency_s.count",
                                           "cache.lookups"))
    server.close()


def test_shared_registry_spans_servers(served):
    """A caller-supplied registry is adopted (not replaced) so several
    servers can report into one snapshot."""
    engine, data = served[:2]
    reg = MetricsRegistry()
    server = _make(engine, "sync", registry=reg)
    assert server.registry is reg
    server.serve_many(_stream(data, 3))
    assert reg.snapshot()["serving.served"] == 3
    server.close()
