"""Train-while-serve: the OnlineTrainer fold/refresh machinery and the
shadow-serving freshness oracle.

The binding contract (serving/online.py + serving/shadow.py): after
``fold(); refresh_dense()`` the continuously-updated live engine serves
bit-for-bit what a cold rebuild of the trainer's current parameters would
serve — folds ride the quantize-at-ingestion path, refreshes re-quantize
the dense tables with the build-time transform — so the shadow HR gap is
exactly zero at every checkpoint, and anything in between is *measured*
staleness, not silent drift.
"""
import threading

import jax
import numpy as np
import pytest

from repro.data import synthetic
from repro.data.synthetic import serving_queries as _queries
from repro.models import recsys as rs
from repro.serving import (
    LiveCatalog,
    MicroBatcher,
    OnlineTrainer,
    RecSysEngine,
    ShadowHarness,
    make_server,
    rebuild_from_params,
)


@pytest.fixture(scope="module")
def world():
    data = synthetic.make_movielens(n_users=120, n_items=90, history_len=6)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=6)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=data.n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=16,
                                top_k=5, hot_rows=32, item_freqs=freqs)
    return engine, data, cfg, params


def _trainer(world, **kw):
    engine, data, cfg, params = world
    cat = LiveCatalog(engine, delta_capacity=engine.cfg.n_items)
    return OnlineTrainer(cat, cfg, params, **kw), data


def _serve(engine, queries):
    out = MicroBatcher(engine, max_batch=8).serve_many(queries)
    return (np.stack([o.items for o in out]),
            np.stack([o.scores for o in out]))


def _batches(data, n, seed=1, batch=64):
    return list(synthetic.movielens_batches(data, batch, n, seed=seed))


# ---------------------------------------------------------------------------
# the fold/refresh contract: live == cold rebuild, bit for bit
# ---------------------------------------------------------------------------
def test_fold_refresh_bitmatches_cold_rebuild(world):
    trainer, data = _trainer(world, fold_every=0)
    for b in _batches(data, 5):
        trainer.step(b)
    trainer.fold()
    trainer.refresh_dense()
    queries = list(_queries(data, np.arange(20) % 60))
    live = _serve(trainer.catalog.engine, queries)
    ref = _serve(rebuild_from_params(trainer.catalog.engine,
                                     trainer.params), queries)
    np.testing.assert_array_equal(live[0], ref[0])
    np.testing.assert_array_equal(live[1], ref[1])
    # ... and against the catalog's own table-level oracle
    tbl = _serve(trainer.catalog.rebuild_reference(), queries)
    np.testing.assert_array_equal(live[0], tbl[0])


def test_shadow_checkpoint_gap_is_zero(world):
    """The shadow gate doesn't just pass within tolerance — the fold and
    refresh transforms are the exact build-time transforms, so live and
    cold-rebuilt HR are IDENTICAL and the probe agreement is total."""
    trainer, data = _trainer(world, fold_every=2)
    shadow = ShadowHarness(trainer, data, k=5, tol=0.01, probe_batch=64)
    for b in _batches(data, 6):
        trainer.step(b)
    rec = shadow.checkpoint()
    assert rec.gap == 0.0
    assert rec.agree_frac == 1.0
    assert rec.hr_live == rec.hr_ref
    assert shadow.records == [rec]
    # a second checkpoint with no intervening steps still holds
    assert shadow.checkpoint().gap == 0.0


def test_shadow_detects_divergence_and_gates(world):
    """A live engine that really diverges from the trainer's parameters
    must be visible to the harness — and the tolerance check is a gate
    (raises), not a logger."""
    trainer, data = _trainer(world, fold_every=0)
    for b in _batches(data, 3):
        trainer.step(b)
    trainer.fold()
    trainer.refresh_dense()
    # corrupt the live catalog behind the trainer's back: the next fold
    # sees no trainer-side change, so serving stays wrong while the cold
    # rebuild of the (honest) parameters does not
    rng = np.random.default_rng(0)
    d = trainer._last_folded.shape[1]
    trainer.catalog.upsert(np.arange(30),
                           rng.normal(size=(30, d)).astype(np.float32) * 5)
    rec = ShadowHarness(trainer, data, k=5, tol=1.0,
                        probe_batch=64).checkpoint()
    assert rec.agree_frac < 1.0  # the probe sees the divergence
    # the gate fires whenever the gap leaves the band (records first,
    # then raises — the failing record is preserved for postmortems)
    shadow = ShadowHarness(trainer, data, k=5, tol=-1.0, probe_batch=0)
    with pytest.raises(AssertionError, match="exceeds tol"):
        shadow.checkpoint()
    assert len(shadow.records) == 1


# ---------------------------------------------------------------------------
# staleness accounting: landed vs visible is measured, not assumed
# ---------------------------------------------------------------------------
def test_staleness_counters(world):
    trainer, data = _trainer(world, fold_every=0)
    bs = _batches(data, 3)
    for i, b in enumerate(bs):
        trainer.step(b)
        assert trainer.updates_landed == i + 1
        assert trainer.updates_visible == 0
        assert trainer.updates_pending == i + 1
    assert trainer.staleness_ms == []
    n = trainer.fold()
    assert n > 0  # training moved embeddings
    assert trainer.updates_visible == 3 and trainer.updates_pending == 0
    assert len(trainer.staleness_ms) == 3
    assert all(ms >= 0.0 for ms in trainer.staleness_ms)
    # staleness is monotone in landing order: the first-landed batch
    # waited longest
    assert trainer.staleness_ms == sorted(trainer.staleness_ms,
                                          reverse=True)
    st = trainer.stats()
    assert st["updates_landed"] == 3 and st["updates_pending"] == 0
    assert st["staleness_ms_mean"] > 0.0


def test_fold_cadence_and_noop(world):
    trainer, data = _trainer(world, fold_every=2)
    bs = _batches(data, 4)
    trainer.step(bs[0])
    assert trainer.n_folds == 0 and trainer.updates_pending == 1
    trainer.step(bs[1])  # cadence hit: auto-fold
    assert trainer.n_folds == 1 and trainer.updates_pending == 0
    # a fold with nothing pending is a publication no-op
    pending_before = trainer.catalog.n_pending
    assert trainer.fold() == 0
    assert trainer.catalog.n_pending == pending_before
    trainer.step(bs[2])
    trainer.step(bs[3])
    assert trainer.n_folds == 3 and trainer.updates_visible == 4


def test_refresh_preserves_treedef(world):
    """Publications must never retrace jitted serve steps: fold and
    refresh keep the engine's treedef and leaf shapes identical."""
    trainer, data = _trainer(world, fold_every=1)
    before = trainer.catalog.engine
    want = jax.tree_util.tree_structure(before)
    for b in _batches(data, 2):
        trainer.step(b)
    trainer.refresh_dense()
    after = trainer.catalog.engine
    assert jax.tree_util.tree_structure(after) == want
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(after)):
        assert a.shape == b.shape and a.dtype == b.dtype


# ---------------------------------------------------------------------------
# concurrent train-while-serve (the deployment shape)
# ---------------------------------------------------------------------------
def test_train_while_serve_concurrent_smoke(world):
    """A paced training thread folds (and compacts) into the catalog
    while the concurrent front-end serves: zero error tickets, every
    publication lands under the serve lock, and the final shadow
    checkpoint still shows a zero gap."""
    engine, data, cfg, params = world
    cat = LiveCatalog(engine, delta_capacity=engine.cfg.n_items)
    server = make_server(cat.engine, "concurrent", max_batch=8,
                         buckets=(8,), queue_depth=None)
    cat.attach(server)
    trainer = OnlineTrainer(cat, cfg, params, fold_every=1,
                            compact_every=4)
    bs = _batches(data, 12)
    done = threading.Event()

    def train():
        for b in bs:
            trainer.step(b)
        done.set()

    th = threading.Thread(target=train, daemon=True)
    th.start()
    served = []
    while not done.is_set():
        served.extend(server.serve_many(
            list(_queries(data, np.arange(16) % 60))))
    th.join()
    served.extend(server.serve_many(
        list(_queries(data, np.arange(16) % 60))))
    assert served and all(s.status == "ok" for s in served)
    assert server.stats()["n_errors"] == 0
    assert trainer.n_folds == 12
    assert cat.epoch >= 3  # compact_every=4 really compacted under load
    rec = ShadowHarness(trainer, data, k=5, probe_batch=64).checkpoint()
    assert rec.gap == 0.0 and rec.agree_frac == 1.0
    server.close()
