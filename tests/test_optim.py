import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim.compression import (
    compress_decompress,
    compressed_psum,
    init_error_buffer,
)
from repro.utils import shard_map


def _rosenbrock_ish(params):
    x, y = params["x"], params["y"]
    return jnp.sum((1 - x) ** 2) + 5 * jnp.sum((y - x**2) ** 2)


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges(state_dtype):
    params = {"x": jnp.zeros((4, 8)), "y": jnp.zeros((4, 8))}
    state = adamw.init_adamw_state(params, state_dtype)
    loss0 = float(_rosenbrock_ish(params))
    for _ in range(300):
        grads = jax.grad(_rosenbrock_ish)(params)
        params, state = adamw.adamw_update(
            grads, state, params, 2e-2, weight_decay=0.0,
            state_dtype=state_dtype)
    loss1 = float(_rosenbrock_ish(params))
    assert loss1 < 0.05 * loss0, (state_dtype, loss0, loss1)


def test_int8_state_memory_is_int8():
    params = {"w": jnp.zeros((16, 256))}
    state = adamw.init_adamw_state(params, "int8")
    assert state.mu["w"].values.dtype == jnp.int8
    assert state.mu["w"].values.shape == (16, 256)
    assert state.mu["w"].scales.shape == (16, 1)


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0)}
    clipped, norm = adamw.clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90), rel=1e-5)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_shape():
    lr = adamw.cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) < 1e-3
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=0.1)
    assert float(lr(jnp.int32(99))) < 5e-4


def test_error_feedback_unbiased_over_time(key):
    """With error feedback, the accumulated compressed sum tracks the true
    sum (compression error does not accumulate)."""
    g = jax.random.normal(key, (8, 64))
    err = init_error_buffer({"g": g})
    total_true = np.zeros((8, 64))
    total_comp = np.zeros((8, 64))
    for i in range(50):
        gi = {"g": g * (1 + 0.1 * i)}
        comp, err = compress_decompress(gi, err)
        total_true += np.asarray(gi["g"])
        total_comp += np.asarray(comp["g"])
    rel = np.abs(total_comp - total_true).max() / np.abs(total_true).max()
    assert rel < 0.02, rel


def test_compressed_psum_single_device(key):
    mesh = jax.make_mesh((1,), ("data",))
    x = jax.random.normal(key, (4, 32))

    def f(a):
        return compressed_psum(a, "data")

    y = shard_map(f, mesh=mesh,
                      in_specs=jax.sharding.PartitionSpec(),
                      out_specs=jax.sharding.PartitionSpec(),
                      check_vma=False)(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.02)
