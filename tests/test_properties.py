"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.lsh import pack_bits, unpack_bits
from repro.core.nns import fixed_radius_nns
from repro.core.quantization import (
    dequantize_blockwise,
    dequantize_rowwise,
    quantize_blockwise,
    quantize_rowwise,
)
from repro.core.topk import threshold_topk
from repro.kernels.ref import hamming_distance_ref
from repro.kernels.streaming_nns import (
    big_key,
    key_shift,
    max_streamable_items,
    pack_key,
    unpack_key,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(
    rows=st.integers(1, 20),
    dim=st.integers(1, 40),
    seed=st.integers(0, 2**16),
    scale=st.floats(1e-3, 1e3),
)
def test_rowwise_quant_error_invariant(rows, dim, seed, scale):
    """|x - dq(q(x))| <= scale/2 elementwise, for any magnitude."""
    x = jnp.asarray(
        np.random.default_rng(seed).normal(size=(rows, dim)) * scale,
        dtype=jnp.float32,
    )
    q = quantize_rowwise(x)
    err = jnp.abs(x - dequantize_rowwise(q))
    assert bool(jnp.all(err <= q.scales / 2 + 1e-5 * scale))


@given(
    n=st.integers(1, 300),
    block=st.sampled_from([8, 32, 256]),
    seed=st.integers(0, 2**16),
)
def test_blockwise_roundtrip_shape_invariant(n, block, seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n,)), jnp.float32)
    q = quantize_blockwise(x, block=block)
    xd = dequantize_blockwise(q)
    assert xd.shape == x.shape
    assert bool(jnp.all(jnp.abs(x - xd) <= jnp.max(q.scales) / 2 + 1e-6))


@given(words=st.integers(1, 8), n=st.integers(1, 64), seed=st.integers(0, 2**16))
def test_hamming_metric_axioms(words, n, seed):
    """identity, symmetry, triangle inequality on packed codes."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 2**32, size=(n, words), dtype=np.uint32))
    d = np.asarray(hamming_distance_ref(codes, codes))
    assert (np.diagonal(d) == 0).all()
    assert (d == d.T).all()
    if n <= 16:  # triangle on a subset (O(n^3))
        for i in range(n):
            for j in range(n):
                assert (d[i, j] <= d[i][:, None] + d[:, j][None]).all() or True
                assert d[i, j] <= (d[i] + d[:, j]).min() + 2 * words * 32  # loose
        # exact triangle check
        assert (d[:, :, None] <= d[:, None, :] + d[None, :, :] + 1e-9).all()


@given(
    n=st.integers(2, 100),
    radius=st.integers(0, 64),
    seed=st.integers(0, 2**16),
)
def test_fixed_radius_monotone_in_radius(n, radius, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 2**32, size=(n, 2), dtype=np.uint32))
    q = codes[:1]
    r1 = fixed_radius_nns(q, codes, radius, max_candidates=8)
    r2 = fixed_radius_nns(q, codes, radius + 5, max_candidates=8)
    assert int(r2.counts[0]) >= int(r1.counts[0])


@given(
    n=st.integers(1, 120),
    q=st.integers(1, 4),
    radius=st.integers(0, 64),
    k=st.integers(1, 40),
    scan_block=st.integers(1, 150),
    seed=st.integers(0, 2**16),
)
def test_streaming_nns_equals_dense_property(n, q, radius, k, scan_block, seed):
    """Streaming NNS returns the identical NNSResult to the dense path for
    any scan_block — including blocks that don't divide n, exceed n, or are
    degenerate (1) — any radius, and any candidate bound."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 2**32, size=(n, 2), dtype=np.uint32))
    queries = jnp.asarray(
        rng.integers(0, 2**32, size=(q, 2), dtype=np.uint32))
    dense = fixed_radius_nns(queries, codes, radius, k, scan_block=0)
    stream = fixed_radius_nns(queries, codes, radius, k,
                              scan_block=scan_block)
    np.testing.assert_array_equal(
        np.asarray(dense.indices), np.asarray(stream.indices))
    np.testing.assert_array_equal(
        np.asarray(dense.distances), np.asarray(stream.distances))
    np.testing.assert_array_equal(
        np.asarray(dense.counts), np.asarray(stream.counts))


# ---------------------------------------------------------------------------
# streaming-NNS packed-key encoding (kernels/streaming_nns.py)
# ---------------------------------------------------------------------------
_WORDS = st.integers(1, 8)


@st.composite
def _key_pairs(draw):
    """(words, dist, row) with row hitting the capacity boundaries often."""
    words = draw(_WORDS)
    cap = max_streamable_items(words)
    dist = draw(st.integers(0, 32 * words))
    row = draw(st.one_of(
        st.integers(0, cap - 1),
        st.sampled_from([0, 1, cap // 2, cap - 2, cap - 1])))
    return words, dist, row


@given(_key_pairs())
def test_key_roundtrip_and_sentinel(pair):
    """pack/unpack round-trips exactly and every valid key is < big_key —
    including the boundary rows 0 and capacity-1 (2**22-1 at words=8)."""
    words, dist, row = pair
    key = pack_key(dist, row, words)
    assert unpack_key(key, words) == (dist, row)
    assert 0 <= key < big_key(words)
    assert key < 2**31  # stays an int32


@given(_key_pairs(), _key_pairs())
def test_key_total_order_matches_lexicographic(a, b):
    """key(a) < key(b) iff (dist, row)_a < (dist, row)_b — the packed int32
    compare IS the dense path's (distance, index) tie-break order."""
    hypothesis.assume(a[0] == b[0])  # same words -> same encoding
    words, da, ra = a
    _, db_, rb = b
    assert (pack_key(da, ra, words) < pack_key(db_, rb, words)) == (
        (da, ra) < (db_, rb))


@given(
    n=st.integers(2, 220),
    q=st.integers(1, 3),
    radius=st.integers(0, 64),
    k=st.integers(1, 24),
    scan_block=st.integers(1, 96),
    superblock=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_streaming_superblocks_equal_dense_property(n, q, radius, k,
                                                    scan_block, superblock,
                                                    seed):
    """Wide-key invariant: any superblock split (degenerate 1-row
    superblocks included) x any scan_block must return the identical
    NNSResult to the dense path — shard-offset edges, cross-superblock
    distance ties, and buffer overflow all land in this space."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 2**32, size=(n, 2), dtype=np.uint32))
    queries = jnp.asarray(
        rng.integers(0, 2**32, size=(q, 2), dtype=np.uint32))
    dense = fixed_radius_nns(queries, codes, radius, k, scan_block=0)
    wide = fixed_radius_nns(queries, codes, radius, k, scan_block=scan_block,
                            superblock=superblock)
    np.testing.assert_array_equal(
        np.asarray(dense.indices), np.asarray(wide.indices))
    np.testing.assert_array_equal(
        np.asarray(dense.distances), np.asarray(wide.distances))
    np.testing.assert_array_equal(
        np.asarray(dense.counts), np.asarray(wide.counts))


@given(
    k=st.integers(1, 10),
    n=st.integers(1, 50),
    thresh=st.floats(-2, 2),
    seed=st.integers(0, 2**16),
)
def test_threshold_topk_invariants(k, n, thresh, seed):
    scores = jnp.asarray(np.random.default_rng(seed).normal(size=(1, n)), jnp.float32)
    res = threshold_topk(scores, thresh, k)
    s = np.asarray(res.scores[0])
    idx = np.asarray(res.indices[0])
    valid = idx >= 0
    # all returned scores >= threshold and sorted descending
    assert (s[valid] >= thresh).all()
    assert (np.diff(s[valid]) <= 1e-6).all()
    # count consistency
    assert int(res.counts[0]) == int((np.asarray(scores[0]) >= thresh).sum())
    assert valid.sum() == min(k, int(res.counts[0]))


@given(
    bits_n=st.sampled_from([32, 64, 128, 256]),
    rows=st.integers(1, 10),
    seed=st.integers(0, 2**16),
)
def test_pack_unpack_property(bits_n, rows, seed):
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 2, size=(rows, bits_n)), jnp.int32)
    assert bool(jnp.all(unpack_bits(pack_bits(bits), bits_n) == bits))
