import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import (
    BlockQuantizedTensor,
    QuantizedTensor,
    dequantize_blockwise,
    dequantize_rowwise,
    quantize_blockwise,
    quantize_rowwise,
    quantize_symmetric_int8,
    rowwise_quant_error_bound,
)


def test_rowwise_roundtrip_error_bound(key):
    x = jax.random.normal(key, (64, 32)) * 3.0
    q = quantize_rowwise(x)
    xd = dequantize_rowwise(q)
    bound = rowwise_quant_error_bound(q)
    assert q.values.dtype == jnp.int8
    err = np.abs(np.asarray(x - xd))
    np.testing.assert_array_less(err, np.broadcast_to(np.asarray(bound) + 1e-6, err.shape))


def test_rowwise_exact_for_scaled_ints(key):
    # rows of the form scale * int (with max |int| = 127) reproduce exactly
    ints = jax.random.randint(key, (16, 8), -127, 128).astype(jnp.float32)
    ints = ints.at[:, 0].set(127.0)
    x = ints * 0.02
    xd = dequantize_rowwise(quantize_rowwise(x))
    np.testing.assert_allclose(np.asarray(xd), np.asarray(x), rtol=1e-5)


@pytest.mark.parametrize("shape", [(7,), (3, 5), (4, 16, 9)])
@pytest.mark.parametrize("block", [8, 256])
def test_blockwise_roundtrip(key, shape, block):
    x = jax.random.normal(key, shape) * 2.0
    q = quantize_blockwise(x, block=block)
    xd = dequantize_blockwise(q)
    assert xd.shape == x.shape
    # error bounded by half a quantization step per block
    err = np.abs(np.asarray(x - xd))
    assert err.max() <= float(q.scales.max()) / 2 + 1e-6


def test_quantized_tensor_is_pytree(key):
    q = quantize_rowwise(jax.random.normal(key, (8, 4)))
    leaves = jax.tree_util.tree_leaves(q)
    assert len(leaves) == 2
    q2 = jax.tree_util.tree_map(lambda x: x, q)
    assert isinstance(q2, QuantizedTensor)


def test_block_quantized_tensor_pytree_static_meta(key):
    q = quantize_blockwise(jax.random.normal(key, (10, 3)), block=8)
    q2 = jax.jit(lambda t: t)(q)
    assert isinstance(q2, BlockQuantizedTensor)
    assert q2.orig_shape == (10, 3)
    np.testing.assert_allclose(
        np.asarray(dequantize_blockwise(q2)),
        np.asarray(dequantize_blockwise(q)),
    )


def test_symmetric_axis_quant(key):
    x = jax.random.normal(key, (6, 12))
    q, s = quantize_symmetric_int8(x, axis=0)
    assert q.shape == x.shape and s.shape == (1, 12)
    np.testing.assert_allclose(
        np.asarray(q.astype(jnp.float32) * s), np.asarray(x), atol=float(s.max()) / 2 + 1e-6
    )
