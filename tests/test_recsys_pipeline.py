"""End-to-end behaviour tests for the paper's system: train YoutubeDNN /
DLRM on synthetic data, build the iMARS serving engine, serve queries, and
check the accuracy ordering of Sec. IV-B (small-scale smoke; the full run
is benchmarks/accuracy_hr.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.models import recsys as rs
from repro.optim import adamw
from repro.serving.recsys_engine import RecSysEngine, hit_rate


def _adam_fit(params, loss_fn, batches, lr=3e-3):
    state = adamw.init_adamw_state(params)
    lg = jax.jit(jax.value_and_grad(loss_fn))
    losses = []
    for batch in batches:
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, g = lg(params, b)
        params, state = adamw.adamw_update(g, state, params, lr,
                                           weight_decay=0.0)
        losses.append(float(loss))
    return params, losses


@pytest.fixture(scope="module")
def small_data():
    # 600 items keeps chance-level HR@10 (k/n_items) well below what the
    # trained tower reaches, so the accuracy-ordering assertions have margin
    return synthetic.make_movielens(n_users=400, n_items=600, history_len=8)


@pytest.fixture(scope="module")
def trained(small_data):
    data = small_data
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=8)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    params, _ = _adam_fit(params, lambda p, b: rs.filtering_loss(p, cfg, b),
                          synthetic.movielens_batches(data, 128, 250))
    params, _ = _adam_fit(params, lambda p, b: rs.ranking_loss(p, cfg, b),
                          synthetic.movielens_rank_batches(data, 64, 8, 80))
    return params, cfg


def test_filtering_training_learns(small_data):
    data = small_data
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=8)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    params, losses = _adam_fit(
        params, lambda p, b: rs.filtering_loss(p, cfg, b),
        synthetic.movielens_batches(data, 128, 120))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_engine_serves_and_costs(trained, small_data):
    params, cfg = trained
    engine = RecSysEngine.build(params, cfg, radius=110, n_candidates=20,
                                top_k=5)
    data = small_data
    idx = np.arange(8)
    batch = {
        **{k: jnp.asarray(v[idx]) for k, v in data.user_feats.items()},
        "history": jnp.asarray(data.histories[idx]),
        "genre": jnp.asarray(data.genres[idx]),
    }
    res = engine.serve(batch)
    assert res.items.shape == (8, 5)
    # returned ids are valid or -1
    arr = np.asarray(res.items)
    assert ((arr >= -1) & (arr < data.n_items)).all()
    # hot-cache counters ride along in the serve result
    assert int(res.stats.lookups) > 0
    assert 0.0 <= res.stats.hit_rate() <= 1.0
    # the hardware cost model rides along (N_candidates=20 here)
    from repro.core import cost_model as cm
    want = cm.end_to_end_movielens(n_candidates=20)
    assert res.cost.latency_us == pytest.approx(
        want["imars_latency_us"], rel=1e-6)
    assert res.cost.energy_uj == pytest.approx(
        want["imars_energy_uj"], rel=1e-6)


@pytest.mark.slow
def test_accuracy_ordering_fp32_int8_lsh(trained, small_data):
    """Paper Sec. IV-B: HR(fp32-cos) >= HR(int8-cos) > HR(lsh) and the int8
    drop is small; all three far above chance."""
    params, cfg = trained
    engine = RecSysEngine.build(params, cfg, radius=115, n_candidates=64)
    hr_fp32 = hit_rate(engine, small_data, k=10, mode="fp32")
    hr_int8 = hit_rate(engine, small_data, k=10, mode="int8")
    hr_lsh = hit_rate(engine, small_data, k=10, mode="lsh")
    chance = 10 / small_data.n_items
    # synthetic data reproduces the paper's ORDERING (see DESIGN.md §7):
    # fp32 ~ int8 (paper -0.6pt), LSH-Hamming strictly cheaper (paper -5.4pt)
    assert hr_fp32 > 1.2 * chance  # above random retrieval
    assert abs(hr_fp32 - hr_int8) < 0.02  # int8 ~ fp32
    assert hr_lsh <= hr_int8 + 0.01  # LSH does not beat exact cosine


def test_dlrm_trains(key):
    cfg = rs.DLRMConfig(cardinality=500)
    params = rs.init_dlrm(key, cfg)
    params, losses = _adam_fit(
        params, lambda p, b: rs.dlrm_loss(p, cfg, b),
        synthetic.make_criteo_batches(256, 150, cardinality=500))
    assert losses[-1] < losses[0] - 0.05, (losses[0], losses[-1])
    b = {k: jnp.asarray(v) for k, v in
         next(iter(synthetic.make_criteo_batches(512, 1, cardinality=500,
                                                 seed=9))).items()}
    # AUC-ish sanity: predictions separate the classes
    logits = rs.dlrm_forward(params, cfg, b)
    pos = np.asarray(logits)[np.asarray(b["label"]) == 1].mean()
    neg = np.asarray(logits)[np.asarray(b["label"]) == 0].mean()
    assert pos > neg
