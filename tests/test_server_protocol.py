"""The unified Server API: one protocol, one factory, three front-ends.

Every front-end constructed through `make_server` must serve the same
stream to the same bits (items, scores, AND cache counters) — the mode is
an execution knob, never a results knob. The concurrent front-end
additionally owns the overload contract: a full tenant queue sheds (with
per-tenant accounting, as resolved sentinel tickets — never an exception
out of `result()` and never a dead drain thread), close() with in-flight
tickets drains instead of deadlocking, and engine-swap/serve races stay
serialized. Typed exceptions (`ServingError` family) carry the rest."""
import threading
from collections import deque

import jax
import numpy as np
import pytest

from repro.data import synthetic
from repro.data.synthetic import serving_queries as _queries
from repro.models import recsys as rs
from repro.serving import (
    STATUS_ERROR,
    STATUS_OK,
    STATUS_SHED,
    ConcurrentFrontend,
    LoadGen,
    QueueFullError,
    RecSysEngine,
    SchemaMismatchError,
    Server,
    ServerClosedError,
    ServerConfigError,
    ServingError,
    make_server,
    summarize_trace,
)

MODES = ("sync", "pipelined", "concurrent")


@pytest.fixture(scope="module")
def served():
    data = synthetic.make_movielens(n_users=120, n_items=90, history_len=6)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=6)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=data.n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=16,
                                top_k=5, hot_rows=32, item_freqs=freqs)
    return engine, data


def _make(engine, mode, **knobs):
    knobs.setdefault("max_batch", 8)
    if mode == "concurrent":
        knobs.setdefault("tenants", 4)
    return make_server(engine, mode, **knobs)


def _stream(data, n=19):
    return _queries(data, np.arange(n) % 7)


# ---------------------------------------------------------------------------
# the factory
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", MODES)
def test_factory_builds_protocol_instances(served, mode):
    """Every mode satisfies the structural `Server` protocol and reports
    itself in stats()."""
    engine, _ = served
    server = _make(engine, mode)
    assert isinstance(server, Server)
    assert server.mode == mode
    st = server.stats()
    assert st["mode"] == mode and st["n_submitted"] == 0
    server.close()
    assert server.stats()["closed"]


def test_factory_rejects_unknown_mode_and_knobs(served):
    engine, _ = served
    with pytest.raises(ServerConfigError, match="unknown serving mode"):
        make_server(engine, "warp")
    # knob valid for one mode is rejected for another, with the mode named
    with pytest.raises(ServerConfigError, match="sync"):
        make_server(engine, "sync", depth=2)
    with pytest.raises(ServerConfigError, match="tenants"):
        make_server(engine, "pipelined", tenants=4)
    # unknown knobs land in the same typed family (no ValueError base)
    with pytest.raises(ServerConfigError):
        make_server(engine, "concurrent", bogus_knob=1)


# ---------------------------------------------------------------------------
# parity: one stream, three front-ends, identical bits
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ("pipelined", "concurrent"))
def test_modes_bitmatch_sync(served, mode):
    """items, scores, and hot-cache counters all match the sync path —
    mixed full + padded-tail buckets included."""
    engine, data = served
    stream = _stream(data)
    ref = _make(engine, "sync")
    want = ref.serve_many(stream)
    server = _make(engine, mode)
    got = server.serve_many(stream)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w.items, g.items)
        np.testing.assert_array_equal(w.scores, g.scores)
        assert g.status == STATUS_OK and g.ok
    for key in ("n_served", "n_padded", "n_batches",
                "cache_hits", "cache_lookups"):
        assert server.stats()[key] == ref.stats()[key], key
    server.close()
    ref.close()


@pytest.mark.parametrize("mode", MODES)
def test_ticket_api_and_tenant_accounting(served, mode):
    """submit/result round-trips per tenant; per_tenant stats account every
    ticket; redeeming twice raises KeyError in every mode."""
    engine, data = served
    server = _make(engine, mode)
    stream = _stream(data, 6)
    tickets = [server.submit(q, tenant=i % 2) for i, q in enumerate(stream)]
    server.flush()
    ref = _make(engine, "sync").serve_many(stream)
    for i, (t, w) in enumerate(zip(tickets, ref)):
        got = server.result(t, timeout=30.0)
        np.testing.assert_array_equal(got.items, w.items)
        assert got.tenant == i % 2
    pt = server.stats()["per_tenant"]
    assert pt[0]["served"] == 3 and pt[1]["served"] == 3
    with pytest.raises(KeyError):
        server.result(tickets[0])
    server.close()


@pytest.mark.parametrize("mode", MODES)
def test_closed_server_rejects_submits(served, mode):
    engine, data = served
    server = _make(engine, mode)
    server.close()
    with pytest.raises(ServerClosedError):
        server.submit(_stream(data, 1)[0])
    server.close()  # idempotent


@pytest.mark.parametrize("mode", MODES)
def test_swap_engine_schema_mismatch_is_typed(served, mode):
    """A schema-mismatched swap raises SchemaMismatchError and leaves the
    server serving."""
    engine, data = served
    cfg2 = rs.YoutubeDNNConfig(
        n_items=data.n_items, user_features={"user_id": data.n_users},
        history_len=6)
    other = RecSysEngine.build(rs.init_youtubednn(jax.random.key(1), cfg2),
                               cfg2, radius=112, n_candidates=16, top_k=5)
    server = _make(engine, mode)
    with pytest.raises(SchemaMismatchError, match="schema"):
        server.swap_engine(other)
    out = server.serve_many(_stream(data, 3))
    assert all(s.ok for s in out)
    server.close()


# ---------------------------------------------------------------------------
# overload: shedding, accounting, no deadlock
# ---------------------------------------------------------------------------
def test_full_queue_sheds_with_accounting(served):
    """With the drain thread parked, submits beyond queue_depth shed:
    resolved sentinel tickets (items all -1), per-tenant shed counts, and
    the survivors still serve exact results after start()."""
    engine, data = served
    server = _make(engine, "concurrent", queue_depth=4, autostart=False)
    stream = _stream(data)
    tickets = [server.submit(q) for q in stream]
    st = server.stats()
    assert st["per_tenant"][0]["shed"] == len(stream) - 4
    server.start()
    server.flush()
    ref = _make(engine, "sync").serve_many(stream[:4])
    got = [server.result(t, timeout=30.0) for t in tickets]
    for g, w in zip(got[:4], ref):
        assert g.status == STATUS_OK
        np.testing.assert_array_equal(g.items, w.items)
    for g in got[4:]:
        assert g.status == STATUS_SHED and not g.ok
        assert (g.items == -1).all() and (g.scores == 0).all()
    st = server.stats()
    pt = st["per_tenant"][0]
    assert pt["submitted"] == len(stream)
    assert pt["served"] + pt["shed"] + pt["errors"] == len(stream)
    trace = server.take_trace()
    assert sum(r.status == STATUS_SHED for r in trace) == len(stream) - 4
    server.close()


def test_shed_false_raises_queue_full(served):
    engine, data = served
    server = _make(engine, "concurrent", queue_depth=2, shed=False,
                   autostart=False)
    q = _stream(data, 1)[0]
    server.submit(q)
    server.submit(q)
    with pytest.raises(QueueFullError):
        server.submit(q)
    server.start()
    server.close()


def test_close_with_inflight_tickets_drains(served):
    """close() with queued + in-flight work drains everything (no deadlock,
    no lost tickets) — even when the drain thread was never started."""
    engine, data = served
    stream = _stream(data, 9)
    for autostart in (True, False):
        server = _make(engine, "concurrent", autostart=autostart)
        tickets = [server.submit(q, tenant=i % 3)
                   for i, q in enumerate(stream)]
        server.close()
        got = [server.result(t, timeout=30.0) for t in tickets]
        assert all(g.status == STATUS_OK for g in got)
        ref = _make(engine, "sync").serve_many(stream)
        for g, w in zip(got, ref):
            np.testing.assert_array_equal(g.items, w.items)


def test_drain_thread_survives_engine_errors(served):
    """An exception inside the serve path resolves that batch's tickets as
    status=error sentinels and keeps the thread alive for later submits —
    overload or poison queries must never kill the drain loop."""
    engine, data = served
    server = _make(engine, "concurrent", autostart=False)
    stream = _stream(data, 4)
    boom = ServingError("injected serve failure")
    real_inner = server._inner

    class _Exploding:
        # the containment path resets these after a failure; give it the
        # real attributes so the reset itself cannot raise
        engine = real_inner.engine
        _pending: list = []
        _ring = deque()
        _results: dict = {}

        def submit(self, q):
            raise boom

    server._inner = _Exploding()
    bad = [server.submit(q) for q in stream]
    server.start()
    server.flush()
    got = [server.result(t, timeout=30.0) for t in bad]
    assert all(g.status == STATUS_ERROR for g in got)
    # the thread is still draining: restore the real inner and serve
    server._inner = real_inner
    st = server.stats()
    assert st["last_error"] == "ServingError: injected serve failure"
    assert st["per_tenant"][0]["errors"] == len(stream)
    ok = [server.submit(q) for q in stream]
    server.flush()
    ref = _make(engine, "sync").serve_many(stream)
    for t, w in zip(ok, ref):
        g = server.result(t, timeout=30.0)
        assert g.status == STATUS_OK
        np.testing.assert_array_equal(g.items, w.items)
    server.close()


def test_concurrent_submitters_one_drain(served):
    """Many submitting threads against one front-end: every ticket resolves
    to the exact sync result for its own query (ticket fan-out is
    thread-safe even though all JAX work stays on the one drain thread)."""
    engine, data = served
    server = _make(engine, "concurrent", tenants=4, queue_depth=64)
    stream = _stream(data, 8)
    ref = _make(engine, "sync").serve_many(stream)
    results = {}

    def worker(tenant):
        ts = [server.submit(q, tenant=tenant) for q in stream]
        server.flush()
        results[tenant] = [server.result(t, timeout=30.0) for t in ts]

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=60)
        assert not th.is_alive(), "submitter deadlocked"
    for tenant, got in results.items():
        assert [g.tenant for g in got] == [tenant] * len(stream)
        for g, w in zip(got, ref):
            assert g.status == STATUS_OK
            np.testing.assert_array_equal(g.items, w.items)
            np.testing.assert_array_equal(g.scores, w.scores)
    server.close()


# ---------------------------------------------------------------------------
# retired shims stay retired
# ---------------------------------------------------------------------------
def test_pre_protocol_shims_are_gone(served):
    engine, data = served
    server = _make(engine, "sync")
    server.serve_many(_stream(data))
    # the one-release deprecated accessors were removed: stats() is the API
    assert not hasattr(server, "cache_hit_rate")
    assert not hasattr(server, "padding_fraction")
    st = server.stats()
    assert 0.0 <= st["cache_hit_rate"] <= 1.0
    assert 0.0 <= st["padding_fraction"] < 1.0
    # typed errors no longer alias ValueError (pre-protocol compat window)
    assert not issubclass(ServerConfigError, ValueError)
    assert not issubclass(SchemaMismatchError, ValueError)


# ---------------------------------------------------------------------------
# load generator
# ---------------------------------------------------------------------------
def test_load_gen_schedule_is_deterministic():
    mk = lambda: LoadGen(rate_qps=200, duration_s=0.5, tenants=2,
                         pool_size=32, zipf_a=1.2, seed=7).schedule()
    a, b = mk(), mk()
    assert a == b and len(a) > 0
    assert {t for _, t, _ in a} == {0, 1}
    assert all(0 <= qi < 32 for _, _, qi in a)
    assert all(x[0] <= y[0] for x, y in zip(a, a[1:]))


def test_load_gen_zipf_skews_and_burst_raises_rate():
    sched = LoadGen(rate_qps=2000, duration_s=1.0, pool_size=64,
                    zipf_a=1.3, seed=0).schedule()
    qs = [qi for _, _, qi in sched]
    assert qs.count(0) > qs.count(32)  # rank-1 beats the tail
    base = LoadGen(rate_qps=500, duration_s=2.0, pool_size=8, seed=1)
    burst = LoadGen(rate_qps=500, duration_s=2.0, pool_size=8, seed=1,
                    burst=(0.5, 0.25, 4.0))
    # 25% duty at 4x + 75% at 1x -> ~1.75x the base arrivals
    ratio = len(burst.schedule()) / len(base.schedule())
    assert 1.4 < ratio < 2.1
    with pytest.raises(ServerConfigError, match="burst"):
        LoadGen(rate_qps=1, duration_s=1, pool_size=1, burst=(0, 1, 1))


def test_load_gen_replay_and_summary(served):
    """Replay through the concurrent front-end: the trace accounts every
    arrival, the summary's tenants partition it, and every admitted ticket
    bit-matches the sync serve of its own pool query."""
    engine, data = served
    pool = _queries(data, np.arange(16))
    gen = LoadGen(rate_qps=400, duration_s=0.3, tenants=2, pool_size=16,
                  seed=3)
    server = _make(engine, "concurrent", tenants=2, queue_depth=64)
    server.serve_many(pool[:8])  # compile off the trace
    server.take_trace()
    replay = gen.replay(server, pool)
    server.flush()
    trace = server.take_trace()
    assert len(trace) == len(replay) == len(gen.schedule())
    summary = summarize_trace(trace, gen.duration_s)
    assert set(summary.per_tenant) == {0, 1}
    assert summary.shed_frac + summary.error_frac < 1.0
    ref = _make(engine, "sync").serve_many(pool)
    for ticket, tenant, qi in replay:
        got = server.result(ticket, timeout=30.0)
        assert got.tenant == tenant
        if got.status == STATUS_OK:
            np.testing.assert_array_equal(got.items, ref[qi].items)
            np.testing.assert_array_equal(got.scores, ref[qi].scores)
    server.close()


def test_frontend_direct_construction_still_supported(served):
    """`ConcurrentFrontend` remains importable/constructible for library
    users; make_server is the porcelain, not a gate."""
    engine, data = served
    fe = ConcurrentFrontend(engine, tenants=2, max_batch=8)
    out = fe.serve_many(_stream(data, 3), tenant=1)
    assert all(s.ok and s.tenant == 1 for s in out)
    fe.close()
