import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduce_config
from repro.configs.registry import get_arch
from repro.data.lm_data import PrefetchIterator, synthetic_token_stream
from repro.models import transformer as tf
from repro.serving.engine import LMServingEngine
from repro.serving.kv_cache import cache_bytes, init_cache


def test_lm_engine_generates_greedy():
    cfg = reduce_config(get_arch("qwen3-8b").model).with_(n_layers=2)
    params = tf.init_params(cfg, jax.random.key(0))
    engine = LMServingEngine(params, cfg, batch=2, cache_len=48)
    rng = np.random.default_rng(0)
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)}
    out = engine.generate(prompt, n_steps=6)
    assert out.tokens.shape == (2, 6)
    assert (out.tokens >= 0).all() and (out.tokens < cfg.vocab_size).all()
    # greedy decode is deterministic
    out2 = engine.generate(prompt, n_steps=6)
    np.testing.assert_array_equal(out.tokens, out2.tokens)


def test_int8_cache_quantization_roundtrip():
    """int8 KV caches (the paper's ET quantization) keep decode logits close
    to the bf16-cache decode."""
    from repro.serving.engine import decode_step, prefill

    cfg = reduce_config(get_arch("qwen3-8b").model).with_(
        n_layers=2, dtype="float32")
    params = tf.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 13)), jnp.int32)
    prefix, last = {"tokens": toks[:, :12]}, {"tokens": toks[:, 12:]}

    outs = {}
    for dt in ("bfloat16", "int8"):
        pre = prefill(params, cfg, prefix, cache_len=16, cache_dtype=dt)
        dec = decode_step(params, cfg, last, pre.caches, jnp.int32(12))
        outs[dt] = np.asarray(dec.logits[:, -1], np.float32)
    # the int8-cache greedy choice is near-optimal under the bf16 cache:
    # with random weights the logit landscape is nearly flat, so exact
    # argmax equality is a knife-edge — instead require the chosen token's
    # bf16 logit to sit within a sliver of the bf16 maximum
    b16, i8 = outs["bfloat16"], outs["int8"]
    tok8 = i8.argmax(-1)
    gap = b16.max(-1) - np.take_along_axis(b16, tok8[:, None], -1)[:, 0]
    spread = b16.max(-1) - b16.min(-1)
    assert (gap <= 0.05 * spread).all(), (gap, spread)
    np.testing.assert_allclose(outs["int8"], outs["bfloat16"],
                               rtol=0.12, atol=0.12)
    # and int8 cache is ~2x smaller than bf16 (values dominate scales)
    c8 = init_cache(cfg, 2, 16, "int8")
    c16 = init_cache(cfg, 2, 16, "bfloat16")
    assert cache_bytes(c8) < 0.8 * cache_bytes(c16)


def test_prefetch_iterator():
    stream = synthetic_token_stream(100, 8, 2, seed=0)
    pf = PrefetchIterator(stream, depth=2)
    items = [next(pf) for _ in range(5)]
    assert all(i["tokens"].shape == (2, 8) for i in items)
    # deterministic vs raw stream
    raw = synthetic_token_stream(100, 8, 2, seed=0)
    raw_items = [next(raw) for _ in range(5)]
    for a, b in zip(items, raw_items):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_prefetch_propagates_errors():
    def bad():
        yield {"x": 1}
        raise RuntimeError("boom")

    pf = PrefetchIterator(bad(), depth=2)
    next(pf)
    with pytest.raises(RuntimeError):
        next(pf)
        next(pf)
