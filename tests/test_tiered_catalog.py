"""Tier-migration churn matrix: the frequency-tiered out-of-core catalog
must serve bit-identically to the all-RAM engine over the same state —
items, scores, NNS candidates, AND hot-cache counters — through every
tier transition: cold->int8 promotion, int8->hot promotion, demotion in
both directions, deletes of promoted rows, and migration riding epoch
compaction, including under the depth-3 pipelined ring.

Runs in the CI pallas-interpret lane: every serve drives the streaming
NNS kernel (out-of-core chunks on the tiered side, resident superblocks
on the all-RAM side), so the bit-match also cross-checks the two kernel
drive paths against each other.
"""
import jax
import numpy as np
import pytest

from repro.data import synthetic
from repro.models import recsys as rs
from repro.serving import (
    AsyncServer,
    LiveCatalog,
    MicroBatcher,
    RecSysEngine,
    TieredCatalog,
    open_base_shard,
    write_base_shard,
)
from repro.serving.hot_cache import INVALID_ID


@pytest.fixture(scope="module")
def served():
    data = synthetic.make_movielens(n_users=60, n_items=90, history_len=6)
    cfg = rs.YoutubeDNNConfig(
        n_items=data.n_items,
        user_features={"user_id": data.n_users, "gender": 3, "age": 7,
                       "occupation": 21, "zip_bucket": 250},
        history_len=6)
    params = rs.init_youtubednn(jax.random.key(0), cfg)
    freqs = np.bincount(data.histories[data.histories >= 0],
                        minlength=data.n_items)
    engine = RecSysEngine.build(params, cfg, radius=112, n_candidates=16,
                                top_k=5, hot_rows=16, item_freqs=freqs)
    return engine, data, freqs


def _batch(engine, data, idx, bucket=16):
    queries = synthetic.serving_queries(data, idx)
    return MicroBatcher(engine)._stack_np(list(queries), bucket)


def _rows(rng, m, d):
    return rng.normal(size=(m, d)).astype(np.float32)


def _assert_serves_match(cat, batch):
    """Tiered serve == all-RAM serve == rebuilt-reference serve, bitwise,
    counters included. Returns the tiered result."""
    got = cat.serve(batch)
    for oracle in (cat.to_ram_engine(), cat.rebuild_reference()):
        want = oracle.serve({k: np.asarray(v) for k, v in batch.items()})
        np.testing.assert_array_equal(np.asarray(got.items),
                                      np.asarray(want.items))
        np.testing.assert_array_equal(np.asarray(got.topk.scores),
                                      np.asarray(want.topk.scores))
        np.testing.assert_array_equal(np.asarray(got.nns.indices),
                                      np.asarray(want.nns.indices))
        np.testing.assert_array_equal(np.asarray(got.nns.distances),
                                      np.asarray(want.nns.distances))
        assert int(got.stats.hits) == int(want.stats.hits)
        assert int(got.stats.lookups) == int(want.stats.lookups)
    return got


# ---------------------------------------------------------------------------
# base shard round-trip
# ---------------------------------------------------------------------------
def test_base_shard_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    vals = rng.integers(-128, 128, size=(300, 8), dtype=np.int8)
    scales = rng.random((300, 1), dtype=np.float32)
    sigs = rng.integers(0, 2**32, size=(300, 8), dtype=np.uint32)
    alive = rng.random(300) < 0.8
    write_base_shard(str(tmp_path / "s"), vals, scales, sigs, alive=alive)
    shard, alive2, summary = open_base_shard(str(tmp_path / "s"))
    assert (shard.n, shard.d, shard.words) == (300, 8, 8)
    np.testing.assert_array_equal(np.asarray(shard.values), vals)
    np.testing.assert_array_equal(np.asarray(shard.scales), scales)
    np.testing.assert_array_equal(np.asarray(shard.sigs), sigs)
    np.testing.assert_array_equal(alive2, alive)
    assert summary is None  # none persisted


# ---------------------------------------------------------------------------
# churn matrix against the all-RAM oracles
# ---------------------------------------------------------------------------
def test_tiered_initial_state_matches_allram(served, tmp_path):
    engine, data, freqs = served
    cat = TieredCatalog.from_engine(engine, str(tmp_path), pool_rows=40,
                                    item_freqs=freqs, delta_capacity=8)
    res = _assert_serves_match(cat, _batch(engine, data, range(12)))
    assert int(res.stats.lookups) > 0
    st = cat.stats()
    assert st["pool_rows"] == 40 and st["hot_rows"] == 16
    assert st["resident_bytes"] > 0
    # hot tier is a prefix of the pool: every pinned id is byte-resident
    hot = np.asarray(cat.inner.item_hot.hot_ids)
    hot = hot[hot != INVALID_ID]
    assert np.isin(hot, cat.pool_ids).all()


def test_churn_matrix_bit_matches_reference(served, tmp_path):
    """cold->int8 promote, int8->hot promote, demotions, delete of a
    promoted row, re-embed of a pool row — every intermediate state serves
    bit-identically to the all-RAM engine and the rebuilt reference."""
    engine, data, freqs = served
    rng = np.random.default_rng(1)
    d = engine.item_table_q.shape[1]
    cat = TieredCatalog.from_engine(engine, str(tmp_path), pool_rows=32,
                                    item_freqs=freqs, delta_capacity=8)
    batch = _batch(engine, data, range(12))
    hot_id = int(np.asarray(cat.inner.item_hot.hot_ids)[0])
    pool_only = int(cat.pool_ids[~np.isin(
        cat.pool_ids, np.asarray(cat.inner.item_hot.hot_ids))][0])
    cold_id = int(np.setdiff1d(np.arange(90), cat.pool_ids)[0])

    # upsert touching hot + pool rows: both tiers must evict the stale bytes
    cat.upsert([hot_id, pool_only], _rows(rng, 2, d))
    assert hot_id not in np.asarray(cat.inner.item_hot.hot_ids)
    assert hot_id not in cat.pool_ids and pool_only not in cat.pool_ids
    _assert_serves_match(cat, batch)

    # delete of a promoted row + a cold row
    cat.delete([pool_only, cold_id])
    _assert_serves_match(cat, batch)

    # cold->int8 and int8->hot promotion: skew measured frequency to a
    # cold id and compact — migration rides the epoch fold
    cat.item_freqs[:] = 0
    promoted = int(np.setdiff1d(np.arange(90), cat.pool_ids)[-1])
    cat.item_freqs[promoted] = 10_000
    cat.compact()
    assert promoted in cat.pool_ids  # cold -> int8 pool
    assert promoted in np.asarray(cat.inner.item_hot.hot_ids)  # -> hot
    assert cat.n_pending == 0 and cat.epoch == 1
    _assert_serves_match(cat, batch)

    # demotion: drop its frequency to the floor, everything else above it
    cat.item_freqs[:] = 100
    cat.item_freqs[promoted] = 0
    cat.rebalance()
    assert promoted not in cat.pool_ids
    assert promoted not in np.asarray(cat.inner.item_hot.hot_ids)
    _assert_serves_match(cat, batch)

    # deleted rows never repin
    assert cold_id not in cat.pool_ids


def test_forced_compaction_on_full_delta(served, tmp_path):
    engine, data, freqs = served
    rng = np.random.default_rng(2)
    d = engine.item_table_q.shape[1]
    cat = TieredCatalog.from_engine(engine, str(tmp_path), pool_rows=24,
                                    item_freqs=freqs, delta_capacity=4)
    batch = _batch(engine, data, range(8))
    for lo in range(0, 18, 3):  # 6 batches of 3 > capacity 4 -> compactions
        ids = (np.arange(3) * 7 + lo) % 96  # includes ids past the base
        cat.upsert(ids, _rows(rng, 3, d))
        _assert_serves_match(cat, batch)
    assert cat.n_compactions >= 1
    assert cat.epoch == cat.n_compactions


def test_observe_feeds_freqs_and_never_changes_results(served, tmp_path):
    engine, data, freqs = served
    cat = TieredCatalog.from_engine(engine, str(tmp_path), pool_rows=24,
                                    item_freqs=None, delta_capacity=8)
    batch = _batch(engine, data, range(12))
    before = cat.item_freqs.copy()
    got = _assert_serves_match(cat, batch)
    assert cat.n_observed > 0
    gained = cat.item_freqs - before
    # every real history id and every served item was counted
    hist = np.asarray(batch["history"])[np.asarray(batch["valid"])]
    for gid in hist[hist >= 0].reshape(-1):
        assert gained[gid] > 0
    items = np.asarray(got.items)
    for gid in items[items >= 0].reshape(-1):
        assert gained[gid] > 0


# ---------------------------------------------------------------------------
# migration under the depth-3 pipelined ring (all-RAM LiveCatalog repin)
# ---------------------------------------------------------------------------
def test_repin_under_depth3_ring_matches_sync(served):
    """The hot-cache repin that rides `LiveCatalog.compact` (measured
    frequencies refill churn-evicted slots) must keep the depth-3
    `AsyncServer` bit-identical to the synchronous batcher across the
    same update/serve schedule — counters included."""
    engine, data, _ = served
    rng = np.random.default_rng(3)
    d = engine.item_table_q.shape[1]

    def run(server_cls, **kw):
        cat = LiveCatalog(engine, delta_capacity=8)
        server = server_cls(cat.engine, max_batch=8, **kw)
        cat.attach(server)
        out = []
        hot_sizes = []
        for step in range(3):
            queries = synthetic.serving_queries(
                data, range(step * 10, step * 10 + 10))
            for o in server.serve_many(list(queries)):
                out.append((o.items, o.scores))
            ids = (np.arange(4) + step * 4) % 90
            cat.upsert(ids, _rows(np.random.default_rng(50 + step), 4, d))
            cat.compact()  # repins from observed frequencies
            hot = np.asarray(cat.engine.item_hot.hot_ids)
            hot_sizes.append(int((hot != INVALID_ID).sum()))
        stats = server.stats()
        return out, (stats["cache_hits"], stats["cache_lookups"]), hot_sizes

    sync_out, sync_stats, sync_hot = run(MicroBatcher)
    ring_out, ring_stats, ring_hot = run(AsyncServer, depth=3)
    for (si, ss), (ri, rs_) in zip(sync_out, ring_out):
        np.testing.assert_array_equal(si, ri)
        np.testing.assert_array_equal(ss, rs_)
    assert sync_stats == ring_stats
    assert sync_hot == ring_hot
    # the repin actually refills slots the churn evictions emptied
    assert all(h == engine.item_hot.capacity for h in sync_hot)


def test_tiered_compact_migration_with_live_traffic(served, tmp_path):
    """Drive real traffic (observe), churn, compact, and verify the
    migrated tiers reflect the measured skew while still bit-matching."""
    engine, data, _ = served
    rng = np.random.default_rng(4)
    d = engine.item_table_q.shape[1]
    cat = TieredCatalog.from_engine(engine, str(tmp_path), pool_rows=24,
                                    item_freqs=None, delta_capacity=8)
    for step in range(3):
        batch = _batch(engine, data, range(step * 12, step * 12 + 12))
        _assert_serves_match(cat, batch)
    cat.upsert([1, 2], _rows(rng, 2, d))
    cat.compact()
    batch = _batch(engine, data, range(12))
    _assert_serves_match(cat, batch)
    # post-migration pool = top-measured rows: the most-observed alive id
    # must be byte-resident
    top = int(np.argmax(cat.item_freqs[:90] * cat.alive[:90]))
    assert top in cat.pool_ids


# ---------------------------------------------------------------------------
# persistence: frequency counters + hot-set ranking survive restore
# ---------------------------------------------------------------------------
def test_snapshot_restore_preserves_freqs_and_ranking(served, tmp_path):
    """The sidecar snapshot (delta + tombstones + measured frequencies)
    restores across an epoch swap into a freshly-opened catalog: the
    counters are bit-equal, the re-derived pool/hot ranking is the exact
    pre-snapshot one (no re-learning the skew), and serving bit-matches —
    delta overlay, tombstones, and summary included."""
    engine, data, _ = served
    rng = np.random.default_rng(6)
    d = engine.item_table_q.shape[1]
    shard_dir, snap_dir = tmp_path / "shard", tmp_path / "snap"
    cat = TieredCatalog.from_engine(engine, str(shard_dir), pool_rows=24,
                                    item_freqs=None, delta_capacity=8)
    # measured traffic -> churn -> EPOCH SWAP -> more traffic + churn, so
    # the snapshot carries post-swap counters, pending rows, and
    # tombstones all at once
    for step in range(3):
        _assert_serves_match(
            cat, _batch(engine, data, range(step * 12, step * 12 + 12)))
    cat.upsert([1, 2, 92], _rows(rng, 3, d))
    cat.delete([3])
    cat.compact()
    assert cat.epoch == 1
    _assert_serves_match(cat, _batch(engine, data, range(12)))
    cat.upsert([5, 94], _rows(rng, 2, d))
    cat.delete([7])
    cat.snapshot(snap_dir)

    other = TieredCatalog.open(str(shard_dir), engine, pool_rows=24,
                               delta_capacity=8)
    assert not np.array_equal(other.item_freqs, cat.item_freqs)  # cold
    other.restore(snap_dir)
    np.testing.assert_array_equal(other.item_freqs, cat.item_freqs)
    assert other.n_observed == cat.n_observed
    np.testing.assert_array_equal(other.alive, cat.alive)
    np.testing.assert_array_equal(np.asarray(other.delta.ids),
                                  np.asarray(cat.delta.ids))
    # the hot-set ranking is the exact pre-snapshot one. (Restore ends in
    # `rebalance()`; the live side's pool has churn-evicted slots that
    # only refill at its next rebalance — pure residency movement, so
    # bring it to the same image before comparing membership.)
    cat.rebalance()
    np.testing.assert_array_equal(other.pool_ids, cat.pool_ids)
    np.testing.assert_array_equal(np.asarray(other.inner.item_hot.hot_ids),
                                  np.asarray(cat.inner.item_hot.hot_ids))
    for f in ("or_sigs", "and_sigs", "min_pc", "max_pc", "n_alive"):
        np.testing.assert_array_equal(np.asarray(getattr(other.summary, f)),
                                      np.asarray(getattr(cat.summary, f)))
    batch = _batch(engine, data, range(8, 20))
    want, got = cat.serve(batch), other.serve(batch)
    np.testing.assert_array_equal(np.asarray(want.items),
                                  np.asarray(got.items))
    np.testing.assert_array_equal(np.asarray(want.topk.scores),
                                  np.asarray(got.topk.scores))
    assert int(want.stats.hits) == int(got.stats.hits)


def test_restore_guards(served, tmp_path):
    """Restore refuses an empty snapshot dir and an epoch mismatch (the
    sidecar is only valid against the base bytes it was taken over)."""
    engine, data, _ = served
    rng = np.random.default_rng(7)
    d = engine.item_table_q.shape[1]
    cat = TieredCatalog.from_engine(engine, str(tmp_path / "a"),
                                    pool_rows=16, delta_capacity=8)
    with pytest.raises(FileNotFoundError, match="no committed snapshot"):
        cat.restore(tmp_path / "empty")
    cat.upsert([1], _rows(rng, 1, d))
    cat.compact()  # epoch 1
    cat.snapshot(tmp_path / "snap")
    fresh = TieredCatalog.from_engine(engine, str(tmp_path / "b"),
                                      pool_rows=16, delta_capacity=8)
    assert fresh.epoch == 0
    with pytest.raises(ValueError, match="does not match the opened"):
        fresh.restore(tmp_path / "snap")
