import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParallelConfig, ShapeConfig
from repro.configs.reduced import reduce_config
from repro.configs.registry import get_arch
from repro.distributed import training as tr
from repro.models import transformer as tf


def _tiny_setup(arch="qwen2.5-3b", accum=2, logit_chunk=8):
    cfg = reduce_config(get_arch(arch).model).with_(n_layers=2)
    pcfg = ParallelConfig(
        remat="block", logit_chunk=logit_chunk,
        grad_accum={"tiny": accum}, opt_state_dtype="float32")
    shape = ShapeConfig("tiny", "train", seq_len=16, global_batch=4)
    return cfg, pcfg, shape


def _batch(cfg, accum, mb, S, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (accum, mb, S + 1))
    return {
        "tokens": jnp.asarray(toks[..., :-1], jnp.int32),
        "labels": jnp.asarray(toks[..., 1:], jnp.int32),
    }


def test_chunked_ce_matches_unchunked(key):
    cfg, pcfg, shape = _tiny_setup()
    params = tf.init_params(cfg, key)
    rng = np.random.default_rng(1)
    hidden = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    full = tr.chunked_cross_entropy(params, cfg, hidden, labels, 0)
    chunked = tr.chunked_cross_entropy(params, cfg, hidden, labels, 4)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)


def test_train_step_reduces_loss_on_learnable_data(key):
    """A few steps on structured data must reduce the loss (end-to-end:
    remat + accumulation + chunked CE + AdamW)."""
    cfg, pcfg, shape = _tiny_setup()
    state = tr.init_train_state(cfg, pcfg, key)
    step = jax.jit(tr.make_train_step(cfg, pcfg, shape, base_lr=1e-2,
                                      warmup=2, total_steps=80))
    # learnable: constant mapping token -> (token+1) % V
    rng = np.random.default_rng(0)
    losses = []
    for i in range(60):
        toks = rng.integers(0, cfg.vocab_size, (2, 4, 33))
        toks[..., 1::2] = (toks[..., 0::2][..., : toks[..., 1::2].shape[-1]]
                           + 1) % cfg.vocab_size
        batch = {
            "tokens": jnp.asarray(toks[..., :-1], jnp.int32),
            "labels": jnp.asarray(toks[..., 1:], jnp.int32),
        }
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]
    assert int(state.step) == 60


def test_accum_equals_bigger_batch(key):
    """grad accumulation over 2 microbatches == one batch of 2x (same data),
    up to numerical noise."""
    cfg, pcfg1, shape1 = _tiny_setup(accum=1)
    pcfg2 = pcfg1.with_(grad_accum={"tiny": 2})
    state0 = tr.init_train_state(cfg, pcfg1, key)

    batch = _batch(cfg, 2, 2, 16)
    merged = {k: v.reshape(1, 4, 16) for k, v in batch.items()}

    s1, m1 = jax.jit(tr.make_train_step(cfg, pcfg1,
                                        ShapeConfig("tiny", "train", 16, 4))
                     )(state0, merged)
    state0b = tr.init_train_state(cfg, pcfg1, key)
    s2, m2 = jax.jit(tr.make_train_step(cfg, pcfg2,
                                        ShapeConfig("tiny", "train", 16, 4))
                     )(state0b, batch)
    w1 = np.asarray(jax.tree_util.tree_leaves(s1.params)[0], np.float32)
    w2 = np.asarray(jax.tree_util.tree_leaves(s2.params)[0], np.float32)
    np.testing.assert_allclose(w1, w2, rtol=1e-4, atol=1e-5)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)


def _run_variant(key, pcfg, n_steps=45, lr=1e-2):
    cfg, _, shape = _tiny_setup(accum=1)
    state = tr.init_train_state(cfg, pcfg, key)
    step = jax.jit(tr.make_train_step(cfg, pcfg, shape, base_lr=lr,
                                      warmup=2, total_steps=n_steps + 5))
    rng = np.random.default_rng(0)
    losses = []
    for i in range(n_steps):
        toks = rng.integers(0, cfg.vocab_size, (1, 8, 33))
        toks[..., 1::2] = (toks[..., 0::2][..., : toks[..., 1::2].shape[-1]]
                           + 1) % cfg.vocab_size
        batch = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
                 "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return state, losses


def test_grad_compression_tracks_uncompressed(key):
    """int8 grad compression w/ error feedback: must learn, and must track
    the uncompressed run closely (the EF property)."""
    cfg, pcfg, shape = _tiny_setup(accum=1)
    _, base = _run_variant(key, pcfg)
    state_c, comp = _run_variant(key, pcfg.with_(grad_compression=True))
    assert state_c.err_buf is not None
    assert base[-1] < base[0] - 0.2, base[::9]
    assert comp[-1] < comp[0] - 0.2, comp[::9]
    assert abs(comp[-1] - base[-1]) < 0.25, (base[-1], comp[-1])


def test_int8_opt_state_tracks_fp32(key):
    """int8 (sqrt-v) optimizer states track the fp32-state trajectory."""
    cfg, pcfg, shape = _tiny_setup(accum=1)
    _, fp32 = _run_variant(key, pcfg)
    _, int8 = _run_variant(key, pcfg.with_(opt_state_dtype="int8"))
    assert fp32[-1] < fp32[0] - 0.2, fp32[::9]
    assert int8[-1] < int8[0] - 0.2, int8[::9]
    assert abs(int8[-1] - fp32[-1]) < 0.25, (fp32[-1], int8[-1])
