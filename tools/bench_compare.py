"""Diff BENCH_<name>.json artifacts and flag qps regressions.

The benchmarks all emit machine-readable ``BENCH_<name>.json`` (see
benchmarks/bench_io.py) with per-row ``qps=...`` figures embedded in the
``derived`` string and a ``us_per_call`` column. This tool makes the perf
trajectory actionable: point it at two artifacts (or two directories of
them — files pair up by benchmark name) and it prints a side-by-side table
with each side's provenance (git sha + timestamp, stamped by the shared
writer) and exits non-zero on any regression beyond the threshold.

A row regresses when its qps drops by more than ``--threshold`` (default
10%), or — for rows without a qps figure — when ``us_per_call`` rises by
more than the threshold. Rows carry an ``ok=False`` style self-check in
``derived`` sometimes; those are the benchmark's own gates and are not
re-judged here. Rows present on only one side are listed but never fail
the diff (benchmarks grow cells over time).

Stdlib-only (like tools/check_docs.py), so CI can run it without a jax
install:

    python tools/bench_compare.py OLD NEW [--threshold 0.10]

where OLD/NEW are BENCH_*.json files or directories containing them.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

_QPS = re.compile(r"(?:^|;)qps=([0-9.eE+-]+)")


def load_artifacts(path: Path) -> dict[str, dict]:
    """{bench name: payload} for one file or every BENCH_*.json in a dir."""
    files = ([path] if path.is_file() else
             sorted(path.glob("BENCH_*.json")))
    if not files:
        raise FileNotFoundError(f"no BENCH_*.json under {path}")
    out = {}
    for f in files:
        payload = json.loads(f.read_text())
        out[payload.get("bench", f.stem)] = payload
    return out


def row_metric(row: dict):
    """(kind, value) — ('qps', v) if the derived string carries one,
    else ('us_per_call', v); (None, None) when neither is usable."""
    m = _QPS.search(row.get("derived", "") or "")
    if m:
        return "qps", float(m.group(1))
    us = row.get("us_per_call")
    if isinstance(us, (int, float)) and us > 0:
        return "us_per_call", float(us)
    return None, None


def provenance(payload: dict) -> str:
    sha = payload.get("git_sha") or "?"
    return f"{str(sha)[:12]} @ {payload.get('iso_time', '?')}"


def compare_bench(name: str, old: dict, new: dict, threshold: float):
    """Yield (row_name, verdict, detail, is_regression) for one benchmark."""
    old_rows = {r["name"]: r for r in old.get("rows", [])}
    new_rows = {r["name"]: r for r in new.get("rows", [])}
    for row_name in sorted(old_rows | new_rows):
        if row_name not in new_rows:
            yield row_name, "dropped", "row only in OLD", False
            continue
        if row_name not in old_rows:
            yield row_name, "new", "row only in NEW", False
            continue
        kind, was = row_metric(old_rows[row_name])
        kind2, now = row_metric(new_rows[row_name])
        if kind is None or kind != kind2:
            yield row_name, "skip", "no comparable metric", False
            continue
        if kind == "qps":
            ratio = now / was if was else float("inf")
            bad = ratio < 1.0 - threshold
            detail = f"qps {was:.0f} -> {now:.0f} ({ratio:.2f}x)"
        else:
            ratio = now / was if was else float("inf")
            bad = ratio > 1.0 + threshold
            detail = f"us/call {was:.1f} -> {now:.1f} ({ratio:.2f}x)"
        yield row_name, ("REGRESSION" if bad else "ok"), detail, bad


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_<name>.json artifacts (or directories)")
    ap.add_argument("old", type=Path)
    ap.add_argument("new", type=Path)
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="fractional qps drop (or us/call rise) that "
                         "counts as a regression (default 0.10)")
    args = ap.parse_args(argv)

    olds, news = load_artifacts(args.old), load_artifacts(args.new)
    n_regressions = 0
    for name in sorted(olds | news):
        if name not in news or name not in olds:
            side = "OLD" if name in olds else "NEW"
            print(f"[{name}] only in {side} — skipped")
            continue
        print(f"[{name}] {provenance(olds[name])}  ->  "
              f"{provenance(news[name])}")
        for row_name, verdict, detail, bad in compare_bench(
                name, olds[name], news[name], args.threshold):
            print(f"  {verdict:>10}  {row_name}  {detail}")
            n_regressions += bad
    print(f"{n_regressions} regression(s) beyond "
          f"{args.threshold:.0%} threshold")
    return 1 if n_regressions else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
