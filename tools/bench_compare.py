"""Diff BENCH_<name>.json artifacts and flag qps regressions.

The benchmarks all emit machine-readable ``BENCH_<name>.json`` (see
benchmarks/bench_io.py) with per-row ``qps=...`` figures embedded in the
``derived`` string and a ``us_per_call`` column. This tool makes the perf
trajectory actionable: point it at two artifacts (or two directories of
them — files pair up by benchmark name) and it prints a side-by-side table
with each side's provenance (git sha + timestamp, stamped by the shared
writer) and exits non-zero on any regression beyond the threshold.

A row regresses when any throughput metric drops by more than
``--threshold`` (default 10%), or any lower-is-better metric rises by
more than it. Every metric present on *both* sides of a row is judged:
``qps_at_slo=`` (the load harness's provisioning number), ``qps=``,
``p99_ms=`` (tail latency, lower is better), ``blocks_touched=`` and
``scan_frac=`` (block-summary pruning effectiveness — lower is better;
a pruned scan touching more of the catalog is a perf regression even
when raw qps holds), ``resident_bytes=`` (tiered-catalog RAM residency,
lower is better), ``hr_at_10=`` (retrieval quality, higher is better),
``staleness_ms=`` (online-learning update-visibility latency, lower is
better), ``overhead_frac=`` (telemetry overhead: the fraction of qps
instrumented serving gives up — lower is better), plus the
``us_per_call`` column. Rows carry an
``ok=False`` style self-check in ``derived`` sometimes; those are the
benchmark's own gates and are not re-judged here. Rows present on only
one side are listed but never fail the diff (benchmarks grow cells over
time), and a metric present on only one side of a row is ignored the
same way.

Stdlib-only (like tools/check_docs.py), so CI can run it without a jax
install:

    python tools/bench_compare.py OLD NEW [--threshold 0.10]

where OLD/NEW are BENCH_*.json files or directories containing them.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

# per-row metrics — every one found in `derived` is judged (bool = lower
# is better); anchored so e.g. achieved_qps= never parses as qps= and
# p50_ms= never parses as p99_ms=
_METRICS = (
    ("qps_at_slo", re.compile(r"(?:^|;)qps_at_slo=([0-9.eE+-]+)"), False),
    ("qps", re.compile(r"(?:^|;)qps=([0-9.eE+-]+)"), False),
    ("p99_ms", re.compile(r"(?:^|;)p99_ms=([0-9.eE+-]+)"), True),
    ("blocks_touched", re.compile(r"(?:^|;)blocks_touched=([0-9.eE+-]+)"),
     True),
    ("scan_frac", re.compile(r"(?:^|;)scan_frac=([0-9.eE+-]+)"), True),
    # tiered-catalog residency: RAM bytes the serving tiers pin — growing
    # it is a capacity regression even at equal qps
    ("resident_bytes", re.compile(r"(?:^|;)resident_bytes=([0-9.eE+-]+)"),
     True),
    # online freshness: retrieval quality (a drop is the regression) and
    # update-landed -> update-visible latency (a rise is the regression)
    ("hr_at_10", re.compile(r"(?:^|;)hr_at_10=([0-9.eE+-]+)"), False),
    ("staleness_ms", re.compile(r"(?:^|;)staleness_ms=([0-9.eE+-]+)"),
     True),
    # telemetry overhead: fractional qps lost to instrumented serving
    # (benchmarks/obs_overhead.py) — growing it is a serving regression
    # even when the uninstrumented baseline holds
    ("overhead_frac", re.compile(r"(?:^|;)overhead_frac=([0-9.eE+-]+)"),
     True),
)


def load_artifacts(path: Path) -> dict[str, dict]:
    """{bench name: payload} for one file or every BENCH_*.json in a dir."""
    files = ([path] if path.is_file() else
             sorted(path.glob("BENCH_*.json")))
    if not files:
        raise FileNotFoundError(f"no BENCH_*.json under {path}")
    out = {}
    for f in files:
        payload = json.loads(f.read_text())
        out[payload.get("bench", f.stem)] = payload
    return out


def row_metrics(row: dict) -> dict[str, float]:
    """{kind: value} for every `_METRICS` field the derived string carries,
    plus the 'us_per_call' column; NaN values (e.g. p99 of an all-shed run)
    are not comparable and are dropped."""
    derived = row.get("derived", "") or ""
    out = {}
    for kind, rx, _ in _METRICS:
        m = rx.search(derived)
        if m:
            v = float(m.group(1))
            if v == v:
                out[kind] = v
    us = row.get("us_per_call")
    if isinstance(us, (int, float)) and us > 0:
        out["us_per_call"] = float(us)
    return out


def metric_lower_is_better(kind: str) -> bool:
    return kind == "us_per_call" or any(
        k == kind and lower for k, _, lower in _METRICS)


def provenance(payload: dict) -> str:
    sha = payload.get("git_sha") or "?"
    return f"{str(sha)[:12]} @ {payload.get('iso_time', '?')}"


def compare_bench(name: str, old: dict, new: dict, threshold: float):
    """Yield (row_name, verdict, detail, is_regression) for one benchmark."""
    old_rows = {r["name"]: r for r in old.get("rows", [])}
    new_rows = {r["name"]: r for r in new.get("rows", [])}
    for row_name in sorted(old_rows | new_rows):
        if row_name not in new_rows:
            yield row_name, "dropped", "row only in OLD", False
            continue
        if row_name not in old_rows:
            yield row_name, "new", "row only in NEW", False
            continue
        olds, news = row_metrics(old_rows[row_name]), \
            row_metrics(new_rows[row_name])
        shared = [k for k in olds if k in news]  # _METRICS order preserved
        if not shared:
            yield row_name, "skip", "no comparable metric", False
            continue
        any_bad, details = False, []
        for kind in shared:
            was, now = olds[kind], news[kind]
            ratio = (now / was) if was else (float("inf") if now else 1.0)
            if metric_lower_is_better(kind):
                bad = ratio > 1.0 + threshold
                details.append(f"{kind} {was:.1f} -> {now:.1f} "
                               f"({ratio:.2f}x)")
            else:
                bad = ratio < 1.0 - threshold
                details.append(f"{kind} {was:.0f} -> {now:.0f} "
                               f"({ratio:.2f}x)")
            any_bad |= bad
        yield (row_name, ("REGRESSION" if any_bad else "ok"),
               ", ".join(details), any_bad)


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_<name>.json artifacts (or directories)")
    ap.add_argument("old", type=Path)
    ap.add_argument("new", type=Path)
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="fractional qps drop (or us/call rise) that "
                         "counts as a regression (default 0.10)")
    args = ap.parse_args(argv)

    olds, news = load_artifacts(args.old), load_artifacts(args.new)
    n_regressions = 0
    for name in sorted(olds | news):
        if name not in news or name not in olds:
            side = "OLD" if name in olds else "NEW"
            print(f"[{name}] only in {side} — skipped")
            continue
        print(f"[{name}] {provenance(olds[name])}  ->  "
              f"{provenance(news[name])}")
        for row_name, verdict, detail, bad in compare_bench(
                name, olds[name], news[name], args.threshold):
            print(f"  {verdict:>10}  {row_name}  {detail}")
            n_regressions += bad
    print(f"{n_regressions} regression(s) beyond "
          f"{args.threshold:.0%} threshold")
    return 1 if n_regressions else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
