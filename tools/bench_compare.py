"""Diff BENCH_<name>.json artifacts and flag qps regressions.

The benchmarks all emit machine-readable ``BENCH_<name>.json`` (see
benchmarks/bench_io.py) with per-row ``qps=...`` figures embedded in the
``derived`` string and a ``us_per_call`` column. This tool makes the perf
trajectory actionable: point it at two artifacts (or two directories of
them — files pair up by benchmark name) and it prints a side-by-side table
with each side's provenance (git sha + timestamp, stamped by the shared
writer) and exits non-zero on any regression beyond the threshold.

A row regresses when its throughput metric drops by more than
``--threshold`` (default 10%), or its latency metric rises by more than
it. Per row, the first metric present wins: ``qps_at_slo=`` (the load
harness's provisioning number), then ``qps=``, then ``p99_ms=`` (tail
latency, lower is better), then the ``us_per_call`` column. Rows carry an ``ok=False`` style self-check in
``derived`` sometimes; those are the benchmark's own gates and are not
re-judged here. Rows present on only one side are listed but never fail
the diff (benchmarks grow cells over time).

Stdlib-only (like tools/check_docs.py), so CI can run it without a jax
install:

    python tools/bench_compare.py OLD NEW [--threshold 0.10]

where OLD/NEW are BENCH_*.json files or directories containing them.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

# per-row metric, first match wins: throughput (higher better) before
# latency (lower better); anchored so e.g. achieved_qps= never parses as
# qps= and p50_ms= never parses as p99_ms=
_METRICS = (
    ("qps_at_slo", re.compile(r"(?:^|;)qps_at_slo=([0-9.eE+-]+)"), False),
    ("qps", re.compile(r"(?:^|;)qps=([0-9.eE+-]+)"), False),
    ("p99_ms", re.compile(r"(?:^|;)p99_ms=([0-9.eE+-]+)"), True),
)


def load_artifacts(path: Path) -> dict[str, dict]:
    """{bench name: payload} for one file or every BENCH_*.json in a dir."""
    files = ([path] if path.is_file() else
             sorted(path.glob("BENCH_*.json")))
    if not files:
        raise FileNotFoundError(f"no BENCH_*.json under {path}")
    out = {}
    for f in files:
        payload = json.loads(f.read_text())
        out[payload.get("bench", f.stem)] = payload
    return out


def row_metric(row: dict):
    """(kind, value) — the first `_METRICS` field the derived string
    carries, else ('us_per_call', v); (None, None) when none is usable."""
    derived = row.get("derived", "") or ""
    for kind, rx, _ in _METRICS:
        m = rx.search(derived)
        if m:
            v = float(m.group(1))
            if v == v:  # NaN (e.g. p99 of an all-shed run) is not comparable
                return kind, v
    us = row.get("us_per_call")
    if isinstance(us, (int, float)) and us > 0:
        return "us_per_call", float(us)
    return None, None


def metric_lower_is_better(kind: str) -> bool:
    return kind == "us_per_call" or any(
        k == kind and lower for k, _, lower in _METRICS)


def provenance(payload: dict) -> str:
    sha = payload.get("git_sha") or "?"
    return f"{str(sha)[:12]} @ {payload.get('iso_time', '?')}"


def compare_bench(name: str, old: dict, new: dict, threshold: float):
    """Yield (row_name, verdict, detail, is_regression) for one benchmark."""
    old_rows = {r["name"]: r for r in old.get("rows", [])}
    new_rows = {r["name"]: r for r in new.get("rows", [])}
    for row_name in sorted(old_rows | new_rows):
        if row_name not in new_rows:
            yield row_name, "dropped", "row only in OLD", False
            continue
        if row_name not in old_rows:
            yield row_name, "new", "row only in NEW", False
            continue
        kind, was = row_metric(old_rows[row_name])
        kind2, now = row_metric(new_rows[row_name])
        if kind is None or kind != kind2:
            yield row_name, "skip", "no comparable metric", False
            continue
        ratio = now / was if was else float("inf")
        if metric_lower_is_better(kind):
            bad = ratio > 1.0 + threshold
            detail = f"{kind} {was:.1f} -> {now:.1f} ({ratio:.2f}x)"
        else:
            bad = ratio < 1.0 - threshold
            detail = f"{kind} {was:.0f} -> {now:.0f} ({ratio:.2f}x)"
        yield row_name, ("REGRESSION" if bad else "ok"), detail, bad


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_<name>.json artifacts (or directories)")
    ap.add_argument("old", type=Path)
    ap.add_argument("new", type=Path)
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="fractional qps drop (or us/call rise) that "
                         "counts as a regression (default 0.10)")
    args = ap.parse_args(argv)

    olds, news = load_artifacts(args.old), load_artifacts(args.new)
    n_regressions = 0
    for name in sorted(olds | news):
        if name not in news or name not in olds:
            side = "OLD" if name in olds else "NEW"
            print(f"[{name}] only in {side} — skipped")
            continue
        print(f"[{name}] {provenance(olds[name])}  ->  "
              f"{provenance(news[name])}")
        for row_name, verdict, detail, bad in compare_bench(
                name, olds[name], news[name], args.threshold):
            print(f"  {verdict:>10}  {row_name}  {detail}")
            n_regressions += bad
    print(f"{n_regressions} regression(s) beyond "
          f"{args.threshold:.0%} threshold")
    return 1 if n_regressions else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
