"""Docs link/reference checker: docs can't silently rot.

Scans the markdown docs (docs/*.md + ROADMAP.md) for

  * relative markdown links — ``[text](path)`` with no URL scheme — which
    must resolve to an existing file (anchors are stripped), and
  * source-tree references — any token that looks like a repo path under a
    known prefix (``src/``, ``tests/``, ``benchmarks/``, ``examples/``,
    ``docs/``, ``tools/``, ``.github/``, or package-relative ``core/``,
    ``kernels/``, ``serving/``, resolved under ``src/repro``) — which must
    name an existing file or directory. ``path.py:symbol`` /
    ``path.py:123`` suffixes are allowed and stripped.
  * absolute filesystem paths (``/root/...``, ``/home/...``, ``/tmp/...``,
    ``/opt/...``, ``/usr/...``, ``/var/...``) — always flagged: they
    reference one author's machine, not the repo, so they rot the moment
    anyone else (or CI) reads the doc.

Exits non-zero listing every dangling reference. Run from the repo root:

    python tools/check_docs.py [files...]

CI runs this on every push (the `docs` step) and
tests/test_docs.py runs it as a tier-1 test.
"""
from __future__ import annotations

import glob
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# top-level prefixes checked against the repo root
_ROOT_PREFIXES = ("src/", "tests/", "benchmarks/", "examples/", "docs/",
                  "tools/", ".github/")
# package-relative prefixes, resolved under src/repro (docs shorthand)
_PKG_PREFIXES = ("core/", "kernels/", "serving/", "models/", "configs/",
                 "launch/", "distributed/", "data/", "checkpoint/", "optim/")

_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_PATH_TOKEN = re.compile(r"[A-Za-z0-9_./-]+")
# machine-local absolute paths: never valid in a doc, whether or not the
# path happens to exist on the machine running the checker
_ABS_PATH = re.compile(r"(?<![\w./-])/(?:root|home|tmp|opt|usr|var)/"
                       r"[A-Za-z0-9_./-]+")


def _exists(rel: str) -> bool:
    rel = rel.split("#", 1)[0]
    # strip `path.py:symbol` / `path.py:123` suffixes
    if ":" in rel:
        rel = rel.split(":", 1)[0]
    if not rel:
        return True
    return (REPO / rel).exists()


def check_file(path: Path) -> list[str]:
    """Dangling references in one markdown file, as readable messages."""
    text = path.read_text()
    resolved_path = path.resolve()
    label = (str(resolved_path.relative_to(REPO))
             if resolved_path.is_relative_to(REPO) else path.name)
    problems = []
    for m in _MD_LINK.finditer(text):
        target = m.group(1)
        if "://" in target or target.startswith(("mailto:", "#")):
            continue
        resolved = (path.parent / target.split("#", 1)[0]).resolve()
        if not resolved.exists():
            problems.append(f"{label}: dead link ({target})")
    for token in _PATH_TOKEN.findall(text):
        token = token.rstrip(".,;")
        if token.startswith(_ROOT_PREFIXES):
            if not _exists(token):
                problems.append(f"{label}: missing path ({token})")
        elif token.startswith(_PKG_PREFIXES) and "." in token:
            # package shorthand: only flag file-looking tokens (with an
            # extension) to avoid matching prose like "core/ banks"
            if not _exists(f"src/repro/{token}"):
                problems.append(
                    f"{label}: missing src/repro path ({token})")
    for m in _ABS_PATH.finditer(text):
        problems.append(f"{label}: absolute path outside the repo "
                        f"({m.group(0)})")
    return problems


def main(argv: list[str]) -> int:
    files = ([Path(a) for a in argv] if argv else
             [Path(p) for p in sorted(glob.glob(str(REPO / "docs" / "*.md")))]
             + [REPO / "ROADMAP.md"])
    problems = []
    for f in files:
        problems += check_file(f)
    for p in problems:
        print(f"DANGLING {p}")
    print(f"checked {len(files)} files: "
          f"{'OK' if not problems else f'{len(problems)} dangling refs'}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
