"""Render a per-stage latency breakdown from a serving trace.

The serving front-ends stamp every ticket with a stage-span chain
(submit -> admit -> bucket -> dispatch -> scan -> rank -> resolve; see
src/repro/obs/tracing.py and docs/OBSERVABILITY.md). `take_trace()` hands
the records back in-process; `repro.obs.dump_trace` writes them as JSONL.
This tool turns either form into the table iMARS-style evaluations lead
with — where each microsecond of a request actually went:

    python tools/obs_report.py TRACE.jsonl [--tenant N] [--status ok]

Stdlib-only (the check_docs/bench_compare idiom), so CI and laptops can
render a trace without a jax install. Import surface for harnesses:
`load_trace` (JSONL -> records), `stage_breakdown` (records -> per-stage
stats), `render_breakdown` (stats -> table text). `stage_breakdown`
accepts both the JSONL dict shape and live `TicketTrace` records, so
``examples/serve_recsys.py --report`` feeds `take_trace()` output
straight in.

Each chain is contiguous (stage i starts where stage i-1 ended), so the
per-stage means sum to the mean ticket latency exactly; the breakdown
also reports that sum against the measured submit->done latency — the
consistency `benchmarks/obs_overhead.py` gates at 10%.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# canonical stage order (src/repro/obs/tracing.py STAGES, sans submit:
# the submit boundary opens the chain and is never charged time)
_STAGE_ORDER = ("admit", "bucket", "dispatch", "scan", "rank", "resolve")


def load_trace(path) -> list[dict]:
    """Read a `dump_trace` JSONL file: one trace record dict per line."""
    records = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not JSON: {e}") from e
    return records


def _as_dict(rec) -> dict:
    """One record as the JSONL dict shape (accepts live TicketTrace)."""
    if isinstance(rec, dict):
        return rec
    return {"ticket": rec.ticket, "tenant": rec.tenant,
            "submit_s": rec.submit_s, "done_s": rec.done_s,
            "status": rec.status, "stages": list(rec.stages)}


def _quantile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank quantile of an ascending list (0.0 when empty)."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[idx]


def stage_breakdown(records, *, tenant=None, status=None) -> dict:
    """Per-stage latency stats over `records` (dicts or TicketTrace).

    Returns::

        {"n": tickets counted, "by_status": {status: count},
         "latency_s": {"mean", "p50", "p99", "max"},
         "stage_sum_mean_s": mean of per-ticket stage sums,
         "stages": {stage: {"n", "mean_s", "p50_s", "p99_s", "max_s",
                            "frac"}}}

    ``frac`` is the stage's share of total traced time. Tickets with an
    empty chain (``trace=False`` servers) count toward ``n``/``by_status``
    but contribute no stage rows. `tenant` / `status` filter the records
    before aggregation.
    """
    by_status: dict = {}
    latencies: list[float] = []
    sums: list[float] = []
    per_stage: dict = {}
    n = 0
    for rec in records:
        rec = _as_dict(rec)
        if tenant is not None and rec.get("tenant") != tenant:
            continue
        if status is not None and rec.get("status") != status:
            continue
        n += 1
        by_status[rec["status"]] = by_status.get(rec["status"], 0) + 1
        latencies.append(float(rec["done_s"]) - float(rec["submit_s"]))
        stages = rec.get("stages") or []
        if len(stages) < 2:
            continue
        total = 0.0
        for (_, t0), (name, t1) in zip(stages, stages[1:]):
            d = float(t1) - float(t0)
            per_stage.setdefault(name, []).append(d)
            total += d
        sums.append(total)
    latencies.sort()
    grand = sum(sum(v) for v in per_stage.values())
    stages_out = {}
    for name in _STAGE_ORDER:
        vals = sorted(per_stage.get(name, []))
        if not vals:
            continue
        stages_out[name] = {
            "n": len(vals),
            "mean_s": sum(vals) / len(vals),
            "p50_s": _quantile(vals, 0.50),
            "p99_s": _quantile(vals, 0.99),
            "max_s": vals[-1],
            "frac": (sum(vals) / grand) if grand else 0.0,
        }
    return {
        "n": n,
        "by_status": by_status,
        "latency_s": {
            "mean": sum(latencies) / len(latencies) if latencies else 0.0,
            "p50": _quantile(latencies, 0.50),
            "p99": _quantile(latencies, 0.99),
            "max": latencies[-1] if latencies else 0.0,
        },
        "stage_sum_mean_s": sum(sums) / len(sums) if sums else 0.0,
        "stages": stages_out,
    }


def render_breakdown(bd: dict) -> str:
    """The breakdown as a fixed-width table (submit -> resolve order)."""
    us = 1e6
    lines = []
    statuses = ", ".join(f"{k}={v}" for k, v in sorted(bd["by_status"]
                                                       .items()))
    lines.append(f"tickets: {bd['n']} ({statuses or 'none'})")
    lat = bd["latency_s"]
    lines.append(f"latency: mean {lat['mean'] * us:10.1f} us   "
                 f"p50 {lat['p50'] * us:10.1f} us   "
                 f"p99 {lat['p99'] * us:10.1f} us   "
                 f"max {lat['max'] * us:10.1f} us")
    lines.append(f"{'stage':>10}  {'n':>8}  {'mean_us':>12}  "
                 f"{'p50_us':>12}  {'p99_us':>12}  {'frac':>6}")
    for name in _STAGE_ORDER:
        st = bd["stages"].get(name)
        if st is None:
            continue
        lines.append(f"{name:>10}  {st['n']:>8}  "
                     f"{st['mean_s'] * us:>12.1f}  "
                     f"{st['p50_s'] * us:>12.1f}  "
                     f"{st['p99_s'] * us:>12.1f}  {st['frac']:>6.1%}")
    if bd["stages"]:
        lines.append(f"stage-sum mean {bd['stage_sum_mean_s'] * us:.1f} us "
                     f"vs latency mean {lat['mean'] * us:.1f} us")
    return "\n".join(lines)


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="per-stage latency breakdown of a serving trace "
                    "(dump_trace JSONL)")
    ap.add_argument("trace", type=Path, help="trace JSONL file")
    ap.add_argument("--tenant", type=int, default=None,
                    help="only this tenant's tickets")
    ap.add_argument("--status", default=None,
                    choices=("ok", "shed", "error"),
                    help="only tickets with this status")
    args = ap.parse_args(argv)
    records = load_trace(args.trace)
    bd = stage_breakdown(records, tenant=args.tenant, status=args.status)
    print(render_breakdown(bd))
    return 0 if bd["n"] else 1


if __name__ == "__main__":
    try:
        sys.exit(main(sys.argv[1:]))
    except BrokenPipeError:  # e.g. piped into `head`
        sys.exit(0)
